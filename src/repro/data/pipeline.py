"""Synthetic sharded token pipeline.

Deterministic per (seed, step, shard): every data-parallel host generates
only its shard, so the pipeline scales to any process count without a
central dispenser — and a restarted/elastic job regenerates identical
batches from the step counter alone (important for the fault-tolerance
story: data state is a pure function of `step`).

The generator is a cheap per-element hash (splitmix-style) producing a
Zipf-ish skewed token stream plus a deterministic "document" structure so
losses are not pure noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import ModelConfig, ShapeConfig


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


@dataclass
class SyntheticLM:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    num_shards: int = 1
    shard: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        B = self.shape.global_batch // self.num_shards
        S = self.shape.seq_len
        idx = (np.arange(B * (S + 1), dtype=np.uint64).reshape(B, S + 1)
               + np.uint64(step) * np.uint64(1 << 32)
               + np.uint64(self.shard) * np.uint64(1 << 48)
               + np.uint64(self.seed) * np.uint64(1 << 56))
        h = _splitmix(idx)
        # Zipf-ish skew: square a uniform to concentrate mass at low ids
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = (u * u * self.cfg.vocab_size).astype(np.int32)
        out = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
        if self.cfg.family == "audio":
            f = _splitmix(idx[:, :S] + np.uint64(7))
            frames = ((f >> np.uint64(11)).astype(np.float64) / float(1 << 53)
                      - 0.5).astype(np.float32)
            out["frames"] = np.repeat(frames[:, :, None],
                                      self.cfg.frontend_embed_dim, axis=2)
        if self.cfg.family == "vlm":
            g = _splitmix(idx[:, :64] + np.uint64(13))
            patches = ((g >> np.uint64(11)).astype(np.float64) / float(1 << 53)
                       - 0.5).astype(np.float32)
            out["patches"] = np.repeat(patches[:, :, None],
                                       self.cfg.frontend_embed_dim, axis=2)
            p = np.arange(S, dtype=np.int32)[None].repeat(B, 0)
            out["positions"] = np.stack([p, p, p])
        return out


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int = 0,
               seed: int = 0) -> Dict[str, np.ndarray]:
    return SyntheticLM(cfg, shape, seed=seed).batch(step)
