"""Architecture registry: maps ``--arch`` ids to ModelConfig factories."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from .base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}

# All modules under repro.configs that register an architecture.
_CONFIG_MODULES = [
    "gemma3_4b",
    "smollm_360m",
    "qwen2_72b",
    "mistral_nemo_12b",
    "qwen3_moe_30b_a3b",
    "llama4_maverick_400b_a17b",
    "seamless_m4t_large_v2",
    "jamba_v0_1_52b",
    "qwen2_vl_7b",
    "mamba2_370m",
    "mixtral_8x7b",
    "phi35_moe",
]


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def _ensure_loaded() -> None:
    for mod in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)
