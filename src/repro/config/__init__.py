from .base import (CacheConfig, ModelConfig, MoEConfig, OptimizerConfig,
                   RuntimeConfig, SHAPES, ShapeConfig, SSMConfig, reduced)
from .registry import get_config, list_archs, register

__all__ = [
    "CacheConfig", "ModelConfig", "MoEConfig", "OptimizerConfig",
    "RuntimeConfig", "SHAPES", "ShapeConfig", "SSMConfig", "reduced",
    "get_config", "list_archs", "register",
]
