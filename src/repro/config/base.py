"""Configuration dataclasses for the repro framework.

Everything is a frozen dataclass so configs are hashable and can be used as
jit static arguments. Architecture configs live in ``repro.configs.<id>``
and register themselves via :mod:`repro.config.registry`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Tuple

LayerKind = Literal["attn", "mamba"]
FFNKind = Literal["dense", "moe"]


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden size
    num_shared_experts: int = 0     # always-on shared experts (llama4-style)
    router_jitter: float = 0.0
    capacity_factor: float = 1.25   # train-time dispatch capacity (drops ok)
    serve_capacity_factor: float = 8.0  # prefill/serve: effectively dropless
    aux_loss_coef: float = 0.01     # load-balance loss weight


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture config. One instance per ``--arch`` id."""

    name: str
    family: Literal["dense", "moe", "audio", "hybrid", "vlm", "ssm"]
    num_layers: int
    d_model: int
    num_heads: int          # query heads (0 for attn-free archs)
    num_kv_heads: int
    d_ff: int               # dense FFN hidden size (0 if every FFN is MoE)
    vocab_size: int
    head_dim: int = 0       # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Layer pattern --------------------------------------------------------
    # attn/mamba interleave, repeated cyclically over num_layers.
    layer_pattern: Tuple[LayerKind, ...] = ("attn",)
    # Which layers get the MoE FFN: every `moe_every` layers starting at
    # `moe_offset` (1 -> all layers are MoE).
    moe_every: int = 1
    moe_offset: int = 0
    # Sliding-window pattern: window size per pattern slot, -1 = global.
    # e.g. gemma3: (1024,)*5 + (-1,) repeated. Empty -> all global.
    window_pattern: Tuple[int, ...] = ()
    # Attention details ----------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False              # multimodal 3D RoPE (qwen2-vl)
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # Encoder-decoder -----------------------------------------------------
    encoder_layers: int = 0          # >0 -> enc-dec model (seamless)
    # Modality frontend stub: inputs are precomputed embeddings of this dim
    # instead of token ids (audio/vlm encoders).
    frontend_embed_dim: int = 0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    max_seq_len: int = 131072

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # Derived -------------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return all(k == "mamba" for k in self.layer_pattern)

    def layer_kind(self, i: int) -> LayerKind:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe_every) == self.moe_offset

    def window_for_layer(self, i: int) -> int:
        if not self.window_pattern:
            return -1
        return self.window_pattern[i % len(self.window_pattern)]

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context (500k) decode is architecturally sensible."""
        if self.attn_free:
            return True
        n_attn = sum(1 for i in range(self.num_layers) if self.layer_kind(i) == "attn")
        if n_attn <= self.num_layers // 4:   # hybrid (jamba)
            return True
        if self.window_pattern and sum(1 for w in self.window_pattern if w > 0) * 2 >= len(self.window_pattern):
            return True                       # mostly sliding-window (gemma3)
        return False

    # Parameter counts (analytic; used by roofline + cache sizing) --------
    def _attn_params(self) -> int:
        hd = self.head_dim
        return self.d_model * hd * (self.num_heads + 2 * self.num_kv_heads) + \
            self.num_heads * hd * self.d_model

    def _dense_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _moe_ffn_params(self, active_only: bool = False) -> int:
        m = self.moe
        e = (m.top_k + m.num_shared_experts) if active_only else (m.num_experts + m.num_shared_experts)
        return 3 * self.d_model * m.d_ff * e

    def _mamba_params(self) -> int:
        s = self.ssm
        di = s.d_inner(self.d_model)
        nh = s.num_heads(self.d_model)
        # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
        return self.d_model * (2 * di + 2 * s.d_state + nh) + di * self.d_model + \
            (di + 2 * s.d_state) * s.d_conv + 2 * nh

    def param_count(self, active_only: bool = False) -> int:
        total = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        if self.frontend_embed_dim:
            total += self.frontend_embed_dim * self.d_model
        layers = self.num_layers + self.encoder_layers
        for i in range(layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += self._attn_params()
            else:
                total += self._mamba_params()
            if self.is_moe_layer(i):
                total += self._moe_ffn_params(active_only)
                total += self.moe.num_experts * self.d_model  # router
            elif self.d_ff > 0:
                total += self._dense_ffn_params()
        if self.encoder_layers:  # cross-attention in decoder
            total += self.num_layers * self._attn_params()
        return int(total)

    def expert_bytes(self, bytes_per_param: int = 2) -> int:
        """Size of a single expert's weights (the cache slot unit)."""
        if self.moe is None:
            return 0
        return 3 * self.d_model * self.moe.d_ff * bytes_per_param


@dataclass(frozen=True)
class CacheConfig:
    """Set-associative expert-cache configuration (the paper's §III-B)."""

    num_indexes: int          # N: cached layers 0..N-1 (one set per layer)
    num_ways: int             # M: expert slots per set
    policy: Literal["lru", "fifo", "random"] = "lru"

    @property
    def num_slots(self) -> int:
        return self.num_indexes * self.num_ways

    @staticmethod
    def from_memory(mem_bytes: int, expert_bytes: int, num_ways: int,
                    policy: str = "lru", max_layers: int = 10 ** 9) -> "CacheConfig":
        """Paper: S = mem/expert_size, N = floor(S/M)."""
        slots = int(mem_bytes // max(expert_bytes, 1))
        n = min(slots // num_ways, max_layers)
        if n < 1:
            raise ValueError(
                f"cache memory {mem_bytes} too small for even one {num_ways}-way set "
                f"of {expert_bytes}-byte experts")
        return CacheConfig(num_indexes=n, num_ways=num_ways, policy=policy)  # type: ignore[arg-type]


@dataclass(frozen=True)
class ShapeConfig:
    """Input-shape cell: what step gets lowered and with what geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0
    # int8 gradient compression across the (slow) pod axis
    compress_pod_grads: bool = False


@dataclass(frozen=True)
class RuntimeConfig:
    """Distributed runtime knobs."""

    remat: bool = True
    remat_policy: str = "dots_with_no_batch_dims"
    donate_state: bool = True
    # Checkpointing
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 100
    keep_ckpts: int = 3
    async_ckpt: bool = True
    # Fault tolerance
    heartbeat_interval_s: float = 10.0
    straggler_grace_s: float = 30.0
    elastic: bool = True


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    period = max(len(cfg.layer_pattern), len(cfg.window_pattern) or 1,
                 cfg.moe_every)
    changes = dict(
        num_layers=min(cfg.num_layers, 2 * period),
        d_model=128,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32 if cfg.num_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_embed_dim=64 if cfg.frontend_embed_dim else 0,
        max_seq_len=512,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2), d_ff=128)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=32, head_dim=32, chunk_size=64)
    if cfg.window_pattern:
        changes["window_pattern"] = tuple(64 if w > 0 else -1 for w in cfg.window_pattern)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
