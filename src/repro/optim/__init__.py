from .adamw import (AdamWState, adamw_update, clip_by_global_norm,
                    compress_int8, decompress_int8, global_norm,
                    init_opt_state, lr_schedule, maybe_compress_grads)
from .train_step import make_train_step

__all__ = ["AdamWState", "adamw_update", "clip_by_global_norm",
           "compress_int8", "decompress_int8", "global_norm",
           "init_opt_state", "lr_schedule", "maybe_compress_grads",
           "make_train_step"]
