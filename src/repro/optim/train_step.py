"""The jittable train step: loss -> grads -> clip -> (compress) -> AdamW.

ZeRO-1 resharding is expressed with sharding constraints around the update
(see repro.sharding.partition); when no mesh is active the constraints are
no-ops and this is a plain single-host step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, OptimizerConfig
from repro.models import loss_fn
from repro.sharding import constrain, current_mesh
from repro.sharding.partition import opt_state_spec, param_specs_for
from .adamw import (AdamWState, adamw_update, clip_by_global_norm,
                    maybe_compress_grads)


def _constrain_tree(tree: Any, specs: Any):
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return tree
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, specs)


def _split_micro(batch: Dict[str, jax.Array], micro: int):
    """Reshape the global batch into [micro, B/micro, ...] microbatches.
    `positions` carries batch on dim 1 ([3, B, S]); everything else dim 0."""
    out = {}
    for k, v in batch.items():
        if k == "positions":
            out[k] = v.reshape(v.shape[0], micro, v.shape[1] // micro,
                               *v.shape[2:]).swapaxes(0, 1)
        else:
            out[k] = v.reshape(micro, v.shape[0] // micro, *v.shape[1:])
    return out


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig,
                    remat: bool = True, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Donate params and opt_state when jitting.

    microbatches > 1 = gradient accumulation: the forward/backward runs
    per microbatch inside a scan (activation memory divides by the count;
    grads accumulate in fp32) — the standard lever when a cell's global
    batch does not fit HBM at the target mesh."""

    def _grads(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, remat=remat),
            has_aux=True)(params)

    def train_step(params, opt_state: AdamWState, batch
                   ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        if microbatches > 1:
            mb = _split_micro(batch, microbatches)

            # ZeRO-2-style: the fp32 accumulator lives in the optimizer
            # sharding (grads reduce-scattered every microbatch) — a
            # TP-only fp32 accumulator would itself blow HBM (measured
            # 12.9 GB/device on jamba-52B).
            mesh = current_mesh()
            ospecs = None
            if mesh is not None and mesh.size > 1:
                pspecs = param_specs_for(params, mesh)
                ospecs = jax.tree.map(
                    lambda sp, p: opt_state_spec(sp, p.shape, mesh),
                    pspecs, params)

            def acc(carry, mbatch):
                gsum, lsum, xsum, asum = carry
                (l, parts), g = _grads(params, mbatch)
                if ospecs is not None:
                    g = _constrain_tree(g, ospecs)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, xsum + parts["xent"],
                        asum + parts["aux"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if ospecs is not None:
                zeros = _constrain_tree(zeros, ospecs)
            (gsum, lsum, xsum, asum), _ = jax.lax.scan(
                acc, (zeros, 0.0, 0.0, 0.0), mb)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: (g * inv), gsum)
            loss = lsum * inv
            parts = {"xent": xsum * inv, "aux": asum * inv}
        else:
            (loss, parts), grads = _grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, ocfg.grad_clip)
        grads = maybe_compress_grads(grads, ocfg)

        mesh = current_mesh()
        if mesh is not None and mesh.size > 1:
            pspecs = param_specs_for(params, mesh)
            ospecs = jax.tree.map(
                lambda sp, p: opt_state_spec(sp, p.shape, mesh), pspecs, params)
            # ZeRO-1: reduce-scatter grads into the optimizer sharding
            grads = _constrain_tree(grads, ospecs)
            opt_in = AdamWState(opt_state.step,
                                _constrain_tree(opt_state.mu, ospecs),
                                _constrain_tree(opt_state.nu, ospecs),
                                _constrain_tree(opt_state.master, ospecs))
            sharded_params = _constrain_tree(params, ospecs)
            new_params, new_opt = adamw_update(grads, opt_in, sharded_params,
                                               ocfg)
            # all-gather updated params back to the compute sharding
            new_params = _constrain_tree(new_params, pspecs)
        else:
            new_params, new_opt = adamw_update(grads, opt_state, params, ocfg)

        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        return new_params, new_opt, metrics

    return train_step
