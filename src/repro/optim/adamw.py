"""AdamW with fp32 master weights, ZeRO-1 sharded optimizer state.

State layout per parameter: {mu, nu, master} fp32, sharded with the param's
spec *plus* a "data"-axis shard on the largest free divisible dim
(sharding/partition.opt_state_spec). The train step:

  grads (param sharding) --constrain--> opt sharding   [reduce-scatter]
  shard-local AdamW update on master fp32
  new bf16 params --constrain--> param sharding        [all-gather]

which is exactly the GSPMD spelling of ZeRO-1. Optional int8 gradient
compression models the cross-pod (DCN) all-reduce precision reduction.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any


def init_opt_state(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: fp32 params would otherwise *alias* the master buffer
    # (astype is a no-op), breaking donation of (params, opt_state) pairs
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                          params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def lr_schedule(ocfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to 10%."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - ocfg.warmup_steps) /
                    jnp.maximum(ocfg.total_steps - ocfg.warmup_steps, 1), 0, 1)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * warm * cos


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), n


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (DCN gradient compression)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def maybe_compress_grads(grads: Any, ocfg: OptimizerConfig) -> Any:
    """Round-trips grads through int8 (the precision the pod-axis all-reduce
    would carry on DCN). No-op unless ocfg.compress_pod_grads."""
    if not ocfg.compress_pod_grads:
        return grads
    def rt(g):
        if g.ndim == 0:
            return g
        q, s = compress_int8(g)
        return decompress_int8(q, s).astype(g.dtype)
    return jax.tree.map(rt, grads)


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 ocfg: OptimizerConfig) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    lr = lr_schedule(ocfg, step)
    b1, b2 = ocfg.beta1, ocfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        master = master - lr * (mhat / (jnp.sqrt(nhat) + ocfg.eps)
                                + ocfg.weight_decay * master)
        return mu, nu, master

    out = jax.tree.map(upd, grads, state.mu, state.nu, state.master)
    mu = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, AdamWState(step=step, mu=mu, nu=nu, master=master)
