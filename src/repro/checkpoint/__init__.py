from .ckpt import (CheckpointManager, load_checkpoint, save_checkpoint,
                   reshard_tree)

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint",
           "reshard_tree"]
