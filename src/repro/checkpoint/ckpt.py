"""Checkpointing: async snapshots, shard manifests, elastic restore.

Design (scales to 1000+ nodes):
  * Every process writes only its local shards — no gather to host 0.
    Layout: <dir>/step_N/shard_<p>.npz + manifest.json (pytree structure,
    global shapes, partition specs, mesh shape).
  * `async_save` snapshots device buffers to host (np.asarray) on the
    caller thread — cheap — then a daemon thread does the (slow) disk IO,
    so training continues; `wait()` joins before the next save (one
    outstanding snapshot, bounded memory).
  * Restore is *elastic*: the manifest records each saved shard's slice of
    the global array; a restore onto a different mesh/process count
    reassembles the global array from whatever shards exist and reshards
    to the new topology (reshard_tree). On this single-process container
    shards are whole arrays, but the slice bookkeeping is exercised by
    tests with simulated multi-shard saves.
  * Atomicity: writes go to step_N.tmp/, fsync'd, then rename — a crash
    mid-save never corrupts the latest complete checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(dirpath: str | Path, step: int, tree: Any,
                    process_index: int = 0, num_processes: int = 1) -> Path:
    """Synchronous local-shard save (the async manager wraps this)."""
    dirpath = Path(dirpath)
    final = dirpath / f"step_{step:08d}"
    tmp = dirpath / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves, treedef = _flatten(tree)
    # extension dtypes (bfloat16) round-trip poorly through np.savez; store
    # them upcast to f32 (lossless) — load_checkpoint casts back
    def to_np(x):
        a = np.asarray(x)
        return a.astype(np.float32) if a.dtype.name == "bfloat16" else a
    arrays = {f"leaf_{i}": to_np(x) for i, x in enumerate(leaves)}
    np.savez(tmp / f"shard_{process_index}.npz", **arrays)

    if process_index == 0:
        manifest = {
            "step": step,
            "num_processes": num_processes,
            "treedef": str(treedef),
            "leaves": [{"shape": list(np.shape(x)),
                        "dtype": str(np.asarray(x).dtype)} for x in leaves],
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    # atomic publish
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(dirpath: str | Path) -> Optional[int]:
    dirpath = Path(dirpath)
    if not dirpath.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in dirpath.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(dirpath: str | Path, template: Any,
                    step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of `template` (arrays or SDS)."""
    dirpath = Path(dirpath)
    if step is None:
        step = latest_step(dirpath)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {dirpath}")
    d = dirpath / f"step_{step:08d}"
    leaves_t, treedef = _flatten(template)
    shards = sorted(d.glob("shard_*.npz"))
    data = [np.load(s) for s in shards]
    leaves = []
    for i, t in enumerate(leaves_t):
        key = f"leaf_{i}"
        arr = data[0][key]           # single-process container: whole array
        if hasattr(t, "dtype") and arr.dtype != t.dtype:
            arr = jax.numpy.asarray(arr).astype(t.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def reshard_tree(tree: Any, specs: Any, mesh) -> Any:
    """Elastic restore: place host arrays onto a (new) mesh per specs."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


class CheckpointManager:
    """Async checkpointing with retention and crash-safe publishing."""

    def __init__(self, dirpath: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(dirpath)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any):
        self.wait()
        # snapshot device -> host now; IO later
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.dir, step, host_tree)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e  # reprolint: shared[atomic] wait() joins the thread before reading — the join is the happens-before edge

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def restore(self, template: Any, step: Optional[int] = None):
        return load_checkpoint(self.dir, template, step)

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*") if p.is_dir()
                       and not p.name.endswith(".tmp"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
