"""Fault tolerance: heartbeats, straggler detection, supervised train loop.

At 1000+ nodes the failure model is: some worker stops heartbeating
(hardware fault / preemption), or heartbeats but runs slow (straggler —
thermal throttling, a bad HBM stack, a flaky ICI link). The supervisor
composes three mechanisms, all unit-testable without real failures:

  FailureDetector   — per-worker phi-style timeout detector over a
                      heartbeat table (monotonic timestamps).
  StragglerMonitor  — per-step duration EWMA + robust z-score; flags
                      workers whose step times exceed median + k·MAD. The
                      mitigation at scale is checkpoint-and-exclude
                      (shrink the data axis); locally we record decisions.
  TrainSupervisor   — drives step(); on a detected failure restores the
                      latest checkpoint and replans the mesh via
                      runtime.elastic (the data pipeline is a pure
                      function of `step`, so replay is exact).

JAX's gang-scheduled SPMD model means a lost worker kills the step
globally; recovery is restart-from-checkpoint with a (possibly smaller)
mesh — exactly what plan_reshard + CheckpointManager implement. There is
deliberately no attempt at per-worker hot-swap inside a step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass
class FailureDetector:
    timeout_s: float = 30.0
    _last_beat: Dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int, now: Optional[float] = None):
        self._last_beat[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(w for w, t in self._last_beat.items()
                      if now - t > self.timeout_s)

    def alive_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(w for w, t in self._last_beat.items()
                      if now - t <= self.timeout_s)


@dataclass
class StragglerMonitor:
    """Flags workers whose step durations are median + k*MAD outliers."""
    k: float = 5.0
    window: int = 20
    _hist: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, worker: int, step_s: float):
        h = self._hist.setdefault(worker, [])
        h.append(step_s)
        if len(h) > self.window:
            h.pop(0)

    def stragglers(self) -> List[int]:
        if len(self._hist) < 2:
            return []
        means = {w: float(np.mean(h)) for w, h in self._hist.items() if h}
        vals = np.array(list(means.values()))
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med))) + 1e-9
        return sorted(w for w, m in means.items() if m > med + self.k * mad)


class TrainSupervisor:
    """Restart-from-checkpoint supervision around a step callable.

    step_fn(state, step_idx) -> state; save_fn(step, state);
    restore_fn() -> (state, step). `inject_failure` hooks let tests drive
    failure scenarios deterministically.
    """

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, ckpt_every: int = 100,
                 max_restarts: int = 3):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.restarts = 0
        self.detector = FailureDetector()
        self.straggler = StragglerMonitor()

    def run(self, state, start_step: int, num_steps: int,
            failure_at: Optional[int] = None):
        """Runs steps [start_step, start_step+num_steps); `failure_at`
        raises a simulated fault at that step (tests)."""
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                t0 = time.monotonic()
                if failure_at is not None and step == failure_at:
                    failure_at = None      # fail exactly once
                    raise RuntimeError("injected worker failure")
                state = self.step_fn(state, step)
                self.straggler.record(0, time.monotonic() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self.restore_fn()
        return state, step
