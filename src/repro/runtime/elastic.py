"""Elastic scaling: replan the mesh when workers are lost / added.

Policy: keep the "model" axis fixed (TP/EP degree is an architectural
choice — expert divisibility, layout), shrink/grow the "data" axis to the
largest size the surviving chip count supports, and require the global
batch to stay divisible (the data pipeline reshards by pure function of
step, so no data is lost or duplicated).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pods: int
    dropped_chips: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


def plan_reshard(alive_chips: int, model: int = 16, pods: int = 1,
                 global_batch: int = 256,
                 min_data: int = 1) -> Optional[ElasticPlan]:
    """Largest (pods, data, model) mesh that fits the surviving chips."""
    per_pod = alive_chips // pods
    data = per_pod // model
    while data >= min_data:
        if data * model * pods <= alive_chips and global_batch % (data * pods) == 0:
            return ElasticPlan(data=data, model=model, pods=pods,
                               dropped_chips=alive_chips - data * model * pods)
        data -= 1
    return None
