from .fault_tolerance import (FailureDetector, StragglerMonitor, TrainSupervisor)
from .elastic import ElasticPlan, plan_reshard

__all__ = ["FailureDetector", "StragglerMonitor", "TrainSupervisor",
           "ElasticPlan", "plan_reshard"]
