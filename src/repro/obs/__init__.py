"""Event-sourced tracing and metrics for the collaborative serving stack.

The paper's argument is a latency decomposition — where each decode
step's milliseconds go (cache hit vs. miss-fetch vs. CPU lane) — so the
serving stack records *timelines*, not just end-of-run counters:

* ``trace``   — ``TraceRecorder``: a preallocated ring buffer of spans,
  instants and counter samples on the monotonic clock, plus the
  ``NULL_RECORDER`` no-op twin used when tracing is off.
* ``metrics`` — ``LogHistogram``: streaming log-bucket histograms that
  yield p50/p95/p99 for TTFT, TPOT and admission stall without storing
  raw samples.
* ``export``  — Chrome trace-event JSON (loadable in Perfetto /
  ``chrome://tracing``) with one track per request, per slot and per
  dispatch lane, and a structural validator CI runs on the artifact.

Drain-point rule (enforced by reprolint RL007): emission calls —
``complete`` / ``instant`` / ``counter`` / ``span`` — are only legal at
the scheduler's sanctioned drain points, i.e. inside ``_obs_*`` helpers
called AFTER the per-tick token drain. Device-side stages are timed by
bracketing the jitted calls at the drain, never by syncing inside them;
nothing inside the jitted/pure_callback graph may emit.
"""
from .metrics import LogHistogram
from .trace import (NULL_RECORDER, NoopRecorder, TraceEvent, TraceRecorder,
                    now_ns)
from .export import (chrome_trace, validate_chrome_trace,
                     write_chrome_trace)

__all__ = [
    "LogHistogram",
    "NULL_RECORDER",
    "NoopRecorder",
    "TraceEvent",
    "TraceRecorder",
    "now_ns",
    "chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
]
