"""Chrome trace-event JSON export and structural validation.

``chrome_trace`` converts a :class:`~repro.obs.trace.TraceRecorder`'s
ring into the Chrome trace-event format (the JSON-array-of-events
dialect wrapped in ``{"traceEvents": [...]}``) loadable in Perfetto or
``chrome://tracing``. Every track string becomes its own named thread
under one process, so requests (``req:N``), slots (``slot:N``) and
dispatch lanes (``lane:*``) render as parallel swimlanes; timestamps
are rebased to the recorder's epoch and expressed in microseconds as
the format requires.

``validate_chrome_trace`` is the structural checker CI runs on the
served ``TRACE_smoke.json`` artifact (``python -m repro.obs.export
PATH``): phase/field invariants per event, monotone non-negative
durations, and thread-name metadata covering every referenced track.
``lifecycle_coverage`` additionally maps each request track to the
lifecycle span names present, which the acceptance test uses to prove
every request's queued/prefill/decode phases made it into the trace.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Set

from .trace import TraceEvent

PID = 1
# the span names a complete request lifecycle must produce (cancelled
# requests legitimately miss later phases)
LIFECYCLE_SPANS = ("queued", "prefill", "decode")


def _tid_map(events: Iterable[TraceEvent]) -> Dict[str, int]:
    """Stable track → tid assignment: scheduler first, then lanes,
    slots and requests in sorted order so Perfetto's track list reads
    top-down the way the stack does."""
    tracks: Set[str] = {ev.track for ev in events}

    def rank(track: str):
        for i, prefix in enumerate(("sched", "engine", "lane:", "slot:",
                                    "req:")):
            if track.startswith(prefix):
                # numeric suffixes sort numerically (req:2 before req:10)
                tail = track.split(":", 1)[-1]
                num = int(tail) if tail.isdigit() else -1
                return (i, num, track)
        return (99, -1, track)

    return {t: tid for tid, t in enumerate(sorted(tracks, key=rank), 1)}


def chrome_trace(recorder) -> Dict[str, Any]:
    """Render a recorder (or anything with ``events()`` and ``t0_ns``)
    as a Chrome trace-event JSON object."""
    events = recorder.events()
    tids = _tid_map(events)
    out: List[Dict[str, Any]] = []
    for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        out.append({"ph": "M", "name": "thread_name", "pid": PID,
                    "tid": tid, "args": {"name": track}})
    epoch = recorder.t0_ns
    for ev in events:
        ts_us = (ev.ts_ns - epoch) / 1000.0
        rec: Dict[str, Any] = {"ph": ev.kind, "name": ev.name,
                               "pid": PID, "tid": tids[ev.track],
                               "ts": ts_us}
        if ev.kind == "X":
            rec["dur"] = ev.dur_ns / 1000.0
        if ev.kind == "i":
            rec["s"] = "t"  # thread-scoped instant
        if ev.args is not None:
            rec["args"] = dict(ev.args)
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"dropped_events": recorder.dropped}}


def write_chrome_trace(recorder, path: str) -> Dict[str, Any]:
    data = chrome_trace(recorder)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    return data


def validate_chrome_trace(data: Dict[str, Any]) -> List[str]:
    """Return a list of structural problems (empty == valid)."""
    problems: List[str] = []
    if not isinstance(data, dict) or "traceEvents" not in data:
        return ["top level must be an object with a traceEvents list"]
    events = data["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents must be a list"]

    named_tids: Set[int] = set()
    used_tids: Set[int] = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("M", "X", "i", "C"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing name")
        if ev.get("pid") != PID or not isinstance(ev.get("tid"), int):
            problems.append(f"event {i}: bad pid/tid")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_tids.add(ev["tid"])
            continue
        used_tids.add(ev["tid"])
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: complete span without "
                                f"non-negative dur")
        if ph == "C" and "value" not in ev.get("args", {}):
            problems.append(f"event {i}: counter without args.value")
    for tid in sorted(used_tids - named_tids):
        problems.append(f"tid {tid} has events but no thread_name "
                        f"metadata")
    return problems


def lifecycle_coverage(data: Dict[str, Any]) -> Dict[str, Set[str]]:
    """Map each request track name to the lifecycle span names it
    recorded. Requires valid thread-name metadata."""
    names_by_tid = {ev["tid"]: ev["args"]["name"]
                    for ev in data.get("traceEvents", [])
                    if ev.get("ph") == "M"
                    and ev.get("name") == "thread_name"}
    cover: Dict[str, Set[str]] = {}
    for ev in data.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        track = names_by_tid.get(ev.get("tid"), "")
        if track.startswith("req:"):
            cover.setdefault(track, set()).add(ev["name"])
    return cover


def main(argv=None) -> int:
    """CI entry point: ``python -m repro.obs.export TRACE.json``."""
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON artifact")
    ap.add_argument("path")
    ap.add_argument("--require-lifecycle", action="store_true",
                    help="additionally require every req:* track to "
                         "carry the full queued/prefill/decode span set")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        data = json.load(f)
    problems = validate_chrome_trace(data)
    cover = lifecycle_coverage(data)
    if args.require_lifecycle:
        if not cover:
            problems.append("no req:* tracks in trace")
        for track, spans in sorted(cover.items()):
            missing = [s for s in LIFECYCLE_SPANS if s not in spans]
            if missing:
                problems.append(f"{track}: missing lifecycle span(s) "
                                f"{', '.join(missing)}")
    for p in problems:
        print(f"trace-validate: {p}", file=sys.stderr)
    n_events = len([e for e in data.get("traceEvents", [])
                    if isinstance(e, dict) and e.get("ph") != "M"])
    print(f"trace-validate: {args.path}: {n_events} event(s), "
          f"{len(cover)} request track(s)"
          + (": FAIL" if problems else ": OK"))
    return 1 if problems else 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
