"""Low-overhead span/event recorder for the serving hot path.

Design constraints, in order:

1. **Never perturb the thing being measured.** Timestamps come from
   ``time.perf_counter_ns`` (monotonic, ns resolution); recording an
   event is one tuple construction and one ring-buffer store — no
   allocation growth, no locks, no I/O. When tracing is off the
   scheduler holds ``NULL_RECORDER`` whose methods are empty, so the
   instrumented code path is identical either way (the bit-identity
   tests pin this).
2. **Bounded memory.** Events land in a preallocated ring buffer;
   once full, the oldest events are overwritten (``dropped`` counts
   them). A trace of a million-token run costs the same memory as a
   ten-token run.
3. **Retroactive spans.** Hot code records ``t0 = now_ns()`` as a plain
   local (reading the clock is not emission) and emits the whole span
   later at a sanctioned drain point via ``complete(track, name, t0,
   t1)``. This avoids begin/end pairing state in the hot loop and keeps
   every emission call at the drain, where reprolint RL007 can see it.

Tracks are plain strings — ``req:3``, ``slot:0``, ``lane:cpu``,
``sched`` — and become Perfetto threads in the Chrome export.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterator, List, NamedTuple, Optional


def now_ns() -> int:
    """Monotonic nanosecond clock all trace timestamps come from."""
    return time.perf_counter_ns()


class TraceEvent(NamedTuple):
    """One recorded happening. ``kind`` is a Chrome trace-event phase:
    ``"X"`` complete span (``dur_ns`` set), ``"i"`` instant, ``"C"``
    counter sample (scalar in ``args["value"]``)."""
    kind: str
    track: str
    name: str
    ts_ns: int
    dur_ns: int
    args: Optional[Dict[str, Any]]


class TraceRecorder:
    """Preallocated ring buffer of :class:`TraceEvent`.

    The emission methods (``complete`` / ``instant`` / ``counter`` /
    ``span``) are subject to the drain-point rule: reachable-from-hot-
    path call sites outside ``_obs_*`` helpers are RL007 findings.
    """

    def __init__(self, capacity: int = 65536):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._ring: List[Optional[TraceEvent]] = [None] * self.capacity
        self._head = 0          # next write index
        self._count = 0         # events currently held (<= capacity)
        self.dropped = 0        # events overwritten after wraparound
        self.t0_ns = now_ns()   # trace epoch: export rebases onto this

    @property
    def enabled(self) -> bool:
        return True

    def __len__(self) -> int:
        return self._count

    def _push(self, ev: TraceEvent) -> None:
        if self._count == self.capacity:
            self.dropped += 1
        else:
            self._count += 1
        self._ring[self._head] = ev
        self._head = (self._head + 1) % self.capacity

    # -- emission API (drain points only; see RL007) ---------------------

    def complete(self, track: str, name: str, t0_ns: int, t1_ns: int,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record a span retroactively from two clock readings."""
        self._push(TraceEvent("X", track, name, t0_ns,
                              max(0, t1_ns - t0_ns), args))

    def instant(self, track: str, name: str,
                args: Optional[Dict[str, Any]] = None,
                ts_ns: Optional[int] = None) -> None:
        """Record a point-in-time happening (eviction, prefix hit, ...)."""
        self._push(TraceEvent("i", track, name,
                              now_ns() if ts_ns is None else ts_ns,
                              0, args))

    def counter(self, track: str, name: str, value: float,
                ts_ns: Optional[int] = None) -> None:
        """Sample a gauge (pages in use, queue depth, ...)."""
        self._push(TraceEvent("C", track, name,
                              now_ns() if ts_ns is None else ts_ns,
                              0, {"value": value}))

    def span(self, track: str, name: str,
             args: Optional[Dict[str, Any]] = None) -> "_Span":
        """Context manager emitting one complete span on exit. For
        host-side scopes outside the hot loop (e.g. ``serve.py`` run
        phases); hot code uses ``complete`` at the drain instead."""
        return _Span(self, track, name, args)

    # -- reading ---------------------------------------------------------

    def events(self) -> List[TraceEvent]:
        """Events in emission order (oldest surviving first)."""
        if self._count < self.capacity:
            out = self._ring[: self._count]
        else:
            out = self._ring[self._head:] + self._ring[: self._head]
        return [ev for ev in out if ev is not None]

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events())


class _Span:
    __slots__ = ("_rec", "_track", "_name", "_args", "_t0")

    def __init__(self, rec: TraceRecorder, track: str, name: str,
                 args: Optional[Dict[str, Any]]):
        self._rec = rec
        self._track = track
        self._name = name
        self._args = args
        self._t0 = 0

    def __enter__(self) -> "_Span":
        self._t0 = now_ns()
        return self

    def __exit__(self, *exc) -> None:
        self._rec.complete(self._track, self._name, self._t0, now_ns(),
                           self._args)


class NoopRecorder:
    """Drop-in stand-in when tracing is off: every emission is a no-op,
    so instrumented code never branches on whether tracing is enabled.
    Clock reads still work (``now_ns`` is module-level), and the
    overhead benchmark pins traced-vs-noop throughput within 5%."""

    capacity = 0
    dropped = 0
    t0_ns = 0

    @property
    def enabled(self) -> bool:
        return False

    def __len__(self) -> int:
        return 0

    def complete(self, track, name, t0_ns, t1_ns, args=None) -> None:
        pass

    def instant(self, track, name, args=None, ts_ns=None) -> None:
        pass

    def counter(self, track, name, value, ts_ns=None) -> None:
        pass

    def span(self, track, name, args=None) -> "_NoopSpan":
        return _NOOP_SPAN

    def events(self) -> List[TraceEvent]:
        return []

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(())


class _NoopSpan:
    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP_SPAN = _NoopSpan()
NULL_RECORDER = NoopRecorder()
