"""Streaming log-bucket histograms for latency percentiles.

``RunStats`` reports p50/p95/p99 for TTFT, TPOT and admission stall.
Storing raw samples would grow without bound under the ROADMAP's
traffic-scale load harness, so samples land in geometric buckets
instead: bucket ``i`` covers ``[GROWTH**i, GROWTH**(i+1))``, giving a
bounded relative error of ``GROWTH - 1`` (~8% half-width, i.e. ≤~4%
from a bucket's geometric midpoint) at any scale from microseconds to
minutes. ``observe`` is two integer ops and an array increment — cheap
enough to run unconditionally, which is why the histograms feed
``RunStats`` even when the trace recorder is the no-op one.

The percentile estimator interpolates within the winning bucket's
span, and the parity test pins it against ``np.percentile`` on the raw
samples to within the bucket error bound.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

GROWTH = 1.08
_LOG_GROWTH = math.log(GROWTH)


class LogHistogram:
    """Fixed-growth log-bucket histogram over positive samples.

    Buckets are allocated lazily in a dict keyed by bucket index, so an
    idle histogram costs nothing and a busy one holds ~#decades/log10
    (GROWTH) entries (~30 per decade at 1.08).
    """

    __slots__ = ("_buckets", "count", "total", "min", "max")

    def __init__(self) -> None:
        self._buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        """Record one sample. Non-positive samples clamp to the lowest
        bucket (duration math can round to 0 at ns resolution)."""
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        idx = (math.floor(math.log(value) / _LOG_GROWTH)
               if value > 0.0 else -(10 ** 9))
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0 <= q <= 100). Interpolates
        linearly inside the winning bucket; exact at the recorded min
        and max endpoints."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile out of range: {q}")
        if self.count == 0:
            return 0.0
        # rank in [0, count-1], same convention as np.percentile linear
        rank = q / 100.0 * (self.count - 1)
        if rank >= self.count - 1:
            return self.max
        seen = 0
        for idx in sorted(self._buckets):
            n = self._buckets[idx]
            if seen + n > rank:
                lo = GROWTH ** idx if idx > -(10 ** 9) else 0.0
                hi = GROWTH ** (idx + 1) if idx > -(10 ** 9) else 0.0
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / n
                return lo + frac * (hi - lo)
            seen += n
        return self.max

    def percentiles(self, qs: Sequence[float] = (50.0, 95.0, 99.0)
                    ) -> List[float]:
        return [self.percentile(q) for q in qs]

    def to_json(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.percentile(50.0),
            "p95": self.percentile(95.0),
            "p99": self.percentile(99.0),
        }
