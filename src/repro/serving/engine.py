"""Collaborative serving engine: the paper's workflow, runnable end-to-end.

Serves an MoE LM with the expert weights split across the two tiers of
repro.core.collaborative: attention/router/norm weights plus an N-index
M-way expert cache resident in the fast tier; the full expert table in the
host tier. Every decode step performs the paper's (1) cache check,
(2) grouped tiered execution (gmm kernels), (3) asynchronous post-fetch,
all inside one jitted step function whose cache state threads functionally
(donated buffers).

The engine is *batch-capable*: one decode step serves up to
``EngineConfig.max_batch`` concurrent requests, each at its own sequence
position (per-slot KV positions), all sharing ONE expert cache — the
paper's single-request workflow generalized to continuous batching. The
request lifecycle (admission, retirement, queueing) lives in
repro.serving.scheduler; the engine exposes the batch-state primitives it
needs: ``init_slots`` / ``prefill_request`` / ``write_slot`` /
``decode_batch``.

The engine exposes the same counters the paper reports: per-layer hit
rates, host-computed assignment counts, fetch volume — consumed by the
fig5/fig6 benchmarks in live-model mode and by examples/serve_collaborative.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CacheConfig, ModelConfig
from repro.core import collaborative as collab
from repro.models import transformer
from repro.models.layers import rmsnorm
from repro.models.moe import route

Params = Dict[str, Any]


@dataclass(frozen=True)
class EngineConfig:
    cache: CacheConfig
    max_batch: int = 1            # concurrent request slots (T)
    capacity: int = 512           # KV capacity
    greedy: bool = True


class CollaborativeEngine:
    """Single-host engine (the paper's consumer scenario, batched).

    Only homogeneous decoder-only MoE archs (every layer MoE) are accepted
    here — matching the paper's Mixtral/Phi targets. The generic serving
    path without the cache lives in launch/serve.py for all archs.
    """

    def __init__(self, cfg: ModelConfig, params: Params, ecfg: EngineConfig,
                 key=None):
        assert cfg.moe is not None and cfg.moe_every == 1 and not cfg.is_encdec
        slots, G, R = transformer.build_slots(cfg)
        assert len(slots) == 1 and R == 0, "engine expects homogeneous stacks"
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        key = key if key is not None else jax.random.PRNGKey(0)

        # Split expert weights out of the param tree into the two tiers.
        # The host tier is read-only and aliases the param tree — it is
        # deliberately NOT donated (donating it would delete the params'
        # buffers under prefill's feet); only the mutable fast-tier state
        # (slot buffers + tags/age) threads through with donation.
        moe_p = params["scan"]["s0"]["moe"]
        tiers = collab.init_tiers(
            moe_p["w1"], moe_p["w3"], moe_p["w2"], ecfg.cache,
            num_experts=cfg.moe.num_experts, key=key)
        self._host = (tiers.host_w1, tiers.host_w3, tiers.host_w2)
        self.fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)
        self._decode = jax.jit(self._decode_step, donate_argnums=(1, 2))
        self._write = jax.jit(self._write_slot, donate_argnums=(0,))
        self.stats = {"hits": 0, "accesses": 0, "host_assignments": 0,
                      "fetched_experts": 0, "tokens": 0, "steps": 0}

    def _tiers(self, fast) -> collab.ExpertTiers:
        s1, s3, s2, state = fast
        h1, h3, h2 = self._host
        return collab.ExpertTiers(host_w1=h1, host_w3=h3, host_w2=h2,
                                  slot_w1=s1, slot_w3=s3, slot_w2=s2,
                                  state=state)

    # -- one decode step with collaborative MoE ---------------------------
    def _decode_step(self, tokens, state, fast, active):
        """tokens [T, 1]; state['pos'] [T] per-slot positions; active [T]
        bool — padded slots neither touch the shared cache nor the stats."""
        cfg = self.cfg
        params = self.params
        tiers = self._tiers(fast)
        x = transformer._embed_inputs(params, {"tokens": tokens}, cfg)
        pos = state["pos"]
        slots, G, _ = transformer.build_slots(cfg)
        slot = slots[0]

        def body(carry, xs):
            x, tiers, layer = carry
            lp, st = xs["params"], xs["state"]
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            from repro.models import attention as attn
            o, new_st = attn.decode_attention(lp["attn"], h, st, pos, cfg,
                                              slot.window)
            x = x + o
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            _, top_i, top_w = route(lp["moe"]["router"],
                                    h2[:, 0].astype(jnp.float32),
                                    cfg.moe.top_k)
            y, tiers, stats = collab.collaborative_moe(
                tiers, layer, h2[:, 0], top_i, top_w, self.ecfg.cache,
                active=active)
            x = x + y[:, None].astype(x.dtype)
            return (x, tiers, layer + 1), (new_st, stats)

        xs = {"params": params["scan"], "state": state["scan"]}
        (x, tiers, _), (new_scan, stats) = jax.lax.scan(
            body, (x, tiers, jnp.zeros((), jnp.int32)),
            ({"params": xs["params"]["s0"], "state": xs["state"]["s0"]}))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = transformer.lm_logits(params, x, cfg)
        new_state = {"scan": {"s0": new_scan},
                     "pos": pos + active.astype(jnp.int32)}
        new_fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)
        return logits, new_state, new_fast, stats

    # -- batch-state primitives for the scheduler -------------------------
    def init_slots(self) -> Params:
        """Empty decode state for max_batch request slots."""
        state = transformer.init_state(self.cfg, self.ecfg.max_batch,
                                       self.ecfg.capacity)
        state["pos"] = jnp.zeros((self.ecfg.max_batch,), jnp.int32)
        return state

    @staticmethod
    def _write_slot(batch_state, one_state, slot):
        """Scatter a single prefilled request's state into batch slot
        ``slot`` (scan leaves are [G, B, ...]; the incoming state is B=1)."""
        new_scan = jax.tree.map(lambda full, one: full.at[:, slot].set(one[:, 0]),
                                batch_state["scan"], one_state["scan"])
        pos = batch_state["pos"].at[slot].set(one_state["pos"])
        return {"scan": new_scan, "pos": pos}

    def write_slot(self, batch_state: Params, one_state: Params,
                   slot: int) -> Params:
        return self._write(batch_state, one_state, jnp.asarray(slot, jnp.int32))

    def prefill_request(self, prompt: np.ndarray) -> Tuple[int, Params]:
        """Prefill one request; returns (first greedy token, decode state
        with pos=len(prompt), B=1)."""
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        P = prompt.shape[1]
        assert 1 <= P < self.ecfg.capacity, (P, self.ecfg.capacity)
        logits, state = self.prefill(jnp.asarray(prompt))
        tok = int(np.argmax(np.asarray(logits[0, P - 1])))
        return tok, state

    def decode_batch(self, tokens, state: Params, active
                     ) -> Tuple[jax.Array, Params]:
        """One padded decode step for the whole slot batch. tokens [T, 1];
        active [T] bool. Updates the shared expert-cache tiers and the
        engine counters (padded rows excluded); returns (logits, state)."""
        active = jnp.asarray(active, bool)
        logits, state, self.fast, stats = self._decode(
            jnp.asarray(tokens, jnp.int32), state, self.fast, active)
        self._accumulate(stats, int(jax.device_get(active.sum())))
        return logits, state

    def _accumulate(self, stats, n_active: int) -> None:
        for k in ("hits", "accesses", "fetched_experts"):
            self.stats[k] += int(np.asarray(stats[k]).sum())
        self.stats["host_assignments"] += int(
            np.asarray(stats["host_flops_assignments"]).sum())
        self.stats["tokens"] += n_active
        self.stats["steps"] += 1

    # -- static-batch convenience path ------------------------------------
    def prefill(self, tokens: jax.Array) -> Tuple[jax.Array, Params]:
        """Standard prefill (tiers untouched: prefill is compute-bound and
        runs from the host tier on real hardware; cache serves decode)."""
        from repro.models import model as model_lib
        B, P = tokens.shape
        cap = self.ecfg.capacity
        pad = jnp.zeros((B, cap - P), tokens.dtype)
        logits, state = model_lib.prefill(
            self.params, {"tokens": jnp.concatenate([tokens, pad], 1)},
            self.cfg)
        state["pos"] = jnp.asarray(P, jnp.int32)
        return logits, state

    def generate(self, prompt: np.ndarray, steps: int,
                 key=None) -> Tuple[np.ndarray, Dict[str, float]]:
        """Static-batch generation: all prompt rows start and stop together
        (the scheduler path interleaves requests instead)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        B, P = prompt.shape
        logits, state = self.prefill(jnp.asarray(prompt))
        state["pos"] = jnp.full((B,), P, jnp.int32)
        tok = jnp.argmax(logits[:, P - 1], -1)[:, None].astype(jnp.int32)
        active = jnp.ones((B,), bool)
        out = [np.asarray(tok)]
        for _ in range(steps - 1):
            logits, state, self.fast, stats = self._decode(tok, state,
                                                           self.fast, active)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok))
            self._accumulate(stats, B)
        hit_rate = self.stats["hits"] / max(self.stats["accesses"], 1)
        return np.concatenate(out, 1), {**self.stats, "hit_rate": hit_rate}
