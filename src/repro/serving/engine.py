"""Collaborative serving engine: the paper's workflow, runnable end-to-end.

Serves an MoE LM with the expert weights split across the two tiers of
repro.core.collaborative: attention/router/norm weights plus an N-index
M-way expert cache resident in the fast tier; the full expert table in the
host tier. Every decode step runs the staged collaborative pipeline —
probe (cache check + grouping), execute (grouped tiered gmm), commit
(state update + async post-fetch) — all inside one jitted step function
whose cache state threads functionally (donated buffers).

With ``EngineConfig.prefetch`` the decode scan becomes a *software
pipeline* with cross-layer speculative prefetch (DAOP / Pre-gated style):
after layer *l*'s FFN, layer *l+1*'s router runs on layer *l*'s output
hidden state and the predicted top-k experts are reserved in the cache and
streamed in while layer *l+1*'s attention computes
(``prefetch_min_prob`` confidence-gates the reservations on router
probability). Prefetch changes residency and counters, never numerics.

With ``EngineConfig.host_compute`` the execute stage becomes the hybrid
CPU/GPU dispatcher of :mod:`repro.hostexec`: cache-miss expert groups the
calibrated cost model favors ship their activations to a multithreaded
host executor instead of paying the weight fetch, counted in the
``cpu_expert_calls`` / ``cpu_tokens`` stats channel. Cache bookkeeping is
identical on every lane; the in-graph ``host_backend="jax"`` keeps tokens
bit-identical to the all-GPU path.

Prefill is *request-shaped* and resumable: :meth:`start_prefill` runs the
one shared prefill trace (the backbone's prefill mode with the routing
trace emitted — there is no second prefill implementation) and returns a
:class:`PrefillTicket`; :meth:`advance_prefill` replays the prompt's
routing trace through the staged probe → execute → commit pipeline chunk
by chunk, so the prompt's own expert-routing warms the shared cache
before the first decode step (the paper's long-prompt scenario) — all at
once on the synchronous path (:meth:`prefill_chunked`), or one
``EngineConfig.admit_chunks_per_tick`` slice per scheduler tick on the
overlapped-admission path. The hidden states, KV cache and first-token
logits come from the trace in every mode, so warming — however paced —
changes cache residency and the ``prefill_*`` stat channel, never the
generated tokens.

With ``EngineConfig.prefill_segment`` the admission-tick forward itself
goes incremental: :meth:`start_prefill` only tokenizes and (paged)
allocates pages, and each :meth:`advance_prefill` runs ONE C-token
prompt segment through the backbone's segment mode — the segment
attends to the request's KV so far at its absolute offset, appends its
own KV (dense slot or pool pages), and its freshly emitted routing
trace warms the cache inside the same jitted step (the forward IS the
trace source; no separate replay pass). First-token logits emerge at
the last segment, so the per-tick admission cost drops from O(prompt)
to O(segment). Under paged KV a prefix-index hit skips the shared
span's forward AND warm outright — only the unshared suffix is ever
forwarded — counted in ``prefix_tokens_skipped``. Tokens stay
bit-identical to the one-shot forward: a segment row's flash-attention
chunk decomposition over the key axis is independent of how the query
axis is sliced, and the MoE combine is row-order invariant.

The engine is *batch-capable*: one decode step serves up to
``EngineConfig.max_batch`` concurrent requests, each at its own sequence
position (per-slot KV positions), all sharing ONE expert cache. The
request lifecycle (admission, streaming, retirement) lives in
repro.serving.scheduler; the engine exposes the batch-state primitives it
needs: ``init_slots`` / ``prefill_request`` / ``write_slot`` /
``decode_batch`` / ``select_tokens``. Sampling is per-request: there is no
engine-wide greedy/temperature knob — ``select_tokens`` is a vectorized
per-slot sampler driven by a ``[T]`` :class:`SamplingParams` batch.

Counters are typed: :attr:`stats` snapshots an immutable
:class:`~repro.serving.stats.EngineStats` with separate demand, prefetch
and prefill channels plus per-layer series, consumed by the fig5/fig6
benchmarks in live-model mode, benchmarks/decode_prefetch, and the
examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CacheConfig, ModelConfig
from repro.core import collaborative as collab
from repro.obs.trace import NULL_RECORDER, now_ns
from repro.models import transformer
from repro.models import attention as attn
from repro.models.layers import rmsnorm
from repro.models.moe import route
from .kv_pool import KVPagePool, PageTable
from .sampling import GREEDY, SamplingParams, batch_arrays, fold_keys, \
    sample_tokens
from .stats import EngineStats

Params = Dict[str, Any]


@dataclass(frozen=True)
class EngineConfig:
    """Engine geometry and pipeline toggles.

    Sampling is deliberately NOT here: it is a per-request property
    (:class:`~repro.serving.sampling.SamplingParams` on ``Request``), not
    an engine property.
    """
    cache: CacheConfig
    max_batch: int = 1            # concurrent request slots (T)
    capacity: int = 512           # KV capacity
    prefetch: bool = False        # cross-layer speculative expert prefetch
    prefetch_min_prob: float = 0.0  # confidence gate on reservations
    prefill_chunk: int = 8        # cache-warming prefill chunk (0 = bypass)
    # overlapped admission: a newly admitted request advances its
    # cache-warming replay by at most this many chunks per scheduler tick
    # BETWEEN decode steps (its slot sits in the PREFILLING phase until
    # the replay drains), so established requests keep decoding while the
    # newcomer warms. 0 = synchronous admission (the whole replay runs on
    # the admission tick — head-of-line blocking on long prompts).
    admit_chunks_per_tick: int = 0
    # segment-streamed prefill: forward the prompt in this-many-token
    # segments, one advance_prefill call each, instead of one full-prompt
    # forward on the admission tick (0 = one-shot). Each segment appends
    # its own KV and warms the expert cache from its own routing trace in
    # the same jitted step; prefill_chunk degrades to an on/off warming
    # toggle here (the warm granularity IS the segment).
    prefill_segment: int = 0
    # live host execution (repro.hostexec): compute cache-miss experts on
    # the CPU when the cost model favors it over the weight fetch
    host_compute: bool = False
    host_threads: int = 8         # executor pool / cost-model thread count
    host_backend: str = "jax"     # "jax" (in-graph, bit-exact) | "callback"
    # batch small same-step CPU-miss groups (<= this many valid tokens)
    # into one stacked numpy matmul instead of one pool task each
    host_fuse_small: int = 4
    # paged KV: one global [num_pages, page_size, ...] pool per layer
    # replaces the dense [max_batch, capacity, ...] per-slot cache;
    # requests hold refcounted pages through per-slot page tables, and
    # admission reuses an existing request's pages for a shared prompt
    # prefix (copy-on-write on divergence). Bit-identical tokens to the
    # dense cache by construction.
    kv_paged: bool = False
    page_size: int = 16           # tokens per KV page
    kv_pages: Optional[int] = None  # pool size (None = dense-equivalent)
    # paged KV: when a retiring request drops the last reference on
    # prefix-indexed pages, park up to this many in the pool's eviction
    # LRU instead of freeing them — a later admission with the same
    # prompt prefix adopts them back (0 = free eagerly)
    prefix_keep_pages: int = 0
    # rank speculative-prefetch reservations by cross-batch vote count so
    # experts many rows predict claim cache ways first
    prefetch_rank_votes: bool = True

    def __post_init__(self):
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0, got {self.prefill_chunk}")
        if self.admit_chunks_per_tick < 0:
            raise ValueError(
                f"admit_chunks_per_tick must be >= 0, got "
                f"{self.admit_chunks_per_tick}")
        if self.prefill_segment < 0:
            raise ValueError(
                f"prefill_segment must be >= 0, got {self.prefill_segment}")
        if self.prefix_keep_pages < 0:
            raise ValueError(
                f"prefix_keep_pages must be >= 0, got "
                f"{self.prefix_keep_pages}")
        if self.prefix_keep_pages > 0 and not self.kv_paged:
            raise ValueError(
                "prefix_keep_pages retains pool pages: it requires kv_paged")
        if not 0.0 <= self.prefetch_min_prob < 1.0:
            raise ValueError(
                f"prefetch_min_prob must be in [0, 1), got "
                f"{self.prefetch_min_prob}")
        if self.host_threads < 1:
            raise ValueError(
                f"host_threads must be >= 1, got {self.host_threads}")
        if self.host_backend not in ("jax", "callback"):
            raise ValueError(
                f"host_backend must be 'jax' or 'callback', got "
                f"{self.host_backend!r}")
        if self.host_fuse_small < 0:
            raise ValueError(
                f"host_fuse_small must be >= 0, got {self.host_fuse_small}")
        if self.page_size < 1:
            raise ValueError(
                f"page_size must be >= 1, got {self.page_size}")
        if self.kv_paged:
            if self.capacity % self.page_size != 0:
                raise ValueError(
                    f"paged KV needs capacity ({self.capacity}) divisible "
                    f"by page_size ({self.page_size})")
            min_pages = self.capacity // self.page_size
            if self.kv_pages is not None and self.kv_pages < min_pages:
                raise ValueError(
                    f"kv_pages ({self.kv_pages}) < capacity/page_size "
                    f"({min_pages}): one full-capacity request could "
                    f"never hold its pages")


@dataclass(eq=False)
class PrefillTicket:
    """Resumable cache-warming prefill for ONE request (identity
    semantics: a generated ``__eq__`` over the held device arrays would
    raise, like Request's ndarray prompt).

    Produced by :meth:`CollaborativeEngine.start_prefill`. On the
    trace-replay path the shared prefill trace already ran (so ``logits``
    and ``state`` are final — sampling the first token never waits on
    warming) and the ticket holds the routing trace padded to whole
    chunks plus the replay cursor. On the segment-streamed path
    (``seg > 0``) NO forward has run yet: ``logits`` stays ``None`` — the
    scheduler's discriminator for deferred first-token sampling — and the
    cursor counts forwarded segments instead; ``logits`` lands with the
    last segment. :meth:`CollaborativeEngine.advance_prefill` drives
    either — the scheduler interleaves one ticket advance per tick
    between decode steps so established requests keep decoding while the
    newcomer warms."""
    prompt_len: int
    chunk: int                    # warm-chunk token count (0 = bypass)
    n_chunks: int
    logits: Optional[jax.Array] = None  # [1, 1, V] first-token logits
    state: Optional[Params] = None      # decode state, pos = prompt_len
    top_i: Optional[jax.Array] = None   # [L, n_chunks*chunk, K]
    top_w: Optional[jax.Array] = None
    h2: Optional[jax.Array] = None      # [L, n_chunks*chunk, D]
    cursor: int = 0               # chunks already replayed
    # segment-streamed prefill (seg > 0): segment token count, the first
    # absolute position the forward starts at (past a shared prefix),
    # the prompt padded to whole segments [1, fwd_start + n_chunks*seg],
    # whether the KV streams straight into the pool pages (paged) and
    # whether each segment also warms the expert cache from its trace
    seg: int = 0
    fwd_start: int = 0
    tokens: Optional[np.ndarray] = None
    page_ids: Optional[np.ndarray] = None  # [max_pages], num_pages-padded
    kv_streamed: bool = False
    warm: bool = True
    # paged KV: the request's page table (allocated at start_prefill,
    # bound to a slot by bind_slot), its prompt (for the pool's prefix
    # index) and the token count served from a shared prefix — those
    # chunks' warm replay is skipped (cursor starts past them: the
    # prefix's original admission already warmed the cache with the
    # identical routing)
    table: Optional[PageTable] = None
    prompt: Optional[np.ndarray] = None
    shared_tokens: int = 0

    @property
    def done(self) -> bool:
        return self.cursor >= self.n_chunks

    @property
    def remaining(self) -> int:
        return self.n_chunks - self.cursor


def _one_prompt(prompt) -> np.ndarray:
    """Normalize a single request's prompt to [1, P]; reject batches (a
    [B, P] batch would otherwise silently concatenate into one prompt)."""
    prompt = np.asarray(prompt, np.int32)
    if prompt.ndim == 2 and prompt.shape[0] == 1:
        prompt = prompt[0]
    if prompt.ndim != 1:
        raise ValueError(
            f"per-request prefill serves ONE prompt: expected shape [P] or "
            f"[1, P], got {prompt.shape}; use engine.prefill / generate "
            f"for static batches")
    return prompt.reshape(1, -1)


class CollaborativeEngine:
    """Single-host engine (the paper's consumer scenario, batched).

    Only homogeneous decoder-only MoE archs (every layer MoE) are accepted
    here — matching the paper's Mixtral/Phi targets. The generic serving
    path without the cache lives in launch/serve.py for all archs.
    """

    def __init__(self, cfg: ModelConfig, params: Params, ecfg: EngineConfig,
                 key=None, recorder=None):
        assert cfg.moe is not None and cfg.moe_every == 1 and not cfg.is_encdec
        slots, G, R = transformer.build_slots(cfg)
        assert len(slots) == 1 and R == 0, "engine expects homogeneous stacks"
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        # trace recorder (repro.obs): the no-op twin when tracing is off,
        # so the instrumented path is identical either way. All emission
        # happens in the _obs_* drain helpers — never inside jitted code
        # or between a dispatch and its drain (reprolint RL007).
        self.obs = recorder if recorder is not None else NULL_RECORDER
        # last-seen cumulative pool/executor counters, so the drain
        # helpers can emit per-step deltas as instants
        self._obs_prev: Dict[str, int] = {}
        key = key if key is not None else jax.random.PRNGKey(0)

        # Split expert weights out of the param tree into the two tiers.
        # The host tier is read-only and aliases the param tree — it is
        # deliberately NOT donated (donating it would delete the params'
        # buffers under prefill's feet); only the mutable fast-tier state
        # (slot buffers + tags/age) threads through with donation.
        moe_p = params["scan"]["s0"]["moe"]
        tiers = collab.init_tiers(
            moe_p["w1"], moe_p["w3"], moe_p["w2"], ecfg.cache,
            num_experts=cfg.moe.num_experts, key=key)
        self._host = (tiers.host_w1, tiers.host_w3, tiers.host_w2)
        self.fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)

        # live host execution: cost-model split table + (callback backend)
        # the multithreaded numpy executor over the host expert table
        self.host_executor = None
        self.dispatch_policy = None
        self._dispatch_execute = None
        self._cpu_table = None
        if ecfg.host_compute:
            from repro import hostexec
            self._dispatch_execute = hostexec.dispatch_execute
            self.dispatch_policy = hostexec.HostDispatchPolicy(
                hostexec.timings_for(cfg.name), ecfg.host_threads)
            table = self.dispatch_policy.decision_table(
                ecfg.max_batch * cfg.moe.top_k)
            self._cpu_table = jnp.asarray(table)
            if ecfg.host_backend == "callback" and table.any():
                # an all-False table can never dispatch: skip the executor
                # so the step pays no per-layer host round-trip for nothing
                # (the in-graph path is the exact no-op)
                self.host_executor = hostexec.HostExpertExecutor(
                    moe_p["w1"], moe_p["w3"], moe_p["w2"],
                    threads=ecfg.host_threads,
                    fuse_small=ecfg.host_fuse_small)

        # paged KV geometry (kv_paged only): the pool and per-slot page
        # tables are host-side bookkeeping created by init_slots; the
        # device-side page pool rides the scan state exactly where the
        # dense cache did
        self.max_pages = ecfg.capacity // ecfg.page_size
        self.num_pages = (ecfg.kv_pages if ecfg.kv_pages is not None
                          else ecfg.max_batch * self.max_pages)
        self.kv_pool: Optional[KVPagePool] = None
        self._slot_tables = [None] * ecfg.max_batch
        self._slot_pages: Optional[np.ndarray] = None

        self._decode = jax.jit(self._decode_step, donate_argnums=(1, 2))
        self._write = jax.jit(self._write_slot, donate_argnums=(0,))
        self._write_paged = jax.jit(self._write_slot_paged,
                                    donate_argnums=(0,))
        self._cow = jax.jit(self._copy_page, donate_argnums=(0,))
        self._prefill = jax.jit(self._prefill_trace,
                                static_argnames=("want_trace",))
        self._warm = jax.jit(self._warm_chunk, donate_argnums=(0,))
        self._segment = jax.jit(self._segment_step, donate_argnums=(1, 2),
                                static_argnames=("warm",))
        L = cfg.num_layers
        self._counters = {
            "hits": 0, "accesses": 0, "host_assignments": 0,
            "fetched_experts": 0, "tokens": 0, "steps": 0,
            "prefetch_issued": 0, "prefetch_hits": 0, "prefetch_wasted": 0,
            "predicted": 0, "predicted_correct": 0,
            "prefill_hits": 0, "prefill_accesses": 0, "prefill_fetched": 0,
            "prefill_tokens": 0, "prefill_chunks": 0, "first_tokens": 0,
            "prefill_segments": 0, "prefix_tokens_skipped": 0,
            "cpu_expert_calls": 0, "cpu_tokens": 0, "miss_expert_groups": 0,
            "fused_groups": 0, "kv_pages_in_use": 0, "prefix_hits": 0,
            "cow_forks": 0, "prefix_pages_retained": 0}
        self._per_layer_hits = np.zeros(L, np.int64)
        self._per_layer_accesses = np.zeros(L, np.int64)

    # -- typed stats -------------------------------------------------------
    @property
    def stats(self) -> EngineStats:
        """Immutable snapshot of the engine counters (typed; derived rates
        and the per-layer hit-rate array live on EngineStats). The paged-KV
        channel reads the pool directly: ``kv_pages_in_use`` is a gauge,
        ``prefix_hits`` / ``cow_forks`` the pool's cumulative ledger."""
        c = dict(self._counters)
        if self.kv_pool is not None:
            c["kv_pages_in_use"] = self.kv_pool.pages_in_use
            c["prefix_hits"] = self.kv_pool.prefix_hits
            c["cow_forks"] = self.kv_pool.cow_forks
            c["prefix_pages_retained"] = self.kv_pool.prefix_pages_retained
        if self.host_executor is not None:
            # the executor's pool-census channel: best-effort floors (the
            # pure_callback lane may re-invoke), surfaced so the artifact
            # schema carries them — see test_bench_schema.py pins
            c["census_calls"] = self.host_executor.census_calls
            c["census_threads"] = self.host_executor.census_threads
            c["affinity_hits"] = self.host_executor.affinity_hits
            c["host_busy_us"] = self.host_executor.busy_ns // 1000
            c["host_queue_peak"] = self.host_executor.queue_peak
        return EngineStats(
            per_layer_hits=tuple(int(x) for x in self._per_layer_hits),
            per_layer_accesses=tuple(int(x) for x in self._per_layer_accesses),
            **c)

    def _tiers(self, fast) -> collab.ExpertTiers:
        s1, s3, s2, state = fast
        h1, h3, h2 = self._host
        return collab.ExpertTiers(host_w1=h1, host_w3=h3, host_w2=h2,
                                  slot_w1=s1, slot_w3=s3, slot_w2=s2,
                                  state=state)

    # -- one decode step with the staged collaborative pipeline -----------
    def _decode_step(self, tokens, state, fast, active, pages=None):
        """tokens [T, 1]; state['pos'] [T] per-slot positions; active [T]
        bool — padded slots neither touch the shared cache nor the stats;
        pages [T, max_pages] int32 per-slot physical page ids (paged KV
        only; rows padded with num_pages — attention drops their writes).

        The layer scan is a software pipeline: each iteration probes /
        executes / commits layer *l*'s MoE, then (``prefetch`` enabled)
        predicts layer *l+1*'s picks from layer *l*'s output and issues
        reservations + weight streams so the next probe finds them
        resident. The prediction and the issued-fetch set ride the scan
        carry one iteration so accuracy and wasted fetches are scored
        against the *actual* next-layer routing."""
        cfg = self.cfg
        ccfg = self.ecfg.cache
        params = self.params
        tiers = self._tiers(fast)
        x = transformer._embed_inputs(params, {"tokens": tokens}, cfg)
        pos = state["pos"]
        slots, _, _ = transformer.build_slots(cfg)
        slot = slots[0]
        T, K = tokens.shape[0], cfg.moe.top_k
        E = cfg.moe.num_experts
        NG = min(T * K, E + 1)             # dispatch groups per layer

        scan_p = params["scan"]["s0"]
        xs = {"params": scan_p, "state": state["scan"]["s0"]}
        if self.ecfg.prefetch:
            # next layer's ln2 + router, aligned to the current iteration:
            # at layer l the pipeline runs router[l+1] on this layer's
            # output (the pre-gating approximation of layer l+1's true
            # router input). The wrapped last entry is masked via has_next
            # — the next token's layer-0 input is unknowable before
            # sampling. Only the prefetch build pays for the rolled
            # weight-table duplicates.
            xs.update(
                ln2_next=jnp.roll(scan_p["ln2"], -1, axis=0),
                router_next=jnp.roll(scan_p["moe"]["router"], -1, axis=0),
                has_next=jnp.arange(cfg.num_layers) < cfg.num_layers - 1)

        def body(carry, xs):
            x, tiers, layer, pred_prev, rep_prev, issued_prev = carry
            lp, st = xs["params"], xs["state"]
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            if self.ecfg.kv_paged:
                o, new_st = attn.decode_attention_paged(
                    lp["attn"], h, st, pos, pages, cfg, slot.window,
                    active=active)
            else:
                o, new_st = attn.decode_attention(lp["attn"], h, st, pos,
                                                  cfg, slot.window)
            x = x + o
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            _, top_i, top_w = route(lp["moe"]["router"],
                                    h2[:, 0].astype(jnp.float32), K)

            # staged collaborative MoE: probe -> dispatch/execute -> commit
            pr = collab.probe(tiers, layer, top_i, ccfg, active=active)
            if self.ecfg.host_compute:
                # hybrid dispatcher (repro.hostexec): GPU-hit groups run
                # the grouped kernels, CPU-miss groups the host executor,
                # cost-model-chosen; cache warming identical either way
                y, host_w, dstats = self._dispatch_execute(
                    tiers, layer, h2[:, 0], top_w, pr, ccfg,
                    self._cpu_table, self.host_executor,
                    self.ecfg.host_fuse_small)
            else:
                y, host_w = collab.execute(tiers, layer, h2[:, 0], top_w,
                                           pr, ccfg)
                dstats = {"cpu_expert_calls": jnp.zeros((), jnp.int32),
                          "cpu_tokens": jnp.zeros((), jnp.int32),
                          "miss_expert_groups": jnp.zeros((), jnp.int32),
                          "fused_groups": jnp.zeros((), jnp.int32)}
            tiers, fetch = collab.commit(tiers, layer, pr, host_w, ccfg)
            x = x + y[:, None].astype(x.dtype)

            if self.ecfg.prefetch:
                # score the prediction the previous iteration made for
                # THIS layer: accuracy per predicted assignment, and
                # issued fetches whose expert the layer never demanded
                pred_valid = (pred_prev >= 0) & active[:, None]
                pred_ok = (pred_prev[:, :, None]
                           == top_i[:, None, :]).any(-1)
                demanded = (rep_prev[:, None] == pr.flat_e[None, :]).any(-1)
                wasted = (issued_prev & ~demanded).sum()
                predicted = pred_valid.sum()
                pred_correct = (pred_ok & pred_valid).sum()
                # speculative prefetch for layer l+1 (reservations +
                # streams; invisible until the next probe lands them).
                # Pre-gating prediction: layer l+1's router on layer l's
                # OUTPUT residual (its true input one attention block
                # later) — the DAOP-style one-layer lookahead; the
                # reservation's transfer hides under layer l+1's attention
                h_pred = rmsnorm(xs["ln2_next"], x, cfg.norm_eps)
                pred_p, pred_i, _ = route(xs["router_next"],
                                          h_pred[:, 0].astype(jnp.float32), K)
                gate = xs["has_next"] & active[:, None]
                if self.ecfg.prefetch_min_prob > 0.0:
                    # confidence gate: only reserve picks whose router
                    # probability clears the threshold — mispredictions
                    # are the only source of cache pollution, and low-
                    # confidence picks are where they live
                    p_pick = jnp.take_along_axis(pred_p, pred_i, axis=1)
                    gate = gate & (p_pick >= self.ecfg.prefetch_min_prob)
                pred_i = jnp.where(gate, pred_i, -1).astype(jnp.int32)
                tiers, rep_p, issued, n_issued = collab.prefetch(
                    tiers, layer + 1, pred_i, ccfg, active=active,
                    rank_votes=self.ecfg.prefetch_rank_votes)
            else:
                # prefetch disabled: no rolled weight tables, no scoring —
                # only constant-zero counters so the stats shape is stable
                pred_i = jnp.full((T, K), -1, jnp.int32)
                rep_p = jnp.full((NG,), -1, jnp.int32)
                issued = jnp.zeros((NG,), bool)
                n_issued = wasted = jnp.zeros((), jnp.int32)
                predicted = pred_correct = jnp.zeros((), jnp.int32)

            stats = {
                **collab._stats(pr, fetch),
                **dstats,
                "prefetch_issued": n_issued,
                "prefetch_wasted": wasted,
                "predicted": predicted,
                "predicted_correct": pred_correct,
            }
            return (x, tiers, layer + 1, pred_i, rep_p, issued), \
                (new_st, stats)

        carry0 = (x, tiers, jnp.zeros((), jnp.int32),
                  jnp.full((T, K), -1, jnp.int32),
                  jnp.full((NG,), -1, jnp.int32), jnp.zeros((NG,), bool))
        (x, tiers, _, _, _, _), (new_scan, stats) = jax.lax.scan(
            body, carry0, xs)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = transformer.lm_logits(params, x, cfg)
        new_state = {"scan": {"s0": new_scan},
                     "pos": pos + active.astype(jnp.int32)}
        new_fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)
        return logits, new_state, new_fast, stats

    # -- batch-state primitives for the scheduler -------------------------
    def init_slots(self) -> Params:
        """Empty decode state for max_batch request slots.

        Paged KV: the per-layer KV leaves become the global page pool
        ``[num_pages, page_size, Hk, hd]`` (pages play the dense cache's
        batch role, so the backbone's init_state builds them unchanged)
        and a fresh :class:`KVPagePool` takes over the host-side page
        bookkeeping — any previously bound tables are dropped with it."""
        if self.ecfg.kv_paged:
            state = transformer.init_state(self.cfg, self.num_pages,
                                           self.ecfg.page_size)
            self.kv_pool = KVPagePool(
                self.num_pages, self.ecfg.page_size,
                prefix_keep_pages=self.ecfg.prefix_keep_pages)
            self._slot_tables = [None] * self.ecfg.max_batch
            self._slot_pages = np.full(
                (self.ecfg.max_batch, self.max_pages), self.num_pages,
                np.int32)
        else:
            state = transformer.init_state(self.cfg, self.ecfg.max_batch,
                                           self.ecfg.capacity)
        state["pos"] = jnp.zeros((self.ecfg.max_batch,), jnp.int32)
        return state

    @staticmethod
    def _write_slot(batch_state, one_state, slot):
        """Scatter a single prefilled request's state into batch slot
        ``slot`` (scan leaves are [G, B, ...]; the incoming state is B=1)."""
        new_scan = jax.tree.map(lambda full, one: full.at[:, slot].set(one[:, 0]),
                                batch_state["scan"], one_state["scan"])
        pos = batch_state["pos"].at[slot].set(one_state["pos"])
        return {"scan": new_scan, "pos": pos}

    def write_slot(self, batch_state: Params, one_state: Params,
                   slot: int) -> Params:
        return self._write(batch_state, one_state, jnp.asarray(slot, jnp.int32))

    def _write_slot_paged(self, batch_state, one_state, page_ids,
                          write_mask, slot):
        """Scatter one prefilled request's dense [1, capacity, ...] KV
        into its pool pages. page_ids [max_pages] physical pages (padded
        with num_pages); write_mask [max_pages] — False rows (padding AND
        shared-prefix pages, whose content the prefix's original request
        already wrote) are dropped, so a shared page is never rewritten
        while other requests read it."""
        ps = self.ecfg.page_size
        dst = jnp.where(write_mask, page_ids, self.num_pages)

        def scatter(pool, one):
            L = pool.shape[0]
            chunks = one[:, 0].reshape((L, self.max_pages, ps)
                                       + one.shape[3:])
            return pool.at[:, dst].set(chunks, mode="drop")

        new_scan = jax.tree.map(scatter, batch_state["scan"],
                                one_state["scan"])
        pos = batch_state["pos"].at[slot].set(one_state["pos"])
        return {"scan": new_scan, "pos": pos}

    @staticmethod
    def _copy_page(batch_state, src, dst):
        """Copy-on-write page duplication: clone physical page ``src``
        into ``dst`` across every layer's K and V pools."""
        new_scan = jax.tree.map(lambda pool: pool.at[:, dst].set(pool[:, src]),
                                batch_state["scan"])
        return {"scan": new_scan, "pos": batch_state["pos"]}

    # -- paged slot lifecycle (scheduler-facing) ---------------------------
    def can_admit(self, prompt, max_new_tokens: int) -> bool:
        """Page-pool admission gate: True iff the pool can commit pages
        for the prompt plus ``max_new_tokens`` decode appends right now
        (shared-prefix pages excluded from the requirement). Dense KV has
        per-slot storage by construction — always True."""
        if not self.ecfg.kv_paged or self.kv_pool is None:
            return True
        p = _one_prompt(prompt)[0]
        return self.kv_pool.can_admit(p, p.shape[0] + int(max_new_tokens))

    def bind_slot(self, batch_state: Params, ticket: "PrefillTicket",
                  slot: int) -> Params:
        """Bind a finished prefill to batch slot ``slot``: the paged twin
        of :meth:`write_slot` (which it falls back to for dense KV).
        Scatters the ticket's KV into the table's non-shared pages and
        registers the prompt's full-page prefixes in the pool's prefix
        index — AFTER the write, so the index only ever maps populated
        pages."""
        if not self.ecfg.kv_paged:
            return self.write_slot(batch_state, ticket.state, slot)
        table = ticket.table
        assert table is not None and ticket.prompt is not None, \
            "paged ticket lost its page table (start_prefill not paged?)"
        if ticket.kv_streamed:
            # segment-streamed admission already wrote every segment's KV
            # straight into the pool pages — nothing to scatter, only the
            # slot bookkeeping and the prefix registration remain
            if ticket.logits is None:
                raise RuntimeError(
                    "segment-streamed ticket not drained: advance_prefill "
                    "to done before bind_slot")
            self._slot_tables[slot] = table
            self._slot_pages[slot] = ticket.page_ids
            pos = batch_state["pos"].at[slot].set(ticket.prompt_len)
            self.kv_pool.register(ticket.prompt, table)
            return {"scan": batch_state["scan"], "pos": pos}
        n = len(table.pages)
        ids = np.full((self.max_pages,), self.num_pages, np.int32)
        ids[:n] = table.pages
        mask = np.zeros((self.max_pages,), bool)
        mask[ticket.shared_tokens // self.ecfg.page_size:n] = True
        self._slot_tables[slot] = table
        self._slot_pages[slot] = ids
        state = self._write_paged(batch_state, ticket.state,
                                  jnp.asarray(ids), jnp.asarray(mask),
                                  jnp.asarray(slot, jnp.int32))
        self.kv_pool.register(ticket.prompt, table)
        return state

    def claim_slot(self, ticket: "PrefillTicket", slot: int) -> None:
        """Pre-bind a segment-streamed ticket's page table to the slot it
        will occupy, BEFORE the stream drains — so a cancellation mid-
        stream releases the pages through the ordinary
        :meth:`release_slot` path. Decode never reads the slot while it
        is PREFILLING (inactive rows' writes drop), so exposing the page
        ids early is safe. Dense KV: nothing to claim."""
        if not self.ecfg.kv_paged or ticket.table is None:
            return
        self._slot_tables[slot] = ticket.table
        self._slot_pages[slot] = ticket.page_ids

    def release_slot(self, slot: int) -> None:
        """Return a retired/cancelled slot's pages to the pool
        (refcount-aware: pages a prefix-sharing peer still holds stay
        allocated). Dense KV: no-op — the slot's rows are overwritten on
        reuse."""
        if not self.ecfg.kv_paged:
            return
        table = self._slot_tables[slot]
        if table is not None:
            self.kv_pool.free(table)
            self._slot_tables[slot] = None
            self._slot_pages[slot] = self.num_pages

    def abort_ticket(self, ticket: "PrefillTicket") -> None:
        """Release an open ticket's page table after a failed admission —
        the exception-path twin of :meth:`bind_slot`. Idempotent and
        double-free safe: the ticket's table is taken exactly once, any
        slot already claiming it (a segment-streamed admission claims
        before draining) is unbound first, and dense tickets are a
        no-op."""
        table, ticket.table = ticket.table, None
        if table is None or self.kv_pool is None:
            return
        for i, t in enumerate(self._slot_tables):
            if t is table:
                self._slot_tables[i] = None
                self._slot_pages[i] = self.num_pages
        self.kv_pool.free(table)

    def fork_slot(self, batch_state: Params, src: int, dst: int,
                  total_tokens: int) -> Params:
        """Clone slot ``src``'s sequence into free slot ``dst`` sharing
        ALL its KV pages (zero KV copied now; the partial last page is
        copy-on-written by whichever side appends first). total_tokens
        bounds the child's final length for page commitment."""
        if not self.ecfg.kv_paged:
            raise RuntimeError("fork_slot requires EngineConfig.kv_paged")
        parent = self._slot_tables[src]
        if parent is None:
            raise ValueError(f"slot {src} holds no page table")
        child = self.kv_pool.fork(parent, int(total_tokens))
        self._slot_tables[dst] = child
        ids = np.full((self.max_pages,), self.num_pages, np.int32)
        ids[:len(child.pages)] = child.pages
        self._slot_pages[dst] = ids
        pos = batch_state["pos"].at[dst].set(batch_state["pos"][src])
        return {"scan": batch_state["scan"], "pos": pos}

    # -- prefill: one shared trace, two cache modes ------------------------
    def _prefill_trace(self, tokens, plen, want_trace: bool = False):
        """Full-prompt forward: the backbone's prefill mode, directly.

        tokens [B, capacity] (prompt left-aligned, zero-padded); plen —
        traced scalar count of real prompt tokens. There is ONE prefill
        implementation: ``transformer.backbone(mode="prefill")``, whose
        ``want_trace`` flag additionally emits the per-layer routing
        trace the cache-warming replay consumes (the bypass path skips
        the O(L*S*D) trace materialization entirely). First-token logits
        are read at position ``plen - 1`` — the last *real* prompt token
        (pad positions are causally masked out of every real position's
        attention).

        Returns (logits [B, 1, V], decode state with pos=plen,
        trace {top_i [L, B, S, K], top_w [L, B, S, K], h2 [L, B, S, D]}
        — or None without ``want_trace``).
        """
        cfg = self.cfg
        x, state, _, trace = transformer.backbone(
            self.params, {"tokens": tokens}, cfg, "prefill", remat=False,
            want_trace=want_trace)
        h_last = jax.lax.dynamic_slice_in_dim(x, plen - 1, 1, axis=1)
        logits = transformer.lm_logits(self.params, h_last, cfg)
        state = {"scan": state["scan"], "pos": jnp.asarray(plen, jnp.int32)}
        # homogeneous stack: the one scanned slot's trace IS the engine's
        # [L, B, S, ...] routing trace
        trace = trace["scan"]["s0"] if want_trace else None
        return logits, state, trace

    def _padded_prefill(self, tokens, want_trace: bool = False):
        """Validate, pad to capacity and run the prefill trace.
        tokens [B, P] -> (logits [B, 1, V], state, routing trace|None)."""
        tokens = jnp.asarray(tokens, jnp.int32)
        B, P = tokens.shape
        cap = self.ecfg.capacity
        if not 1 <= P < cap:
            raise ValueError(
                f"prompt length {P} outside [1, capacity={cap}) — decode "
                f"needs at least one free KV slot")
        pad = jnp.zeros((B, cap - P), tokens.dtype)
        return self._prefill(jnp.concatenate([tokens, pad], 1),
                             jnp.asarray(P, jnp.int32),
                             want_trace=want_trace)

    def prefill(self, tokens: jax.Array) -> Tuple[jax.Array, Params]:
        """Bypass prefill (tiers untouched: the cache stays cold until
        decode). tokens [B, P] -> (last-real-position logits [B, 1, V],
        decode state with pos=P)."""
        self._require_dense("prefill")
        logits, state, _ = self._padded_prefill(tokens)
        return logits, state

    def _require_dense(self, what: str) -> None:
        """The static-batch convenience paths produce dense-shaped states
        with no page-table bookkeeping — under kv_paged they would leak
        pages or decode against the wrong cache layout, so they refuse."""
        if self.ecfg.kv_paged:
            raise RuntimeError(
                f"{what}() is a dense-KV path; under EngineConfig.kv_paged "
                f"use the scheduler primitives (start_prefill / bind_slot "
                f"/ decode_batch / release_slot)")

    def _warm_chunk(self, fast, top_i, top_w, h2, active):
        """Route one prompt chunk through probe → execute → commit.

        top_i/top_w [L, C, K]; h2 [L, C, D]; active [C] (False = pad rows
        beyond the prompt). The chunk's C tokens play the role of the T
        decode rows: the probe's demand accesses and the commit's
        post-fetch warm the shared tiers exactly as a decode step would;
        execute's grouped FFN output has no consumer here (the hidden
        states come from the shared prefill trace, keeping chunked and
        bypass prefill bit-identical), so XLA prunes the matmuls and what
        remains is the pipeline's *data movement* — the per-unique-expert
        weight gathers and slot writes. Returns (fast, per-layer stats).
        """
        ccfg = self.ecfg.cache
        tiers = self._tiers(fast)

        def body(carry, xs):
            tiers, layer = carry
            pr = collab.probe(tiers, layer, xs["top_i"], ccfg, active=active)
            _, host_w = collab.execute(tiers, layer, xs["h2"], xs["top_w"],
                                       pr, ccfg)
            tiers, fetch = collab.commit(tiers, layer, pr, host_w, ccfg)
            return (tiers, layer + 1), collab._stats(pr, fetch)

        (tiers, _), stats = jax.lax.scan(
            body, (tiers, jnp.zeros((), jnp.int32)),
            {"top_i": top_i, "top_w": top_w, "h2": h2})
        new_fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)
        return new_fast, stats

    def _segment_step(self, tokens, scan_state, fast, pos0, plen, pages,
                      wmin, warm: bool = True):
        """One C-token prompt segment, forward + warm fused.

        Runs the backbone's segment mode: the segment attends to the
        request's KV so far at absolute offset ``pos0`` (offset causal
        mask), appends its own KV — into the ticket's dense B=1 state
        (``pages is None``) or straight into the batch pool's pages with
        writes masked to ``[wmin, plen)`` so shared-prefix pages stay
        immutable — and (``warm``) routes its freshly emitted trace
        through probe → execute → commit. The forward IS the trace
        source: no separate replay pass, one jitted step per segment.

        First-token logits are read at ``plen - 1`` relative to the
        segment (clamped — only the LAST segment's read is meaningful;
        earlier segments' logits are overwritten by later calls).
        Returns (logits, new scan leaves, fast, new pos clamped to plen,
        warm stats | None). Pad rows past ``plen`` are computed but
        write-masked (paged) or overwritten by decode appends before any
        read (dense) — they never reach real rows through the causal
        mask, so segmentation never changes tokens."""
        cfg = self.cfg
        C = tokens.shape[1]
        state = {"scan": scan_state, "pos": pos0}
        x, new_state, _, trace = transformer.backbone(
            self.params, {"tokens": tokens}, cfg, "segment", state=state,
            remat=False, want_trace=warm, pages=pages,
            kv_write_min=wmin, kv_write_max=plen)
        rel = jnp.clip(plen - 1 - pos0, 0, C - 1)
        h_last = jax.lax.dynamic_slice_in_dim(x, rel, 1, axis=1)
        logits = transformer.lm_logits(self.params, h_last, cfg)
        wstats = None
        if warm:
            tr = trace["scan"]["s0"]
            active = (pos0 + jnp.arange(C)) < plen
            fast, wstats = self._warm_chunk(
                fast, tr["top_i"][:, 0], tr["top_w"][:, 0],
                tr["h2"][:, 0], active)
        new_pos = jnp.minimum(new_state["pos"], plen)
        return logits, new_state["scan"], fast, new_pos, wstats

    # -- resumable prefill: ticket primitives ------------------------------
    def start_prefill(self, prompt: np.ndarray,
                      chunk: Optional[int] = None,
                      max_total_tokens: Optional[int] = None
                      ) -> "PrefillTicket":
        """Run the shared prefill trace once and open a resumable
        cache-warming ticket.

        The returned :class:`PrefillTicket` carries the first-token
        logits, the request's decode state (pos=len(prompt)) and the
        prompt's routing trace padded to whole ``chunk``-token chunks,
        plus a chunk cursor. The caller drives the warming replay with
        :meth:`advance_prefill` — one call per scheduler tick for
        overlapped admission, or all at once for the synchronous path.
        With ``chunk == 0`` (bypass prefill) no trace is materialized and
        the ticket is born done.

        Paged KV: the pool allocates the request's page table here —
        committing pages up to ``max_total_tokens`` (prompt + decode
        budget; defaults to capacity) — and a prefix-index hit makes the
        new table share the matching request's full prompt-prefix pages.
        The warm replay skips the shared span's chunks (the prefix's
        original admission already routed those exact tokens through the
        cache). With ``EngineConfig.prefill_segment`` NO forward runs
        here at all: the ticket comes back with ``logits is None`` and
        :meth:`advance_prefill` streams the prompt forward one segment
        per call — on a prefix hit the shared span's forward AND warm are
        skipped outright (the stream starts past it). Raises
        :class:`~repro.serving.kv_pool.PoolExhausted` when the pool
        cannot commit the pages (gate with :meth:`can_admit` first); any
        error past the page allocation frees the table before the raise
        reaches the caller — a rejected admission never leaks pages."""
        chunk = self.ecfg.prefill_chunk if chunk is None else int(chunk)
        if chunk < 0:
            raise ValueError(f"chunk must be >= 0, got {chunk}")
        prompt = _one_prompt(prompt)
        P = prompt.shape[1]
        table, shared = None, 0
        if self.ecfg.kv_paged:
            if self.kv_pool is None:
                raise RuntimeError(
                    "paged KV: call init_slots() before start_prefill()")
            total = (self.ecfg.capacity if max_total_tokens is None
                     else int(max_total_tokens))
            table, shared = self.kv_pool.alloc_prompt(prompt[0], total)
        try:
            return self._open_ticket(prompt, chunk, table, shared)
        except BaseException:
            if table is not None:
                self.kv_pool.free(table)
            raise

    def _open_ticket(self, prompt: np.ndarray, chunk: int,
                     table: Optional[PageTable], shared: int
                     ) -> "PrefillTicket":
        """Build the ticket for an allocated admission (anything that
        raises from here is caught by start_prefill's page-release
        guard)."""
        P = prompt.shape[1]
        if self.ecfg.prefill_segment > 0:
            return self._start_segmented(prompt, table, shared,
                                         warm=chunk != 0)
        if chunk == 0:
            logits, state, _ = self._padded_prefill(prompt)
            return PrefillTicket(prompt_len=P, chunk=0, n_chunks=0,
                                 logits=logits, state=state,
                                 table=table, prompt=prompt[0],
                                 shared_tokens=shared)
        logits, state, trace = self._padded_prefill(prompt, want_trace=True)
        # fixed [L, chunk, ...] shapes: the warm step compiles once per
        # chunk size; only the chunk count varies with prompt length. The
        # trace stays device-resident on the ticket — no device->host
        # sync on the admission path.
        top_i = trace["top_i"][:, 0]                    # [L, S, K]
        top_w = trace["top_w"][:, 0]
        h2 = trace["h2"][:, 0]                          # [L, S, D]
        n_chunks = -(-P // chunk)
        pad_to = n_chunks * chunk
        if pad_to > top_i.shape[1]:
            ext = ((0, 0), (0, pad_to - top_i.shape[1]), (0, 0))
            top_i, top_w, h2 = (jnp.pad(a, ext) for a in (top_i, top_w, h2))
        return PrefillTicket(prompt_len=P, chunk=chunk, n_chunks=n_chunks,
                             logits=logits, state=state,
                             top_i=top_i, top_w=top_w, h2=h2,
                             cursor=min(shared // chunk, n_chunks),
                             table=table, prompt=prompt[0],
                             shared_tokens=shared)

    def _start_segmented(self, prompt: np.ndarray,
                         table: Optional[PageTable], shared: int,
                         warm: bool) -> "PrefillTicket":
        """Open a segment-streamed ticket: tokenize + cursor only, no
        forward. A prefix hit advances the stream's start past the
        shared span — ``fwd_start = min(shared, P - 1)`` keeps the LAST
        prompt token in the stream even when the whole prompt is shared
        (its recompute reads the shared pages, write-masked, and
        produces the first-token logits)."""
        P = prompt.shape[1]
        cap = self.ecfg.capacity
        if not 1 <= P < cap:
            raise ValueError(
                f"prompt length {P} outside [1, capacity={cap}) — decode "
                f"needs at least one free KV slot")
        seg = self.ecfg.prefill_segment
        fwd_start = min(shared, P - 1)
        n_seg = -(-(P - fwd_start) // seg)
        tok = np.zeros((1, fwd_start + n_seg * seg), np.int32)
        tok[:, :P] = prompt
        self._counters["prefix_tokens_skipped"] += fwd_start
        ticket = PrefillTicket(
            prompt_len=P, chunk=seg, n_chunks=n_seg,
            seg=seg, fwd_start=fwd_start, tokens=tok, warm=warm,
            table=table, prompt=prompt[0], shared_tokens=shared)
        if self.ecfg.kv_paged:
            ids = np.full((self.max_pages,), self.num_pages, np.int32)
            ids[:len(table.pages)] = table.pages
            ticket.page_ids = ids
            ticket.kv_streamed = True
        else:
            state = transformer.init_state(self.cfg, 1, cap)
            ticket.state = {"scan": state["scan"],
                            "pos": jnp.asarray(fwd_start, jnp.int32)}
        return ticket

    def advance_prefill(self, ticket: "PrefillTicket",
                        max_chunks: int = 1) -> bool:
        """Advance a ticket by up to ``max_chunks`` units. Trace-replay
        tickets: warm chunks through the staged probe/execute/commit
        pipeline, in prompt order — warming moves expert weights
        (shared-tier residency + the ``prefill_*`` stat channel) and
        never touches the ticket's logits/state, so decode tokens are
        bit-identical however the replay is paced. Segment-streamed
        tickets: prompt-forward segments (dense only on this signature —
        a paged stream writes the BATCH pool and must thread it through
        :meth:`advance_prefill_state`). Returns True when drained."""
        _, done = self.advance_prefill_state(ticket, None, max_chunks)
        return done

    def advance_prefill_state(self, ticket: "PrefillTicket",
                              batch_state: Optional[Params],
                              max_chunks: int = 1
                              ) -> Tuple[Optional[Params], bool]:
        """State-threading twin of :meth:`advance_prefill` for the
        scheduler: a paged segment-streamed ticket appends its KV into
        the batch pool leaves, so the batch state rides through and
        comes back rebuilt (other modes return it untouched). Returns
        (batch_state, done)."""
        chunk, P = ticket.chunk, ticket.prompt_len
        t0 = now_ns()
        if ticket.seg > 0:
            n = 0
            plen = jnp.asarray(P, jnp.int32)
            while ticket.cursor < ticket.n_chunks and n < max_chunks:
                s = ticket.fwd_start + ticket.cursor * ticket.seg
                tok = jnp.asarray(ticket.tokens[:, s:s + ticket.seg])
                pos0 = jnp.asarray(s, jnp.int32)
                if ticket.kv_streamed:
                    if batch_state is None:
                        raise RuntimeError(
                            "paged segment stream appends into the batch "
                            "pool: use advance_prefill_state(ticket, "
                            "batch_state)")
                    pages = jnp.asarray(ticket.page_ids[None])
                    wmin = jnp.asarray(ticket.shared_tokens, jnp.int32)
                    logits, new_scan, self.fast, _, wstats = self._segment(
                        tok, batch_state["scan"], self.fast, pos0, plen,
                        pages, wmin, warm=ticket.warm)
                    batch_state = {"scan": new_scan,
                                   "pos": batch_state["pos"]}
                else:
                    logits, new_scan, self.fast, new_pos, wstats = \
                        self._segment(tok, ticket.state["scan"], self.fast,
                                      pos0, plen, None, None,
                                      warm=ticket.warm)
                    ticket.state = {"scan": new_scan, "pos": new_pos}
                ticket.logits = logits
                if ticket.warm:
                    self._accumulate_prefill(
                        wstats, max(0, min(ticket.seg, P - s)))
                    self._counters["prefill_chunks"] += 1
                ticket.cursor += 1
                n += 1
            self._counters["prefill_segments"] += n
            self._obs_prefill(t0, n, ticket)
            return batch_state, ticket.done
        advanced = []
        while ticket.cursor < ticket.n_chunks and len(advanced) < max_chunks:
            s = ticket.cursor * chunk
            active = jnp.arange(s, s + chunk) < P
            self.fast, wstats = self._warm(
                self.fast, ticket.top_i[:, s:s + chunk],
                ticket.top_w[:, s:s + chunk], ticket.h2[:, s:s + chunk],
                active)
            advanced.append((wstats, min(chunk, P - s)))
            ticket.cursor += 1
        # stats convert after the mini-loop: a full synchronous drain pays
        # one device->host sync, the per-tick overlapped path one per tick
        for wstats, n_tok in advanced:
            self._accumulate_prefill(wstats, n_tok)
        self._counters["prefill_chunks"] += len(advanced)
        self._obs_prefill(t0, len(advanced), ticket)
        return batch_state, ticket.done

    def prefill_chunked(self, prompt: np.ndarray,
                        chunk: Optional[int] = None
                        ) -> Tuple[jax.Array, Params]:
        """Cache-warming chunked prefill (ROADMAP's long-prompt item).

        Runs the prompt through the shared prefill trace (bit-identical
        hidden states / KV / logits to :meth:`prefill`), then drains the
        whole warming replay synchronously — :meth:`start_prefill` +
        :meth:`advance_prefill` in one call. The warming accesses land in
        the separate ``prefill_*`` stat channel; decode-channel counters
        and generated tokens are untouched by construction (residency
        changes never change logits)."""
        self._require_dense("prefill_chunked")
        chunk = self.ecfg.prefill_chunk if chunk is None else int(chunk)
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        ticket = self.start_prefill(prompt, chunk)
        try:
            self.advance_prefill(ticket, ticket.n_chunks)
        except BaseException:
            self.abort_ticket(ticket)
            raise
        return ticket.logits, ticket.state

    def sample_first(self, ticket: "PrefillTicket",
                     sampling: SamplingParams = GREEDY, key=None) -> int:
        """Select a request's first token from its ticket's prefill
        logits under the request's own SamplingParams (``key``: the
        request's first-step PRNG key; required for non-greedy sampling).
        Counted in the ``first_tokens`` channel — prefill-sampled tokens
        are generated output, so token-based throughput must see them."""
        if ticket.logits is None:
            raise RuntimeError(
                "segment-streamed ticket has no logits yet: drain "
                "advance_prefill to done before sample_first")
        keys = None if key is None else np.asarray(key).reshape(1, 2)
        tok = int(np.asarray(
            self.select_tokens(ticket.logits[:, 0], [sampling], keys))[0])
        self._counters["first_tokens"] += 1
        return tok

    def prefill_request(self, prompt: np.ndarray,
                        sampling: SamplingParams = GREEDY,
                        key=None) -> Tuple[int, Params]:
        """Prefill one request synchronously; returns (first token, decode
        state with pos=len(prompt), B=1). Uses the cache-warming chunked
        path when ``EngineConfig.prefill_chunk > 0``, the cold bypass
        otherwise — the first token is identical either way. The
        overlapped-admission scheduler uses the underlying ticket
        primitives directly instead."""
        self._require_dense("prefill_request")
        ticket = self.start_prefill(prompt)
        try:
            self.advance_prefill(ticket, ticket.n_chunks)
            tok = self.sample_first(ticket, sampling, key)
        except BaseException:
            self.abort_ticket(ticket)
            raise
        return tok, ticket.state

    # -- vectorized per-slot sampling --------------------------------------
    def select_tokens(self, logits: jax.Array,
                      sampling: Union[None, SamplingParams,
                                      Sequence[SamplingParams]] = None,
                      keys=None) -> jax.Array:
        """Next-token selection from step logits [T, V], one
        SamplingParams per row (a scalar broadcasts; None = all greedy).
        keys [T, 2] uint32 — per-row step keys, required as soon as any
        row samples. Returns [T] int32."""
        T = logits.shape[0]
        if sampling is None:
            sampling = [GREEDY] * T
        elif isinstance(sampling, SamplingParams):
            sampling = [sampling] * T
        if len(sampling) != T:
            raise ValueError(f"params batch {len(sampling)} != rows {T}")
        greedy, temp, top_k, top_p = batch_arrays(sampling)
        if greedy.all():
            # the dominant path: skip the sampling graph (sorts, softmax,
            # discarded categorical draw) entirely
            return jnp.argmax(logits.astype(jnp.float32), -1) \
                .astype(jnp.int32)
        if keys is None:
            raise ValueError("non-greedy sampling needs per-row keys")
        return sample_tokens(logits, greedy, temp, top_k, top_p,
                             jnp.asarray(keys))

    def decode_batch(self, tokens, state: Params, active
                     ) -> Tuple[jax.Array, Params]:
        """One padded decode step for the whole slot batch. tokens [T, 1];
        active [T] bool. Updates the shared expert-cache tiers and the
        engine counters (padded rows excluded); returns (logits, state).

        Paged KV: before the step, every active slot's table plans this
        token's append — allocating a fresh page on a page boundary and
        copy-on-writing a partial last page another table still shares —
        and the (possibly updated) page-id rows ride into the jitted step;
        after the step the appends commit (the plan is idempotent, so a
        step that dies between plan and commit replans identically)."""
        # derive the host-side views (page planning, stats row count) from
        # the caller's host value BEFORE it becomes a device array — the
        # old order np.asarray(jnp.asarray(active)) round-tripped through
        # the device and blocked the decode loop twice per step
        t0 = now_ns()
        active_np = np.asarray(active, bool)
        active = jnp.asarray(active_np)
        pages = None
        if self.ecfg.kv_paged:
            act = np.nonzero(active_np)[0]
            for t in act:
                table = self._slot_tables[int(t)]
                if table is None:
                    raise RuntimeError(
                        f"active slot {t} has no bound page table — "
                        f"admit requests via bind_slot under kv_paged")
                plan = self.kv_pool.prepare_append(table)
                if plan.cow_src is not None:
                    state = self._cow(state,
                                      jnp.asarray(plan.cow_src, jnp.int32),
                                      jnp.asarray(plan.page, jnp.int32))
                self._slot_pages[int(t), len(table.pages) - 1] = plan.page
            pages = jnp.asarray(self._slot_pages)
        t_plan = now_ns()
        logits, state, self.fast, stats = self._decode(
            jnp.asarray(tokens, jnp.int32), state, self.fast, active, pages)
        t_disp = now_ns()                 # async dispatch returned
        if self.ecfg.kv_paged:
            for t in act:
                self.kv_pool.commit_append(self._slot_tables[int(t)])
        t_commit = now_ns()
        c = self._counters
        snap = (c["hits"], c["fetched_experts"], c["cpu_expert_calls"],
                c["prefetch_issued"], c["prefetch_hits"])
        busy0 = (self.host_executor.busy_ns
                 if self.host_executor is not None else 0)
        n_active = int(active_np.sum())
        self._accumulate(stats, n_active)
        self._obs_decode(t0, t_plan, t_disp, t_commit, snap, busy0,
                         n_active)
        return logits, state

    def _accumulate(self, stats, n_active: int) -> None:
        c = self._counters
        for k in ("hits", "accesses", "fetched_experts", "prefetch_issued",
                  "prefetch_hits", "prefetch_wasted", "predicted",
                  "predicted_correct", "cpu_expert_calls", "cpu_tokens",
                  "miss_expert_groups", "fused_groups"):
            c[k] += int(np.asarray(stats[k]).sum())
        c["host_assignments"] += int(
            np.asarray(stats["host_flops_assignments"]).sum())
        # scan stacks one entry per layer: accumulate the per-layer series
        # the aggregates above collapse
        self._per_layer_hits += np.asarray(stats["hits"], np.int64)
        self._per_layer_accesses += np.asarray(stats["accesses"], np.int64)
        c["tokens"] += n_active
        c["steps"] += 1

    def _accumulate_prefill(self, stats, n_tokens: int) -> None:
        """Fold one warm chunk's per-layer stats into the prefill channel
        (kept apart from the decode demand channel on purpose)."""
        c = self._counters
        c["prefill_hits"] += int(np.asarray(stats["hits"]).sum())
        c["prefill_accesses"] += int(np.asarray(stats["accesses"]).sum())
        c["prefill_fetched"] += int(
            np.asarray(stats["fetched_experts"]).sum())
        c["prefill_tokens"] += n_tokens

    # -- trace drain helpers (the ONLY emission sites; see RL007) ----------
    def _obs_decode(self, t0: int, t_plan: int, t_disp: int, t_commit: int,
                    snap, busy0: int, n_active: int) -> None:
        """Sanctioned drain point: emit the decode step's phase spans and
        lane attribution AFTER ``_accumulate`` drained the step's stats.
        Device work is timed by bracketing the jitted call at the drain
        (dispatch returns asynchronously; the drain's device_get blocks
        until the step completes), never by syncing inside it."""
        t1 = now_ns()
        obs = self.obs
        c = self._counters
        hit = c["hits"] - snap[0]
        fetch = c["fetched_experts"] - snap[1]
        cpu = c["cpu_expert_calls"] - snap[2]
        obs.complete("engine", "decode_step", t0, t1,
                     {"tokens": n_active, "hit_experts": hit,
                      "fetched_experts": fetch, "cpu_expert_calls": cpu})
        if self.ecfg.kv_paged:
            obs.complete("engine", "plan", t0, t_plan)
        obs.complete("engine", "dispatch", t_plan, t_disp)
        if self.ecfg.kv_paged:
            obs.complete("engine", "commit", t_disp, t_commit)
        obs.complete("engine", "execute+drain", t_commit, t1)
        # per-step lane attribution from the probe/census counters: the
        # gpu-hit vs fetch vs cpu-miss split of this step's assignments
        obs.counter("lane:gpu", "hit_experts", hit, ts_ns=t1)
        obs.counter("lane:fetch", "fetched_experts", fetch, ts_ns=t1)
        obs.counter("lane:cpu", "cpu_expert_calls", cpu, ts_ns=t1)
        if c["prefetch_issued"] - snap[3]:
            obs.instant("lane:fetch", "prefetch_reserve",
                        {"issued": c["prefetch_issued"] - snap[3]},
                        ts_ns=t1)
        if c["prefetch_hits"] - snap[4]:
            obs.instant("lane:gpu", "prefetch_land",
                        {"hits": c["prefetch_hits"] - snap[4]}, ts_ns=t1)
        if self.host_executor is not None:
            dbusy = self.host_executor.busy_ns - busy0
            if dbusy > 0:
                # the host pool's aggregate busy time this step, placed to
                # end at the drain (per-worker placement is unknowable
                # without timing inside the callback)
                obs.complete("lane:cpu", "host_execute", t1 - dbusy, t1,
                             {"queue_peak": self.host_executor.queue_peak})
        if self.kv_pool is not None:
            pool = self.kv_pool
            obs.counter("engine", "kv_pages_in_use", pool.pages_in_use,
                        ts_ns=t1)
            for name, cur in (("prefix_hits", pool.prefix_hits),
                              ("cow_forks", pool.cow_forks),
                              ("retention_evictions",
                               pool.retention_evictions)):
                prev = self._obs_prev.get(name, 0)
                if cur > prev:
                    obs.instant("engine", name, {"count": cur - prev},
                                ts_ns=t1)
                    self._obs_prev[name] = cur

    def _obs_prefill(self, t0: int, n_units: int,
                     ticket: "PrefillTicket") -> None:
        """Sanctioned drain point: one span per advance_prefill_state
        call (its per-unit ``_accumulate_prefill`` drains already
        synchronized), covering the segments/chunks it advanced."""
        if n_units == 0:
            return
        self.obs.complete(
            "engine",
            "segment_stream" if ticket.seg > 0 else "warm_replay",
            t0, now_ns(),
            {"units": n_units, "cursor": ticket.cursor,
             "of": ticket.n_chunks})

    # -- static-batch convenience path ------------------------------------
    def generate(self, prompt: np.ndarray, steps: int,
                 sampling: SamplingParams = GREEDY,
                 key=None) -> Tuple[np.ndarray, EngineStats]:
        """Static-batch generation: all prompt rows start and stop
        together with one shared SamplingParams (the scheduler path
        interleaves requests with per-request sampling instead). Uses
        bypass prefill — the warming path is per-request."""
        self._require_dense("generate")
        base = np.asarray(jax.random.PRNGKey(sampling.seed)
                          if sampling.seed is not None else
                          (key if key is not None else jax.random.PRNGKey(0)))
        B, P = prompt.shape
        logits, state = self.prefill(jnp.asarray(prompt))
        state["pos"] = jnp.full((B,), P, jnp.int32)

        def step_keys(i):
            if sampling.greedy:               # greedy: no key derivation
                return None
            row0 = np.asarray(jax.random.fold_in(base, i))
            return fold_keys(np.broadcast_to(row0, (B, 2)), np.arange(B))

        tok = self.select_tokens(logits[:, 0], sampling, step_keys(0))[:, None]
        # the B prefill-sampled tokens are generated output: count them in
        # the first_tokens channel so token totals don't undercount by one
        # per sequence
        self._counters["first_tokens"] += B
        active = jnp.ones((B,), bool)
        out = [np.asarray(tok)]
        for i in range(steps - 1):
            logits, state, self.fast, stats = self._decode(tok, state,
                                                           self.fast, active)
            tok = self.select_tokens(logits[:, 0], sampling,
                                     step_keys(i + 1))[:, None]
            out.append(np.asarray(tok))
            self._accumulate(stats, B)
        return np.concatenate(out, 1), self.stats
