"""Collaborative serving engine: the paper's workflow, runnable end-to-end.

Serves an MoE LM with the expert weights split across the two tiers of
repro.core.collaborative: attention/router/norm weights plus an N-index
M-way expert cache resident in the fast tier; the full expert table in the
host tier. Every decode step runs the staged collaborative pipeline —
probe (cache check + grouping), execute (grouped tiered gmm), commit
(state update + async post-fetch) — all inside one jitted step function
whose cache state threads functionally (donated buffers).

With ``EngineConfig.prefetch`` the decode scan becomes a *software
pipeline* with cross-layer speculative prefetch (DAOP / Pre-gated style):
after layer *l*'s FFN, layer *l+1*'s router runs on layer *l*'s output
hidden state — an approximation of its real input one attention block
later — and the predicted top-k experts are reserved in the cache and
streamed in while layer *l+1*'s attention computes. Reservations land at
the next probe, so a prediction made at layer *l* can only serve demand
hits from layer *l+1* on (the live-path twin of the simulator's async
fetch engine). Prefetch changes residency and counters, never numerics.

The engine is *batch-capable*: one decode step serves up to
``EngineConfig.max_batch`` concurrent requests, each at its own sequence
position (per-slot KV positions), all sharing ONE expert cache — the
paper's single-request workflow generalized to continuous batching. The
request lifecycle (admission, retirement, queueing) lives in
repro.serving.scheduler; the engine exposes the batch-state primitives it
needs: ``init_slots`` / ``prefill_request`` / ``write_slot`` /
``decode_batch`` / ``select_tokens``.

The engine exposes the counters the paper reports — per-layer and
aggregate hit rates, host-computed assignment counts, fetch volume — plus
the prefetch channel (issued / manufactured-hit / wasted fetches and
next-layer prediction accuracy), consumed by the fig5/fig6 benchmarks in
live-model mode, benchmarks/decode_prefetch, and
examples/serve_collaborative.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CacheConfig, ModelConfig
from repro.core import collaborative as collab
from repro.models import transformer
from repro.models.layers import rmsnorm
from repro.models.moe import route

Params = Dict[str, Any]


@dataclass(frozen=True)
class EngineConfig:
    cache: CacheConfig
    max_batch: int = 1            # concurrent request slots (T)
    capacity: int = 512           # KV capacity
    greedy: bool = True           # False -> temperature sampling (needs key)
    temperature: float = 1.0      # sampling temperature when greedy=False
    prefetch: bool = False        # cross-layer speculative expert prefetch


class CollaborativeEngine:
    """Single-host engine (the paper's consumer scenario, batched).

    Only homogeneous decoder-only MoE archs (every layer MoE) are accepted
    here — matching the paper's Mixtral/Phi targets. The generic serving
    path without the cache lives in launch/serve.py for all archs.
    """

    def __init__(self, cfg: ModelConfig, params: Params, ecfg: EngineConfig,
                 key=None):
        assert cfg.moe is not None and cfg.moe_every == 1 and not cfg.is_encdec
        slots, G, R = transformer.build_slots(cfg)
        assert len(slots) == 1 and R == 0, "engine expects homogeneous stacks"
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        key = key if key is not None else jax.random.PRNGKey(0)

        # Split expert weights out of the param tree into the two tiers.
        # The host tier is read-only and aliases the param tree — it is
        # deliberately NOT donated (donating it would delete the params'
        # buffers under prefill's feet); only the mutable fast-tier state
        # (slot buffers + tags/age) threads through with donation.
        moe_p = params["scan"]["s0"]["moe"]
        tiers = collab.init_tiers(
            moe_p["w1"], moe_p["w3"], moe_p["w2"], ecfg.cache,
            num_experts=cfg.moe.num_experts, key=key)
        self._host = (tiers.host_w1, tiers.host_w3, tiers.host_w2)
        self.fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)
        self._decode = jax.jit(self._decode_step, donate_argnums=(1, 2))
        self._write = jax.jit(self._write_slot, donate_argnums=(0,))
        L = cfg.num_layers
        self.stats = {"hits": 0, "accesses": 0, "host_assignments": 0,
                      "fetched_experts": 0, "tokens": 0, "steps": 0,
                      "prefetch_issued": 0, "prefetch_hits": 0,
                      "prefetch_wasted": 0, "predicted": 0,
                      "predicted_correct": 0,
                      "per_layer_hits": np.zeros(L, np.int64),
                      "per_layer_accesses": np.zeros(L, np.int64)}

    def _tiers(self, fast) -> collab.ExpertTiers:
        s1, s3, s2, state = fast
        h1, h3, h2 = self._host
        return collab.ExpertTiers(host_w1=h1, host_w3=h3, host_w2=h2,
                                  slot_w1=s1, slot_w3=s3, slot_w2=s2,
                                  state=state)

    # -- one decode step with the staged collaborative pipeline -----------
    def _decode_step(self, tokens, state, fast, active):
        """tokens [T, 1]; state['pos'] [T] per-slot positions; active [T]
        bool — padded slots neither touch the shared cache nor the stats.

        The layer scan is a software pipeline: each iteration probes /
        executes / commits layer *l*'s MoE, then (``prefetch`` enabled)
        predicts layer *l+1*'s picks from layer *l*'s output and issues
        reservations + weight streams so the next probe finds them
        resident. The prediction and the issued-fetch set ride the scan
        carry one iteration so accuracy and wasted fetches are scored
        against the *actual* next-layer routing."""
        cfg = self.cfg
        ccfg = self.ecfg.cache
        params = self.params
        tiers = self._tiers(fast)
        x = transformer._embed_inputs(params, {"tokens": tokens}, cfg)
        pos = state["pos"]
        slots, _, _ = transformer.build_slots(cfg)
        slot = slots[0]
        T, K = tokens.shape[0], cfg.moe.top_k
        E = cfg.moe.num_experts
        NG = min(T * K, E + 1)             # dispatch groups per layer

        scan_p = params["scan"]["s0"]
        xs = {"params": scan_p, "state": state["scan"]["s0"]}
        if self.ecfg.prefetch:
            # next layer's ln2 + router, aligned to the current iteration:
            # at layer l the pipeline runs router[l+1] on this layer's
            # output (the pre-gating approximation of layer l+1's true
            # router input). The wrapped last entry is masked via has_next
            # — the next token's layer-0 input is unknowable before
            # sampling. Only the prefetch build pays for the rolled
            # weight-table duplicates.
            xs.update(
                ln2_next=jnp.roll(scan_p["ln2"], -1, axis=0),
                router_next=jnp.roll(scan_p["moe"]["router"], -1, axis=0),
                has_next=jnp.arange(cfg.num_layers) < cfg.num_layers - 1)

        def body(carry, xs):
            x, tiers, layer, pred_prev, rep_prev, issued_prev = carry
            lp, st = xs["params"], xs["state"]
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            from repro.models import attention as attn
            o, new_st = attn.decode_attention(lp["attn"], h, st, pos, cfg,
                                              slot.window)
            x = x + o
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            _, top_i, top_w = route(lp["moe"]["router"],
                                    h2[:, 0].astype(jnp.float32), K)

            # staged collaborative MoE: probe -> execute -> commit
            pr = collab.probe(tiers, layer, top_i, ccfg, active=active)
            y, host_w = collab.execute(tiers, layer, h2[:, 0], top_w, pr,
                                       ccfg)
            tiers, fetch = collab.commit(tiers, layer, pr, host_w, ccfg)
            x = x + y[:, None].astype(x.dtype)

            if self.ecfg.prefetch:
                # score the prediction the previous iteration made for
                # THIS layer: accuracy per predicted assignment, and
                # issued fetches whose expert the layer never demanded
                pred_valid = (pred_prev >= 0) & active[:, None]
                pred_ok = (pred_prev[:, :, None]
                           == top_i[:, None, :]).any(-1)
                demanded = (rep_prev[:, None] == pr.flat_e[None, :]).any(-1)
                wasted = (issued_prev & ~demanded).sum()
                predicted = pred_valid.sum()
                pred_correct = (pred_ok & pred_valid).sum()
                # speculative prefetch for layer l+1 (reservations +
                # streams; invisible until the next probe lands them).
                # Pre-gating prediction: layer l+1's router on layer l's
                # OUTPUT residual (its true input one attention block
                # later) — the DAOP-style one-layer lookahead; the
                # reservation's transfer hides under layer l+1's attention
                h_pred = rmsnorm(xs["ln2_next"], x, cfg.norm_eps)
                _, pred_i, _ = route(xs["router_next"],
                                     h_pred[:, 0].astype(jnp.float32), K)
                pred_i = jnp.where(xs["has_next"] & active[:, None],
                                   pred_i, -1).astype(jnp.int32)
                tiers, rep_p, issued, n_issued = collab.prefetch(
                    tiers, layer + 1, pred_i, ccfg, active=active)
            else:
                # prefetch disabled: no rolled weight tables, no scoring —
                # only constant-zero counters so the stats shape is stable
                pred_i = jnp.full((T, K), -1, jnp.int32)
                rep_p = jnp.full((NG,), -1, jnp.int32)
                issued = jnp.zeros((NG,), bool)
                n_issued = wasted = jnp.zeros((), jnp.int32)
                predicted = pred_correct = jnp.zeros((), jnp.int32)

            stats = {
                **collab._stats(pr, fetch),
                "prefetch_issued": n_issued,
                "prefetch_wasted": wasted,
                "predicted": predicted,
                "predicted_correct": pred_correct,
            }
            return (x, tiers, layer + 1, pred_i, rep_p, issued), \
                (new_st, stats)

        carry0 = (x, tiers, jnp.zeros((), jnp.int32),
                  jnp.full((T, K), -1, jnp.int32),
                  jnp.full((NG,), -1, jnp.int32), jnp.zeros((NG,), bool))
        (x, tiers, _, _, _, _), (new_scan, stats) = jax.lax.scan(
            body, carry0, xs)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = transformer.lm_logits(params, x, cfg)
        new_state = {"scan": {"s0": new_scan},
                     "pos": pos + active.astype(jnp.int32)}
        new_fast = (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2, tiers.state)
        return logits, new_state, new_fast, stats

    # -- batch-state primitives for the scheduler -------------------------
    def init_slots(self) -> Params:
        """Empty decode state for max_batch request slots."""
        state = transformer.init_state(self.cfg, self.ecfg.max_batch,
                                       self.ecfg.capacity)
        state["pos"] = jnp.zeros((self.ecfg.max_batch,), jnp.int32)
        return state

    @staticmethod
    def _write_slot(batch_state, one_state, slot):
        """Scatter a single prefilled request's state into batch slot
        ``slot`` (scan leaves are [G, B, ...]; the incoming state is B=1)."""
        new_scan = jax.tree.map(lambda full, one: full.at[:, slot].set(one[:, 0]),
                                batch_state["scan"], one_state["scan"])
        pos = batch_state["pos"].at[slot].set(one_state["pos"])
        return {"scan": new_scan, "pos": pos}

    def write_slot(self, batch_state: Params, one_state: Params,
                   slot: int) -> Params:
        return self._write(batch_state, one_state, jnp.asarray(slot, jnp.int32))

    def prefill_request(self, prompt: np.ndarray,
                        key=None) -> Tuple[int, Params]:
        """Prefill one request; returns (first token, decode state with
        pos=len(prompt), B=1). The first token is greedy unless the engine
        samples (``greedy=False``) and a key is provided."""
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        P = prompt.shape[1]
        assert 1 <= P < self.ecfg.capacity, (P, self.ecfg.capacity)
        logits, state = self.prefill(jnp.asarray(prompt))
        tok = int(np.asarray(self.select_tokens(logits[:, P - 1], key))[0])
        return tok, state

    def select_tokens(self, logits: jax.Array, key=None) -> jax.Array:
        """Next-token selection from step logits [T, V]: argmax when
        ``greedy``, else temperature sampling (requires a PRNG key)."""
        if self.ecfg.greedy:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        if key is None:
            raise ValueError("greedy=False sampling needs a PRNG key")
        t = max(self.ecfg.temperature, 1e-6)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / t, axis=-1).astype(jnp.int32)

    def decode_batch(self, tokens, state: Params, active
                     ) -> Tuple[jax.Array, Params]:
        """One padded decode step for the whole slot batch. tokens [T, 1];
        active [T] bool. Updates the shared expert-cache tiers and the
        engine counters (padded rows excluded); returns (logits, state)."""
        active = jnp.asarray(active, bool)
        logits, state, self.fast, stats = self._decode(
            jnp.asarray(tokens, jnp.int32), state, self.fast, active)
        self._accumulate(stats, int(jax.device_get(active.sum())))
        return logits, state

    def _accumulate(self, stats, n_active: int) -> None:
        for k in ("hits", "accesses", "fetched_experts", "prefetch_issued",
                  "prefetch_hits", "prefetch_wasted", "predicted",
                  "predicted_correct"):
            self.stats[k] += int(np.asarray(stats[k]).sum())
        self.stats["host_assignments"] += int(
            np.asarray(stats["host_flops_assignments"]).sum())
        # scan stacks one entry per layer: accumulate the per-layer series
        # the aggregates above collapse
        self.stats["per_layer_hits"] += np.asarray(stats["hits"], np.int64)
        self.stats["per_layer_accesses"] += np.asarray(stats["accesses"],
                                                       np.int64)
        self.stats["tokens"] += n_active
        self.stats["steps"] += 1

    @property
    def per_layer_hit_rates(self) -> np.ndarray:
        """Demand hit rate per MoE layer ([num_layers] float; layers with
        zero accesses — e.g. nothing decoded yet — report 0.0)."""
        acc = self.stats["per_layer_accesses"]
        return np.where(acc > 0,
                        self.stats["per_layer_hits"] / np.maximum(acc, 1),
                        0.0)

    @property
    def prediction_accuracy(self) -> float:
        """Share of speculative next-layer predictions the next layer's
        real router confirmed (0.0 when prefetch never predicted)."""
        return self.stats["predicted_correct"] / max(
            self.stats["predicted"], 1)

    # -- static-batch convenience path ------------------------------------
    def prefill(self, tokens: jax.Array) -> Tuple[jax.Array, Params]:
        """Standard prefill (tiers untouched: prefill is compute-bound and
        runs from the host tier on real hardware; cache serves decode)."""
        from repro.models import model as model_lib
        B, P = tokens.shape
        cap = self.ecfg.capacity
        pad = jnp.zeros((B, cap - P), tokens.dtype)
        logits, state = model_lib.prefill(
            self.params, {"tokens": jnp.concatenate([tokens, pad], 1)},
            self.cfg)
        state["pos"] = jnp.asarray(P, jnp.int32)
        return logits, state

    def generate(self, prompt: np.ndarray, steps: int,
                 key=None) -> Tuple[np.ndarray, Dict[str, float]]:
        """Static-batch generation: all prompt rows start and stop together
        (the scheduler path interleaves requests instead)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        B, P = prompt.shape
        logits, state = self.prefill(jnp.asarray(prompt))
        state["pos"] = jnp.full((B,), P, jnp.int32)
        key, sub = jax.random.split(key)
        tok = self.select_tokens(logits[:, P - 1], sub)[:, None]
        active = jnp.ones((B,), bool)
        out = [np.asarray(tok)]
        for _ in range(steps - 1):
            logits, state, self.fast, stats = self._decode(tok, state,
                                                           self.fast, active)
            key, sub = jax.random.split(key)
            tok = self.select_tokens(logits[:, 0], sub)[:, None]
            out.append(np.asarray(tok))
            self._accumulate(stats, B)
        hit_rate = self.stats["hits"] / max(self.stats["accesses"], 1)
        return np.concatenate(out, 1), {
            **self.stats, "hit_rate": hit_rate,
            "prediction_accuracy": self.prediction_accuracy,
            "per_layer_hit_rates": self.per_layer_hit_rates}
