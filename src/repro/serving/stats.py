"""Typed serving statistics.

Replaces the string-keyed stats dicts of the engine and the scheduler:
:class:`EngineStats` is an immutable snapshot of the engine's counters
(demand + prefetch + prefill channels, per-layer series), and
:class:`RunStats` wraps one scheduler run around it with request-level
accounting. Both are frozen dataclasses with typed integer counters,
zero-guarded derived-rate properties, and a ``to_json()`` that emits only
JSON-native types — array-valued series (the per-layer hit-rate vector)
live behind properties, never mixed into a scalar dict, so the export
round-trips through ``json.dumps``/``json.loads`` exactly.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, Tuple

import numpy as np

__all__ = ["EngineStats", "RunStats"]


@dataclass(frozen=True)
class EngineStats:
    """Counters of one :class:`~repro.serving.CollaborativeEngine`.

    Decode (demand) channel: ``hits`` / ``accesses`` / ``host_assignments``
    / ``fetched_experts`` over decode-step expert assignments, plus
    ``tokens`` (active decoded tokens) and ``steps`` (padded batch steps).
    Prefetch channel: cross-layer speculation counters. Prefill channel:
    the cache-warming chunked-prefill accesses — kept separate so decode
    demand hit rates stay comparable with and without warming. Host
    channel: miss-expert groups the hybrid dispatcher ran on the CPU
    (``cpu_expert_calls``) and their token assignments (``cpu_tokens``).
    """
    # decode demand channel
    hits: int = 0
    accesses: int = 0
    host_assignments: int = 0
    fetched_experts: int = 0
    tokens: int = 0
    steps: int = 0
    # cross-layer speculative prefetch channel
    prefetch_issued: int = 0
    prefetch_hits: int = 0
    prefetch_wasted: int = 0
    predicted: int = 0
    predicted_correct: int = 0
    # chunked-prefill (cache warming) channel
    prefill_hits: int = 0
    prefill_accesses: int = 0
    prefill_fetched: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0
    # first tokens sampled from prefill logits (one per request / batch
    # row) — kept apart from the decode-step ``tokens`` counter so decode
    # rates stay per-step, but folded into ``generated_tokens`` totals
    first_tokens: int = 0
    # segment-streamed prefill channel (prefill_segment engines): prompt
    # segments forwarded between decode ticks, and prompt tokens whose
    # forward AND warm a prefix hit skipped outright
    prefill_segments: int = 0
    prefix_tokens_skipped: int = 0
    # live host-execution channel (repro.hostexec): cache-miss expert
    # groups the cost-model dispatcher ran on the CPU, the token
    # assignments they carried, and the total executed non-resident
    # groups (CPU + fetch lanes — only counted while the dispatcher runs)
    cpu_expert_calls: int = 0
    cpu_tokens: int = 0
    miss_expert_groups: int = 0
    # CPU-miss groups the host executor's small-group fusion lane batched
    # into one stacked matmul instead of one pool task each
    fused_groups: int = 0
    # executor pool-census channel (best-effort floors — the pure_callback
    # lane may re-invoke): censused dispatches, their summed effective
    # worker counts (mean workers = census_threads / census_calls), and
    # groups that landed on their thread-affinity bucket
    census_calls: int = 0
    census_threads: int = 0
    affinity_hits: int = 0
    # executor pool-utilization channel (same best-effort floor caveat):
    # summed per-worker microseconds spent inside expert FFN compute, and
    # the high-water mark of bucket tasks one dispatch submitted
    host_busy_us: int = 0
    host_queue_peak: int = 0
    # paged-KV channel (kv_paged engines): current page-pool occupancy
    # (gauge), admissions served from the prefix index, and partial last
    # pages duplicated by copy-on-write appends
    kv_pages_in_use: int = 0
    prefix_hits: int = 0
    cow_forks: int = 0
    # zero-ref prefix pages parked in the pool's retention LRU (gauge)
    prefix_pages_retained: int = 0
    # per-MoE-layer demand series (tuples: immutable + JSON-native)
    per_layer_hits: Tuple[int, ...] = ()
    per_layer_accesses: Tuple[int, ...] = ()

    # -- derived rates (all zero-guarded) ---------------------------------
    @property
    def generated_tokens(self) -> int:
        """Total generated output tokens: decode-step tokens plus the
        first token of every request/row (sampled from prefill logits) —
        the number token-based throughput should divide by."""
        return self.tokens + self.first_tokens

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.accesses, 1)

    @property
    def prefetch_hit_rate(self) -> float:
        """Share of demand accesses served by a landed reservation."""
        return self.prefetch_hits / max(self.accesses, 1)

    @property
    def prefetch_waste_rate(self) -> float:
        return self.prefetch_wasted / max(self.prefetch_issued, 1)

    @property
    def prediction_accuracy(self) -> float:
        return self.predicted_correct / max(self.predicted, 1)

    @property
    def prefill_hit_rate(self) -> float:
        return self.prefill_hits / max(self.prefill_accesses, 1)

    @property
    def cpu_offload_rate(self) -> float:
        """Share of miss assignments the dispatcher computed on the CPU."""
        return self.cpu_tokens / max(self.host_assignments, 1)

    @property
    def per_layer_hit_rates(self) -> np.ndarray:
        """Demand hit rate per MoE layer ([num_layers] float; layers with
        zero accesses report 0.0). Array-valued: exposed as a property so
        the scalar counters and ``to_json()`` stay array-free."""
        acc = np.asarray(self.per_layer_accesses, np.int64)
        hit = np.asarray(self.per_layer_hits, np.int64)
        return np.where(acc > 0, hit / np.maximum(acc, 1), 0.0)

    def to_json(self) -> Dict:
        """JSON-native export: int counters, float rates, list series."""
        d = {k: int(v) for k, v in asdict(self).items()
             if not isinstance(v, tuple)}
        d.update(
            generated_tokens=int(self.generated_tokens),
            hit_rate=float(self.hit_rate),
            prefetch_hit_rate=float(self.prefetch_hit_rate),
            prefetch_waste_rate=float(self.prefetch_waste_rate),
            prediction_accuracy=float(self.prediction_accuracy),
            prefill_hit_rate=float(self.prefill_hit_rate),
            cpu_offload_rate=float(self.cpu_offload_rate),
            per_layer_hits=[int(x) for x in self.per_layer_hits],
            per_layer_accesses=[int(x) for x in self.per_layer_accesses],
            per_layer_hit_rates=[float(x) for x in self.per_layer_hit_rates],
        )
        return d


@dataclass(frozen=True)
class RunStats:
    """One scheduler run: request accounting around an EngineStats
    snapshot — including the overlapped-admission channel
    (``prefill_pending`` slots warming right now, cumulative
    ``admission_stalls`` ticks with a request waiting in queue, and
    ``queue_rejected`` bounded-admission rejections). Engine counters and
    rates are reachable directly (``run.hit_rate`` delegates to
    ``run.engine.hit_rate``)."""
    engine: EngineStats = field(default_factory=EngineStats)
    requests_submitted: int = 0
    requests_finished: int = 0
    requests_active: int = 0
    requests_queued: int = 0
    prefill_pending: int = 0
    admission_stalls: int = 0
    queue_rejected: int = 0
    # latency percentiles (milliseconds) from the scheduler's streaming
    # log-bucket histograms (repro.obs.metrics.LogHistogram — ~4%
    # relative bucket error): time to first token (submit → first token),
    # per-token inter-arrival (TPOT), and the admission-work stall the
    # decode loop absorbed on ticks that admitted or warmed a request
    ttft_ms_p50: float = 0.0
    ttft_ms_p95: float = 0.0
    ttft_ms_p99: float = 0.0
    tpot_ms_p50: float = 0.0
    tpot_ms_p95: float = 0.0
    tpot_ms_p99: float = 0.0
    stall_ms_p50: float = 0.0
    stall_ms_p95: float = 0.0
    stall_ms_p99: float = 0.0

    def __getattr__(self, name):
        # delegate unknown attributes to the engine snapshot so call sites
        # read run.hits / run.hit_rate without the .engine hop. "engine"
        # itself (and dunders) must raise a plain AttributeError: during
        # copy/pickle reconstruction the instance has no fields yet, and
        # delegating the "engine" miss to self.engine would recurse
        # forever
        if name.startswith("__") or name == "engine":
            raise AttributeError(name)
        return getattr(self.engine, name)

    def to_json(self) -> Dict:
        return {
            "requests_submitted": int(self.requests_submitted),
            "requests_finished": int(self.requests_finished),
            "requests_active": int(self.requests_active),
            "requests_queued": int(self.requests_queued),
            "prefill_pending": int(self.prefill_pending),
            "admission_stalls": int(self.admission_stalls),
            "queue_rejected": int(self.queue_rejected),
            "ttft_ms_p50": float(self.ttft_ms_p50),
            "ttft_ms_p95": float(self.ttft_ms_p95),
            "ttft_ms_p99": float(self.ttft_ms_p99),
            "tpot_ms_p50": float(self.tpot_ms_p50),
            "tpot_ms_p95": float(self.tpot_ms_p95),
            "tpot_ms_p99": float(self.tpot_ms_p99),
            "stall_ms_p50": float(self.stall_ms_p50),
            "stall_ms_p95": float(self.stall_ms_p95),
            "stall_ms_p99": float(self.stall_ms_p99),
            "engine": self.engine.to_json(),
        }
