"""Per-request sampling: typed parameters and a vectorized per-slot sampler.

The serving surface treats the *request* as the unit of adaptivity (DAOP /
HybriMoE style): every request carries its own :class:`SamplingParams`
(greedy / temperature / top-k / top-p, optional seed), and one vectorized
sampler draws the whole slot batch's next tokens in a single jitted call
driven by a ``[T]`` params batch — there is no engine-wide sampling knob.

Reproducibility is *per request*, not per batch: each request owns a PRNG
chain seeded from ``SamplingParams.seed`` (or a scheduler-split fallback),
and its i-th generated token always draws from ``fold_in(base, i)`` —
independent of slot placement, batch composition or admission order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SamplingParams", "GREEDY", "batch_arrays", "sample_tokens",
           "request_key", "fold_keys"]


@dataclass(frozen=True)
class SamplingParams:
    """How one request turns logits into tokens.

    greedy       — argmax decoding; all other knobs are ignored.
    temperature  — softmax temperature (>0) when sampling.
    top_k        — keep only the k highest-probability tokens (0 = off).
    top_p        — nucleus sampling: keep the smallest prefix of the
                   sorted distribution whose mass reaches p (1.0 = off).
    seed         — per-request PRNG seed; None derives one from the
                   scheduler's key chain at admission.
    """
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


GREEDY = SamplingParams()


def batch_arrays(params: Sequence[SamplingParams]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """[T] SamplingParams -> (greedy [T]b, temperature [T]f32,
    top_k [T]i32, top_p [T]f32) — the params batch the sampler consumes."""
    return (np.array([p.greedy for p in params], bool),
            np.array([p.temperature for p in params], np.float32),
            np.array([p.top_k for p in params], np.int32),
            np.array([p.top_p for p in params], np.float32))


def request_key(params: SamplingParams, fallback) -> np.ndarray:
    """Base PRNG key of one request's sampling chain ([2] uint32)."""
    if params.seed is not None:
        return np.asarray(jax.random.PRNGKey(params.seed))
    return np.asarray(fallback)


@jax.jit
def fold_keys(bases: jax.Array, counts: jax.Array) -> jax.Array:
    """Per-slot step keys: fold each request's token index into its base
    chain. bases [T, 2] uint32; counts [T] int32 -> [T, 2] uint32."""
    return jax.vmap(jax.random.fold_in)(bases, counts)


@jax.jit
def sample_tokens(logits: jax.Array, greedy: jax.Array,
                  temperature: jax.Array, top_k: jax.Array,
                  top_p: jax.Array, keys: jax.Array) -> jax.Array:
    """Vectorized per-slot next-token selection.

    logits [T, V]; greedy/temperature/top_k/top_p [T] (the params batch);
    keys [T, 2] uint32 (per-slot step keys; ignored for greedy rows).
    Returns [T] int32. Greedy rows take argmax of the raw logits; sampling
    rows apply temperature, then the row's top-k cut, then the row's
    nucleus (top-p) cut, and draw categorically with the row's own key —
    rows never share randomness.
    """
    lg = logits.astype(jnp.float32)
    V = lg.shape[-1]
    arg = jnp.argmax(lg, -1).astype(jnp.int32)

    scaled = lg / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: threshold at each row's k-th largest scaled logit (k=0 -> off)
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]
    keff = jnp.where((top_k <= 0) | (top_k > V), V, top_k)
    kth = jnp.take_along_axis(srt, (keff - 1)[:, None], axis=-1)
    masked = jnp.where(scaled < kth, -jnp.inf, scaled)
    # top-p: keep the smallest sorted prefix reaching mass p (the top
    # token always survives: its preceding cumulative mass is 0 < p)
    srt_m = jnp.sort(masked, axis=-1)[:, ::-1]
    ps = jax.nn.softmax(srt_m, axis=-1)
    csum = jnp.cumsum(ps, axis=-1)
    keep = (csum - ps) < top_p[:, None]
    pth = jnp.min(jnp.where(keep, srt_m, jnp.inf), axis=-1, keepdims=True)
    masked = jnp.where(masked < pth, -jnp.inf, masked)

    drawn = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, masked)
    return jnp.where(greedy, arg, drawn.astype(jnp.int32))
