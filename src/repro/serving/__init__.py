from .api import build
from .engine import CollaborativeEngine, EngineConfig
from .sampling import GREEDY, SamplingParams
from .scheduler import ContinuousBatchingScheduler, Request
from .stats import EngineStats, RunStats

__all__ = ["build", "CollaborativeEngine", "EngineConfig",
           "ContinuousBatchingScheduler", "Request",
           "SamplingParams", "GREEDY", "EngineStats", "RunStats"]
