from .engine import CollaborativeEngine, EngineConfig
from .scheduler import ContinuousBatchingScheduler, Request

__all__ = ["CollaborativeEngine", "EngineConfig",
           "ContinuousBatchingScheduler", "Request"]
