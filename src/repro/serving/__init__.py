from .engine import CollaborativeEngine, EngineConfig

__all__ = ["CollaborativeEngine", "EngineConfig"]
