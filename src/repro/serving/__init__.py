from .api import build
from .engine import CollaborativeEngine, EngineConfig, PrefillTicket
from .kv_pool import KVPagePool, PageTable, PoolExhausted
from .sampling import GREEDY, SamplingParams
from .scheduler import ContinuousBatchingScheduler, QueueFull, Request
from .stats import EngineStats, RunStats

__all__ = ["build", "CollaborativeEngine", "EngineConfig", "PrefillTicket",
           "ContinuousBatchingScheduler", "QueueFull", "Request",
           "SamplingParams", "GREEDY", "EngineStats", "RunStats",
           "KVPagePool", "PageTable", "PoolExhausted"]
