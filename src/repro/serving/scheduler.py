"""Continuous-batching request scheduler over the collaborative engine.

The paper's framework decodes one request at a time; production MoE
serving (HybriMoE, DAOP) interleaves many. This scheduler generalizes the
workflow to T = ``EngineConfig.max_batch`` concurrent *slots* over ONE
shared expert cache:

  * admission   — a queued request claims a free slot: the shared prefill
                  trace runs once (first token sampled immediately, KV
                  state scattered into the slot's rows), then the slot
                  enters the PREFILLING phase while its cache-warming
                  replay drains. With
                  ``EngineConfig.admit_chunks_per_tick > 0`` the replay
                  advances at most that many chunks per tick BETWEEN
                  decode steps — established slots keep decoding while
                  the newcomer warms (no head-of-line blocking); with 0
                  the replay drains synchronously on the admission tick.
                  Under ``EngineConfig.prefill_segment`` the admission
                  tick runs NO forward at all: the slot enters
                  PREFILLING immediately and each tick streams (at most
                  ``admit_chunks_per_tick``) prompt segments through the
                  backbone — forward, KV append and cache warm fused —
                  with the first token sampled on the tick whose segment
                  completes the prompt.
  * decode tick — every step decodes the whole padded slot batch in one
                  jitted call; each slot sits at its own KV position
                  (per-slot ``pos`` vector) and inactive or PREFILLING
                  slots are masked out of the shared expert cache, the
                  stats and the output. Next tokens are drawn by the
                  engine's vectorized per-slot sampler, each row under
                  its own request's SamplingParams and PRNG chain.
  * retirement  — a request finishes on ``max_new_tokens``, ``eos_id`` or
                  one of its ``stop_sequences``; its slot frees
                  immediately and the next queued request is admitted on
                  the same tick (continuous batching: the batch never
                  drains to refill).
  * cancellation — :meth:`cancel` retires a queued or in-flight request
                  mid-decode or mid-warm: the slot frees for the next
                  admission (a PREFILLING slot's ticket is dropped), a
                  terminal ``(rid, -1, done=True)`` event is emitted,
                  and no further tokens are decoded for it.
  * backpressure — ``max_queue`` bounds the waiting line:
                  ``submit(..., block=False)`` raises :class:`QueueFull`
                  when it is at capacity (counted in ``queue_rejected``),
                  the blocking default drives ticks until space frees.
                  :meth:`pause_admission` / :meth:`resume_admission` let
                  a consumer hold new admissions (queued requests wait;
                  in-flight slots keep decoding).

Callers observe tokens as they decode: :meth:`stream` yields
``(rid, token, done)`` events in emission order, and each request may
carry an ``on_token`` callback invoked at append time. Everything here is
host-side orchestration (numpy + python lists) around the engine's jitted
primitives — the scheduler adds no traced code, so the decode step
compiles exactly once per (T, capacity) geometry.
"""
from __future__ import annotations

import os
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterator, List, Optional, \
    Sequence, Tuple

import jax
import numpy as np

from repro.obs.metrics import LogHistogram
from repro.obs.trace import NULL_RECORDER, now_ns

from .engine import CollaborativeEngine, PrefillTicket, _one_prompt
from .sampling import GREEDY, SamplingParams, fold_keys, request_key
from .stats import RunStats

__all__ = ["Request", "ContinuousBatchingScheduler", "StreamEvent",
           "QueueFull"]

StreamEvent = Tuple[int, int, bool]          # (rid, token, done)


class QueueFull(RuntimeError):
    """Raised by ``submit(..., block=False)`` when the scheduler's
    bounded queue (``max_queue``) is at capacity — the consumer's typed
    backpressure signal."""


@dataclass(eq=False)
class Request:
    """One generation request: prompt, per-request sampling, termination
    conditions, optional streaming callback, and accumulated output.

    Identity semantics (``eq=False``): ``rid`` is the key; a generated
    ``__eq__`` would compare the ``np.ndarray`` prompt element-wise and
    make ``req in queue`` / ``list.remove`` raise on two distinct
    requests ("truth value of an array is ambiguous")."""
    rid: int
    prompt: np.ndarray                  # [P] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: SamplingParams = GREEDY
    stop_sequences: Tuple[Tuple[int, ...], ...] = ()
    on_token: Optional[Callable[[int, bool], None]] = None
    generated: List[int] = field(default_factory=list)
    cancelled: bool = False
    # lifecycle stamps (perf_counter_ns; 0 = phase not reached) written as
    # the request moves submit → admit → first token → done. Plain clock
    # reads — the spans they become are emitted retroactively at the
    # scheduler's _obs_retire drain point, never on the hot path.
    t_submit: int = 0
    t_admit: int = 0
    t_first: int = 0
    t_last: int = 0
    t_done: int = 0
    slot: int = -1

    @property
    def done(self) -> bool:
        if self.cancelled:
            return True
        if len(self.generated) >= self.max_new_tokens:
            return True
        if not self.generated:
            return False
        if self.eos_id is not None and self.generated[-1] == self.eos_id:
            return True
        for seq in self.stop_sequences:
            n = len(seq)
            if n and len(self.generated) >= n \
                    and tuple(self.generated[-n:]) == tuple(seq):
                return True
        return False

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.generated, np.int32)


class ContinuousBatchingScheduler:
    """Slot-based continuous batching for :class:`CollaborativeEngine`.

    ``key`` seeds the fallback per-request sampling chains (requests whose
    SamplingParams carry no explicit ``seed``); a request's i-th token
    always draws from ``fold_in(request_base, i)``, so runs are
    reproducible per (scheduler seed, admission order) and — for
    explicitly seeded requests — per request, independent of batch
    composition. ``max_queue`` bounds the waiting line (None =
    unbounded); see :meth:`submit` for the blocking/raising behaviour."""

    def __init__(self, engine: CollaborativeEngine, key=None,
                 max_queue: Optional[int] = None, recorder=None):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.engine = engine
        # trace recorder (repro.obs.TraceRecorder, or the no-op twin when
        # tracing is off); a recorder passed here also becomes the
        # engine's, so one flag wires the whole stack. Emission happens
        # only in the _obs_* drain helpers (reprolint RL007).
        self.obs = recorder if recorder is not None else engine.obs
        if recorder is not None:
            engine.obs = recorder
        # streaming latency histograms: always on (cheap host float math
        # feeding the RunStats percentiles, tracing or not)
        self._h_ttft = LogHistogram()
        self._h_tpot = LogHistogram()
        self._h_stall = LogHistogram()
        self.num_slots = engine.ecfg.max_batch
        self.max_queue = max_queue
        self.state = engine.init_slots()
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        # PREFILLING phase: slot t warms through _tickets[t] and is masked
        # out of decode until the ticket drains (None = decoding/free)
        self._tickets: List[Optional[PrefillTicket]] = [None] * self.num_slots
        self.queue: Deque[Request] = deque()
        self._next = np.zeros((self.num_slots, 1), np.int32)
        self._rid = 0
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._bases = np.zeros((self.num_slots, 2), np.uint32)
        self.finished: List[Request] = []
        self._submitted = 0
        self._paused = False
        self._admission_stalls = 0
        self._queue_rejected = 0
        # events/retirements produced OUTSIDE a consumer-driven tick
        # (cancellations, ticks driven by a blocking submit): buffered
        # here and delivered at the start of the next tick so stream()
        # never loses a token or a terminal done=True
        self._pending_events: List[StreamEvent] = []
        self._pending_done: List[Request] = []
        # REPRO_DEBUG_INVARIANTS=1: audit the page pool's refcount/free-
        # list/prefix-index invariants after every tick (tests set this;
        # production leaves it off — the audit walks the whole pool)
        self._debug_invariants = \
            os.environ.get("REPRO_DEBUG_INVARIANTS") == "1"

    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stop_sequences: Sequence[Sequence[int]] = (),
               on_token: Optional[Callable[[int, bool], None]] = None,
               block: bool = True) -> Request:
        """Queue one request. Validates the prompt against the engine
        geometry here — at submission — so an oversized request fails
        fast with a clear error instead of mid-run after other requests
        already decoded.

        Bounded admission (``max_queue`` set): when the queue is at
        capacity, ``block=True`` (default) drives scheduler ticks until a
        queue slot frees — the natural backpressure for a synchronous
        producer — while ``block=False`` raises :class:`QueueFull`
        immediately (counted in ``queue_rejected``). A full queue with
        admission paused raises :class:`QueueFull` in both modes: ticks
        cannot drain it."""
        prompt = _one_prompt(prompt)[0]      # [P]; rejects [B, P] batches
        plen, cap = prompt.shape[0], self.engine.ecfg.capacity
        if plen < 1:
            raise ValueError("prompt must contain at least one token")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if plen + max_new_tokens > cap:
            raise ValueError(
                f"prompt length {plen} + max_new_tokens {max_new_tokens} "
                f"exceeds engine KV capacity {cap}; shorten the prompt or "
                f"raise EngineConfig.capacity")
        while self.max_queue is not None \
                and len(self.queue) >= self.max_queue:
            if not block or self._paused:
                self._queue_rejected += 1
                raise QueueFull(
                    f"scheduler queue is at max_queue={self.max_queue}"
                    + (" and admission is paused" if self._paused else
                       "; retry later or submit(block=True)"))
            # drain work until space frees; the ticks' events/retirements
            # re-enter the pending buffers so a later stream()/step()
            # still delivers every token and terminal done=True
            finished, events = self._tick()
            self._pending_events.extend(events)
            self._pending_done.extend(finished)
        req = Request(self._rid, prompt, int(max_new_tokens), eos_id,
                      sampling if sampling is not None else GREEDY,
                      tuple(tuple(int(t) for t in s)
                            for s in stop_sequences),
                      on_token, t_submit=now_ns())
        self._rid += 1
        self._submitted += 1
        self.queue.append(req)
        return req

    def pause_admission(self) -> None:
        """Hold new admissions: queued requests stay queued (and keep
        counting ``admission_stalls``) while in-flight slots decode and
        PREFILLING slots keep warming. ``stream()``/``run()`` drain only
        the in-flight work while paused — call :meth:`resume_admission`
        to serve the queue again."""
        self._paused = True

    def resume_admission(self) -> None:
        """Reopen admission; the next tick admits queued requests into
        free slots as usual."""
        self._paused = False

    @property
    def admission_paused(self) -> bool:
        return self._paused

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request mid-decode or mid-warm.

        An in-flight request's slot frees IMMEDIATELY — the next tick's
        admission can hand it to a waiting request without the cancelled
        one decoding another token; a PREFILLING slot additionally drops
        its warming ticket (no further chunks replay). The request
        retires with a terminal ``(rid, -1, done=True)`` stream event,
        delivered ahead of the next tick's events (-1, never a real
        token: every generated token was already streamed exactly once);
        its ``on_token`` callback fires once more with ``(-1, True)``.
        Returns True if the request was found live (queued or in a slot),
        False if unknown or already finished — cancelling is idempotent
        and never raises."""
        req = None
        for r in self.queue:
            if r.rid == rid:
                req = r
                self.queue.remove(r)
                break
        if req is None:
            for t, r in enumerate(self.slots):
                if r is not None and r.rid == rid:
                    if r.done:
                        # finished on the last tick, awaiting retirement:
                        # its terminal done=True event already streamed —
                        # emitting a second one would break the
                        # one-terminal-event contract
                        return False
                    req = r
                    self.slots[t] = None          # slot free for admission
                    self._tickets[t] = None       # mid-warm: drop the ticket
                    self.engine.release_slot(t)   # paged: pages back now
                    break
        if req is None:
            return False
        req.cancelled = True                      # done; rejects new tokens
        req.t_done = now_ns()
        self.finished.append(req)
        self._pending_done.append(req)            # next _tick reports it
        self._pending_events.append((req.rid, -1, True))
        if req.on_token is not None:
            req.on_token(-1, True)
        self._obs_retire([req])
        return True

    def fork(self, rid: int, max_new_tokens: Optional[int] = None,
             sampling: Optional[SamplingParams] = None) -> Request:
        """Fork a live request into a free slot (paged KV only).

        The child shares ALL the parent's KV pages — zero KV is copied
        now; the partial last page copy-on-writes when either side next
        appends — and continues decoding from the parent's pending next
        token under its own sampling chain (``sampling``; parent's by
        default) and budget (``max_new_tokens``; parent's by default).
        The parent must be fully warmed (not PREFILLING) and not done;
        raises :class:`~repro.serving.kv_pool.PoolExhausted` when the
        pool cannot commit the child's decode pages."""
        if not self.engine.ecfg.kv_paged:
            raise RuntimeError("fork requires EngineConfig.kv_paged")
        src = next((t for t, r in enumerate(self.slots)
                    if r is not None and r.rid == rid), None)
        if src is None or self.slots[src].done:
            raise ValueError(f"request {rid} is not in a live slot")
        if self._tickets[src] is not None:
            raise ValueError(
                f"request {rid} is still PREFILLING; fork after warmup")
        dst = next((t for t in range(self.num_slots)
                    if self.slots[t] is None), None)
        if dst is None:
            raise RuntimeError("no free slot to fork into")
        parent = self.slots[src]
        new_max = parent.max_new_tokens if max_new_tokens is None \
            else int(max_new_tokens)
        plen, cap = parent.prompt.shape[0], self.engine.ecfg.capacity
        if new_max <= len(parent.generated):
            raise ValueError(
                f"max_new_tokens {new_max} <= tokens already generated "
                f"({len(parent.generated)}): the child would be born done")
        if plen + new_max > cap:
            raise ValueError(
                f"prompt length {plen} + max_new_tokens {new_max} exceeds "
                f"engine KV capacity {cap}")
        child = Request(self._rid, parent.prompt, new_max, parent.eos_id,
                        sampling if sampling is not None else parent.sampling,
                        parent.stop_sequences,
                        generated=list(parent.generated))
        # the child is born mid-decode: its lifecycle starts (and its
        # queued/prefill phases collapse to zero) at the fork instant
        child.t_submit = child.t_admit = child.t_first = child.t_last \
            = now_ns()
        child.slot = dst
        self._rid += 1
        self._submitted += 1
        self.state = self.engine.fork_slot(self.state, src, dst,
                                           plen + new_max)
        self._next[dst, 0] = self._next[src, 0]
        self._bases[dst] = request_key(child.sampling, self._split())
        self.slots[dst] = child
        self._tickets[dst] = None
        return child

    # -- slot bookkeeping --------------------------------------------------
    @property
    def active_mask(self) -> np.ndarray:
        """Occupied slots — decoding OR warming (PREFILLING)."""
        return np.array([s is not None for s in self.slots], bool)

    @property
    def decode_mask(self) -> np.ndarray:
        """Slots that decode this tick: occupied and fully warmed (a
        PREFILLING slot is masked out until its ticket drains)."""
        return np.array([s is not None and tk is None
                         for s, tk in zip(self.slots, self._tickets)], bool)

    @property
    def num_active(self) -> int:
        return int(self.active_mask.sum())

    @property
    def prefill_pending(self) -> int:
        """Slots currently in the PREFILLING phase (warming mid-replay)."""
        return sum(tk is not None for tk in self._tickets)

    def _retire(self) -> List[Request]:
        out = []
        for t, req in enumerate(self.slots):
            if req is not None and req.done:
                self.slots[t] = None
                self._tickets[t] = None   # done mid-warm: drop the replay
                self.engine.release_slot(t)   # paged: pages back to pool
                out.append(req)
        self.finished.extend(out)
        if out:
            self._obs_retire(out)
        return out

    def _append(self, req: Request, tok: int,
                events: List[StreamEvent]) -> None:
        t = now_ns()
        req.generated.append(tok)
        if req.t_first == 0:
            req.t_first = t
            self._h_ttft.observe((t - req.t_submit) / 1e6)
        else:
            self._h_tpot.observe((t - req.t_last) / 1e6)
        req.t_last = t
        done = req.done
        if done:
            req.t_done = t
        events.append((req.rid, tok, done))
        if req.on_token is not None:
            req.on_token(tok, done)

    def _admit(self, events: List[StreamEvent]) -> int:
        if self._paused:
            return 0
        admitted = 0
        for t in range(self.num_slots):
            if self.slots[t] is None and self.queue:
                req = self.queue[0]
                if not self.engine.can_admit(req.prompt,
                                             req.max_new_tokens):
                    # paged KV backpressure: the FIFO head can't commit
                    # its pages yet — stop admitting (skipping ahead would
                    # starve it); retirements free pages, so it clears on
                    # a later tick, counted by the stall signal below
                    break
                self.queue.popleft()
                req.t_admit = now_ns()
                req.slot = t
                admitted += 1
                base = request_key(req.sampling, self._split())
                self._bases[t] = base
                ticket = self.engine.start_prefill(
                    req.prompt,
                    max_total_tokens=(req.prompt.shape[0]
                                      + req.max_new_tokens))
                if ticket.logits is None:
                    # segment-streamed: no forward ran on this tick — the
                    # slot goes straight into PREFILLING and the first
                    # token is sampled when _advance_prefills drains the
                    # stream. claim_slot pre-binds the page table so a
                    # mid-stream cancel releases pages normally.
                    self.engine.claim_slot(ticket, t)
                    self.slots[t] = req
                    self._tickets[t] = ticket
                    continue
                try:
                    first_tok = self.engine.sample_first(
                        ticket, req.sampling,
                        key=jax.random.fold_in(base, 0))
                    self.state = self.engine.bind_slot(self.state, ticket, t)
                except BaseException:
                    # the ticket's pages are allocated but not yet bound
                    # to the slot: release them or a failed admission
                    # leaks the table
                    self.engine.abort_ticket(ticket)
                    raise
                # claim the slot BEFORE the first-token callback fires so
                # an on_token handler that calls cancel() finds the
                # request live (cancel then frees the slot right here)
                self._next[t, 0] = first_tok
                self.slots[t] = req
                self._tickets[t] = None if ticket.done else ticket
                self._append(req, first_tok, events)
        return admitted

    def _advance_prefills(self, events: List[StreamEvent]) -> None:
        """Drive every PREFILLING slot's warming replay (or segment
        stream): the whole ticket at once when
        ``admit_chunks_per_tick == 0`` (synchronous admission), at most
        that many chunks/segments otherwise — the overlapped path that
        keeps decode ticks flowing under a long-prompt admission. A
        drained ticket flips its slot into the decode set of THIS tick
        (matching the synchronous path's admit-and-decode-same-tick
        behaviour). A drained segment-streamed ticket additionally owes
        the request its deferred first token: sampled, bound and
        streamed here."""
        per_tick = self.engine.ecfg.admit_chunks_per_tick
        for t, ticket in enumerate(self._tickets):
            if ticket is None or self.slots[t] is None:
                continue
            budget = ticket.remaining if per_tick == 0 \
                else min(per_tick, ticket.remaining)
            self.state, done = self.engine.advance_prefill_state(
                ticket, self.state, budget)
            if done:
                self._tickets[t] = None
                if ticket.seg > 0:
                    req = self.slots[t]
                    first_tok = self.engine.sample_first(
                        ticket, req.sampling,
                        key=jax.random.fold_in(self._bases[t], 0))
                    self.state = self.engine.bind_slot(self.state, ticket, t)
                    self._next[t, 0] = first_tok
                    self._append(req, first_tok, events)

    # -- the decode loop ---------------------------------------------------
    def _tick(self) -> Tuple[List[Request], List[StreamEvent]]:
        """One scheduler tick: retire -> admit -> advance warming -> one
        padded decode step over the warmed slots.
        Returns (requests finished this tick, stream events in order)."""
        events: List[StreamEvent] = []
        finished: List[Request] = []
        t0 = now_ns()
        if self._pending_events or self._pending_done:
            # buffered events since the last consumer-driven tick drain
            # first, in production order — a cancellation's done=True and
            # everything a blocking submit() decoded precede what this
            # tick decodes — and their retirements count toward this
            # tick's finished return like any other
            events.extend(self._pending_events)
            self._pending_events.clear()
            finished.extend(self._pending_done)
            self._pending_done.clear()
        finished += self._retire()
        t_adm0 = now_ns()
        warming = self.prefill_pending
        admitted = self._admit(events)
        finished += self._retire()       # an admitted req may already be done
        if self.queue:
            # a request is waiting and no slot took it this tick (every
            # slot busy, or admission paused): the head-of-line signal
            self._admission_stalls += 1
        self._advance_prefills(events)
        # a deferred first token may have completed a max_new_tokens=1
        # request just now: retire it before the decode step so its slot
        # neither decodes a phantom token nor blocks a later admission
        finished += self._retire()
        t_adm1 = now_ns()
        if admitted or warming:
            # the admission-stall sample: time this tick spent on
            # admission work (prefill forward, warm replay, slot binding)
            # that the established slots' decode step had to wait behind
            self._h_stall.observe((t_adm1 - t_adm0) / 1e6)
        decoded = 0
        active = self.decode_mask
        if active.any():
            decoded = int(active.sum())
            logits, self.state = self.engine.decode_batch(
                self._next, self.state, active)
            params = [r.sampling if r is not None and tk is None else GREEDY
                      for r, tk in zip(self.slots, self._tickets)]
            if all(p.greedy for p in params):
                keys = None                   # greedy: skip key derivation
            else:
                counts = np.array([len(r.generated) if r is not None else 0
                                   for r in self.slots], np.int32)
                keys = fold_keys(self._bases, counts)
            # the sanctioned once-per-tick token drain: selected tokens
            # MUST reach the host to stream to callers and feed the next
            # step's input buffer — this is the tick's single sync point
            toks = np.asarray(jax.device_get(self.engine.select_tokens(  # reprolint: allow[RL002] once-per-tick token drain
                logits[:, 0], params, keys))).astype(np.int32)
            for t, req in enumerate(self.slots):
                if req is None or not active[t]:
                    continue
                self._append(req, int(toks[t]), events)
                self._next[t, 0] = toks[t]
        self._obs_tick(t0, t_adm0, t_adm1, admitted, warming, decoded)
        if self._debug_invariants and self.engine.kv_pool is not None:
            self.engine.kv_pool.check_invariants()
        return finished, events

    # -- trace drain helpers (the ONLY emission sites; see RL007) ----------
    def _obs_tick(self, t0: int, t_adm0: int, t_adm1: int, admitted: int,
                  warming: int, decoded: int) -> None:
        """Sanctioned drain point: the tick's step-phase spans, emitted
        after the tick's token drain from plain clock readings the tick
        collected along the way (reading the clock is not emission)."""
        t1 = now_ns()
        self.obs.complete("sched", "tick", t0, t1,
                          {"admitted": admitted, "warming": warming,
                           "decoded": decoded,
                           "queued": len(self.queue)})
        if admitted or warming:
            self.obs.complete("sched", "admission", t_adm0, t_adm1)
        if decoded:
            self.obs.complete("sched", "decode+drain", t_adm1, t1)

    def _obs_retire(self, reqs: Sequence[Request]) -> None:
        """Sanctioned drain point: each retired (or cancelled) request's
        lifecycle spans, emitted retroactively from its timing stamps —
        the queued / prefill / decode phases, the terminal instant, and
        the slot-occupancy span on the slot's own track."""
        for req in reqs:
            track = f"req:{req.rid}"
            end = req.t_done if req.t_done else now_ns()
            if req.t_admit:
                self.obs.complete(track, "queued", req.t_submit,
                                  req.t_admit)
                first = req.t_first if req.t_first else end
                self.obs.complete(
                    track, "prefill", req.t_admit, first,
                    {"prompt_tokens": int(req.prompt.shape[0])})
                if req.t_first:
                    self.obs.complete(
                        track, "decode", req.t_first, end,
                        {"tokens": len(req.generated),
                         "ttft_ms": (req.t_first - req.t_submit) / 1e6})
            else:
                # cancelled while still queued: its whole life was the
                # queue — there is no prefill or decode phase to cover
                self.obs.complete(track, "queued", req.t_submit, end)
            self.obs.instant(
                track, "cancelled" if req.cancelled else "done",
                {"generated": len(req.generated)}, ts_ns=end)
            if req.slot >= 0 and req.t_admit:
                self.obs.complete(f"slot:{req.slot}", "occupied",
                                  req.t_admit, end, {"rid": req.rid})

    def step(self) -> List[Request]:
        """One tick; returns the requests that finished on it."""
        finished, _ = self._tick()
        return finished

    def stream(self) -> Iterator[StreamEvent]:
        """Drain queue + slots, yielding ``(rid, token, done)`` the moment
        each token is decoded — a request's events arrive in generation
        order and its final event (and only that one) carries
        ``done=True``. Requests interleave exactly as the continuous batch
        decodes them. While admission is paused the queue cannot drain:
        stream() finishes the in-flight work and returns, leaving queued
        requests waiting for :meth:`resume_admission`."""
        while (self.queue and not self._paused) or self._pending_events \
                or any(s is not None for s in self.slots):
            _, events = self._tick()
            for ev in events:
                yield ev
        self._retire()

    def run(self) -> Dict[int, np.ndarray]:
        """Drain queue + slots to completion; returns {rid: output tokens}."""
        for _ in self.stream():
            pass
        return {r.rid: r.output for r in self.finished}

    @property
    def stats(self) -> RunStats:
        """Typed run statistics: request accounting + the admission
        channel + an immutable engine counter snapshot (rates
        zero-guarded on EngineStats)."""
        ttft, tpot, stall = self._h_ttft, self._h_tpot, self._h_stall
        return RunStats(engine=self.engine.stats,
                        requests_submitted=self._submitted,
                        requests_finished=len(self.finished),
                        requests_active=self.num_active,
                        requests_queued=len(self.queue),
                        prefill_pending=self.prefill_pending,
                        admission_stalls=self._admission_stalls,
                        queue_rejected=self._queue_rejected,
                        ttft_ms_p50=ttft.percentile(50.0),
                        ttft_ms_p95=ttft.percentile(95.0),
                        ttft_ms_p99=ttft.percentile(99.0),
                        tpot_ms_p50=tpot.percentile(50.0),
                        tpot_ms_p95=tpot.percentile(95.0),
                        tpot_ms_p99=tpot.percentile(99.0),
                        stall_ms_p50=stall.percentile(50.0),
                        stall_ms_p95=stall.percentile(95.0),
                        stall_ms_p99=stall.percentile(99.0))
