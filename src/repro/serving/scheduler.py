"""Continuous-batching request scheduler over the collaborative engine.

The paper's framework decodes one request at a time; production MoE
serving (HybriMoE, DAOP) interleaves many. This scheduler generalizes the
workflow to T = ``EngineConfig.max_batch`` concurrent *slots* over ONE
shared expert cache:

  * admission   — a queued request claims a free slot: its prompt is
                  prefilled (B=1) and the resulting KV state is scattered
                  into the slot's rows of the batch decode state.
  * decode tick — every step decodes the whole padded slot batch in one
                  jitted call; each slot sits at its own KV position
                  (per-slot ``pos`` vector) and inactive slots are masked
                  out of the shared expert cache, the stats and the output.
  * retirement  — a request finishes on ``max_new_tokens`` or ``eos_id``;
                  its slot frees immediately and the next queued request
                  is admitted on the same tick (continuous batching: the
                  batch never drains to refill).

Everything here is host-side orchestration (numpy + python lists) around
the engine's jitted primitives — the scheduler adds no traced code, so the
decode step compiles exactly once per (T, capacity) geometry.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import CollaborativeEngine

__all__ = ["Request", "ContinuousBatchingScheduler"]


@dataclass
class Request:
    """One generation request and its accumulated output."""
    rid: int
    prompt: np.ndarray                  # [P] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and len(self.generated) > 0
                and self.generated[-1] == self.eos_id)

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.generated, np.int32)


class ContinuousBatchingScheduler:
    """Slot-based continuous batching for :class:`CollaborativeEngine`.

    ``key`` seeds the sampling chain used when the engine's ``greedy`` is
    False (temperature sampling); one subkey is split off per decode tick
    and per admission, so scheduler runs are reproducible per seed."""

    def __init__(self, engine: CollaborativeEngine, key=None):
        self.engine = engine
        self.num_slots = engine.ecfg.max_batch
        self.state = engine.init_slots()
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self.queue: Deque[Request] = deque()
        self._next = np.zeros((self.num_slots, 1), np.int32)
        self._rid = 0
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.finished: List[Request] = []

    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> Request:
        req = Request(self._rid, np.asarray(prompt, np.int32).reshape(-1),
                      int(max_new_tokens), eos_id)
        self._rid += 1
        self.queue.append(req)
        return req

    # -- slot bookkeeping --------------------------------------------------
    @property
    def active_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], bool)

    @property
    def num_active(self) -> int:
        return int(self.active_mask.sum())

    def _retire(self) -> List[Request]:
        out = []
        for t, req in enumerate(self.slots):
            if req is not None and req.done:
                self.slots[t] = None
                out.append(req)
        self.finished.extend(out)
        return out

    def _admit(self) -> None:
        for t in range(self.num_slots):
            if self.slots[t] is None and self.queue:
                req = self.queue.popleft()
                first_tok, one_state = self.engine.prefill_request(
                    req.prompt, key=self._split())
                self.state = self.engine.write_slot(self.state, one_state, t)
                req.generated.append(first_tok)
                self._next[t, 0] = first_tok
                self.slots[t] = req

    # -- the decode loop ---------------------------------------------------
    def step(self) -> List[Request]:
        """One scheduler tick: retire -> admit -> one padded decode step.
        Returns the requests that finished on this tick."""
        finished = self._retire()
        self._admit()
        finished += self._retire()       # an admitted req may already be done
        active = self.active_mask
        if active.any():
            logits, self.state = self.engine.decode_batch(
                self._next, self.state, active)
            toks = np.asarray(jax.device_get(self.engine.select_tokens(
                logits[:, 0], key=self._split()))).astype(np.int32)
            for t, req in enumerate(self.slots):
                if req is None:
                    continue
                req.generated.append(int(toks[t]))
                self._next[t, 0] = toks[t]
        return finished

    def run(self) -> Dict[int, np.ndarray]:
        """Drain queue + slots to completion; returns {rid: output tokens}."""
        while self.queue or any(s is not None for s in self.slots):
            self.step()
        self._retire()
        return {r.rid: r.output for r in self.finished}

    @property
    def stats(self) -> Dict[str, float]:
        """Engine counters plus derived rates. Every division is guarded:
        a run that never decoded (zero accesses / zero predictions /
        prefetch disabled) reports 0.0 rates instead of dividing by
        zero."""
        s = dict(self.engine.stats)
        s["hit_rate"] = s["hits"] / max(s["accesses"], 1)
        s["prefetch_hit_rate"] = s["prefetch_hits"] / max(s["accesses"], 1)
        s["prediction_accuracy"] = (
            s["predicted_correct"] / max(s["predicted"], 1))
        s["prefetch_waste_rate"] = (
            s["prefetch_wasted"] / max(s["prefetch_issued"], 1))
        s["per_layer_hit_rates"] = self.engine.per_layer_hit_rates
        return s
