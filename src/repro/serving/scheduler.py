"""Continuous-batching request scheduler over the collaborative engine.

The paper's framework decodes one request at a time; production MoE
serving (HybriMoE, DAOP) interleaves many. This scheduler generalizes the
workflow to T = ``EngineConfig.max_batch`` concurrent *slots* over ONE
shared expert cache:

  * admission   — a queued request claims a free slot: its prompt is
                  prefilled (B=1, cache-warming chunked prefill by
                  default) and the resulting KV state is scattered into
                  the slot's rows of the batch decode state.
  * decode tick — every step decodes the whole padded slot batch in one
                  jitted call; each slot sits at its own KV position
                  (per-slot ``pos`` vector) and inactive slots are masked
                  out of the shared expert cache, the stats and the
                  output. Next tokens are drawn by the engine's
                  vectorized per-slot sampler, each row under its own
                  request's SamplingParams and PRNG chain.
  * retirement  — a request finishes on ``max_new_tokens``, ``eos_id`` or
                  one of its ``stop_sequences``; its slot frees
                  immediately and the next queued request is admitted on
                  the same tick (continuous batching: the batch never
                  drains to refill).
  * cancellation — :meth:`cancel` retires a queued or in-flight request
                  mid-decode: the slot frees for the next admission, a
                  terminal ``(rid, -1, done=True)`` event is emitted,
                  and no further tokens are decoded for it.

Callers observe tokens as they decode: :meth:`stream` yields
``(rid, token, done)`` events in emission order, and each request may
carry an ``on_token`` callback invoked at append time. Everything here is
host-side orchestration (numpy + python lists) around the engine's jitted
primitives — the scheduler adds no traced code, so the decode step
compiles exactly once per (T, capacity) geometry.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterator, List, Optional, \
    Sequence, Tuple

import jax
import numpy as np

from .engine import CollaborativeEngine, _one_prompt
from .sampling import GREEDY, SamplingParams, fold_keys, request_key
from .stats import RunStats

__all__ = ["Request", "ContinuousBatchingScheduler", "StreamEvent"]

StreamEvent = Tuple[int, int, bool]          # (rid, token, done)


@dataclass
class Request:
    """One generation request: prompt, per-request sampling, termination
    conditions, optional streaming callback, and accumulated output."""
    rid: int
    prompt: np.ndarray                  # [P] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: SamplingParams = GREEDY
    stop_sequences: Tuple[Tuple[int, ...], ...] = ()
    on_token: Optional[Callable[[int, bool], None]] = None
    generated: List[int] = field(default_factory=list)
    cancelled: bool = False

    @property
    def done(self) -> bool:
        if self.cancelled:
            return True
        if len(self.generated) >= self.max_new_tokens:
            return True
        if not self.generated:
            return False
        if self.eos_id is not None and self.generated[-1] == self.eos_id:
            return True
        for seq in self.stop_sequences:
            n = len(seq)
            if n and len(self.generated) >= n \
                    and tuple(self.generated[-n:]) == tuple(seq):
                return True
        return False

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.generated, np.int32)


class ContinuousBatchingScheduler:
    """Slot-based continuous batching for :class:`CollaborativeEngine`.

    ``key`` seeds the fallback per-request sampling chains (requests whose
    SamplingParams carry no explicit ``seed``); a request's i-th token
    always draws from ``fold_in(request_base, i)``, so runs are
    reproducible per (scheduler seed, admission order) and — for
    explicitly seeded requests — per request, independent of batch
    composition."""

    def __init__(self, engine: CollaborativeEngine, key=None):
        self.engine = engine
        self.num_slots = engine.ecfg.max_batch
        self.state = engine.init_slots()
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self.queue: Deque[Request] = deque()
        self._next = np.zeros((self.num_slots, 1), np.int32)
        self._rid = 0
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._bases = np.zeros((self.num_slots, 2), np.uint32)
        self.finished: List[Request] = []
        self._submitted = 0
        self._cancel_events: List[StreamEvent] = []
        self._cancel_done: List[Request] = []

    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stop_sequences: Sequence[Sequence[int]] = (),
               on_token: Optional[Callable[[int, bool], None]] = None
               ) -> Request:
        """Queue one request. Validates the prompt against the engine
        geometry here — at submission — so an oversized request fails
        fast with a clear error instead of mid-run after other requests
        already decoded."""
        prompt = _one_prompt(prompt)[0]      # [P]; rejects [B, P] batches
        plen, cap = prompt.shape[0], self.engine.ecfg.capacity
        if plen < 1:
            raise ValueError("prompt must contain at least one token")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if plen + max_new_tokens > cap:
            raise ValueError(
                f"prompt length {plen} + max_new_tokens {max_new_tokens} "
                f"exceeds engine KV capacity {cap}; shorten the prompt or "
                f"raise EngineConfig.capacity")
        req = Request(self._rid, prompt, int(max_new_tokens), eos_id,
                      sampling if sampling is not None else GREEDY,
                      tuple(tuple(int(t) for t in s)
                            for s in stop_sequences),
                      on_token)
        self._rid += 1
        self._submitted += 1
        self.queue.append(req)
        return req

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request mid-decode.

        An in-flight request's slot frees IMMEDIATELY — the next tick's
        admission can hand it to a waiting request without the cancelled
        one decoding another token. The request retires with a terminal
        ``(rid, -1, done=True)`` stream event, delivered ahead of the
        next tick's events (-1, never a real token: every generated
        token was already streamed exactly once); its ``on_token``
        callback fires once more with ``(-1, True)``. Returns True if
        the request was found live (queued or in a slot), False if
        unknown or already finished — cancelling is idempotent and never
        raises."""
        req = None
        for r in self.queue:
            if r.rid == rid:
                req = r
                self.queue.remove(r)
                break
        if req is None:
            for t, r in enumerate(self.slots):
                if r is not None and r.rid == rid:
                    if r.done:
                        # finished on the last tick, awaiting retirement:
                        # its terminal done=True event already streamed —
                        # emitting a second one would break the
                        # one-terminal-event contract
                        return False
                    req = r
                    self.slots[t] = None          # slot free for admission
                    break
        if req is None:
            return False
        req.cancelled = True                      # done; rejects new tokens
        self.finished.append(req)
        self._cancel_done.append(req)             # next _tick reports it
        self._cancel_events.append((req.rid, -1, True))
        if req.on_token is not None:
            req.on_token(-1, True)
        return True

    # -- slot bookkeeping --------------------------------------------------
    @property
    def active_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], bool)

    @property
    def num_active(self) -> int:
        return int(self.active_mask.sum())

    def _retire(self) -> List[Request]:
        out = []
        for t, req in enumerate(self.slots):
            if req is not None and req.done:
                self.slots[t] = None
                out.append(req)
        self.finished.extend(out)
        return out

    def _append(self, req: Request, tok: int,
                events: List[StreamEvent]) -> None:
        req.generated.append(tok)
        done = req.done
        events.append((req.rid, tok, done))
        if req.on_token is not None:
            req.on_token(tok, done)

    def _admit(self, events: List[StreamEvent]) -> None:
        for t in range(self.num_slots):
            if self.slots[t] is None and self.queue:
                req = self.queue.popleft()
                base = request_key(req.sampling, self._split())
                self._bases[t] = base
                first_tok, one_state = self.engine.prefill_request(
                    req.prompt, sampling=req.sampling,
                    key=jax.random.fold_in(base, 0))
                self.state = self.engine.write_slot(self.state, one_state, t)
                # claim the slot BEFORE the first-token callback fires so
                # an on_token handler that calls cancel() finds the
                # request live (cancel then frees the slot right here)
                self._next[t, 0] = first_tok
                self.slots[t] = req
                self._append(req, first_tok, events)

    # -- the decode loop ---------------------------------------------------
    def _tick(self) -> Tuple[List[Request], List[StreamEvent]]:
        """One scheduler tick: retire -> admit -> one padded decode step.
        Returns (requests finished this tick, stream events in order)."""
        events: List[StreamEvent] = []
        finished: List[Request] = []
        if self._cancel_events:
            # terminal events of cancellations since the last tick drain
            # first — a cancelled request's done=True precedes everything
            # the tick decodes — and the cancelled requests count toward
            # this tick's finished return like any other retirement
            events.extend(self._cancel_events)
            self._cancel_events.clear()
            finished.extend(self._cancel_done)
            self._cancel_done.clear()
        finished += self._retire()
        self._admit(events)
        finished += self._retire()       # an admitted req may already be done
        active = self.active_mask
        if active.any():
            logits, self.state = self.engine.decode_batch(
                self._next, self.state, active)
            params = [r.sampling if r is not None else GREEDY
                      for r in self.slots]
            if all(p.greedy for p in params):
                keys = None                   # greedy: skip key derivation
            else:
                counts = np.array([len(r.generated) if r is not None else 0
                                   for r in self.slots], np.int32)
                keys = fold_keys(self._bases, counts)
            toks = np.asarray(jax.device_get(self.engine.select_tokens(
                logits[:, 0], params, keys))).astype(np.int32)
            for t, req in enumerate(self.slots):
                if req is None:
                    continue
                self._append(req, int(toks[t]), events)
                self._next[t, 0] = toks[t]
        return finished, events

    def step(self) -> List[Request]:
        """One tick; returns the requests that finished on it."""
        finished, _ = self._tick()
        return finished

    def stream(self) -> Iterator[StreamEvent]:
        """Drain queue + slots, yielding ``(rid, token, done)`` the moment
        each token is decoded — a request's events arrive in generation
        order and its final event (and only that one) carries
        ``done=True``. Requests interleave exactly as the continuous batch
        decodes them."""
        while self.queue or self._cancel_events \
                or any(s is not None for s in self.slots):
            _, events = self._tick()
            for ev in events:
                yield ev
        self._retire()

    def run(self) -> Dict[int, np.ndarray]:
        """Drain queue + slots to completion; returns {rid: output tokens}."""
        for _ in self.stream():
            pass
        return {r.rid: r.output for r in self.finished}

    @property
    def stats(self) -> RunStats:
        """Typed run statistics: request accounting + an immutable engine
        counter snapshot (rates zero-guarded on EngineStats)."""
        return RunStats(engine=self.engine.stats,
                        requests_submitted=self._submitted,
                        requests_finished=len(self.finished),
                        requests_active=self.num_active,
                        requests_queued=len(self.queue))
