"""Global paged KV pool: fixed-size pages, refcounts, copy-on-write and
a hash prefix index (ROADMAP's "paged KV cache with prefix sharing").

Dense serving reserves ``capacity`` KV slots per scheduler slot for the
request's whole lifetime — worst-case memory, zero sharing. The pool
replaces that with the vLLM/flashinfer paging idiom (SNIPPETS.md
Snippet 1): KV lives in ONE ``[num_pages, page_size, ...]`` tensor per
layer and a request holds an ordered list of physical page ids — its
*page table*, exported in CSR form as ``page_indptr`` / ``page_indices``
/ ``last_page_len``. Three mechanisms ride on the indirection:

  * refcounting + copy-on-write — a physical page may back several
    requests at once. Full pages are immutable while shared, so prefix
    sharing never copies anything; only :meth:`fork` (cloning a live
    request mid-generation) can leave a *partial* page shared, and the
    first side to append then copies it (:meth:`prepare_append` returns
    the copy plan; the engine performs the device copy).
  * prefix index — every full-page prompt prefix is registered under a
    hash of its tokens; a new request whose prompt starts with an
    indexed prefix adopts those pages (refcount bump, zero KV writes)
    and the engine skips the cache-warming replay for the shared span.
    Entries invalidate lazily: each page carries an epoch bumped when it
    returns to the free list, and lookups revalidate epochs.
  * commitment accounting — admission promises a request every page it
    could ever need (``ceil(total_tokens / page_size)`` minus what the
    prefix supplied). ``committed`` pages are subtracted from
    :meth:`available`, so an admitted request can always append inside
    its budget — decode never deadlocks on page exhaustion mid-request,
    and :meth:`can_admit` is the scheduler's backpressure signal.
  * eviction-aware prefix retention — with ``prefix_keep_pages > 0``, a
    retiring request's zero-ref pages that still back a live prefix-index
    entry park in a bounded LRU instead of returning to the free list
    (vLLM's cached-prefix idiom): their epochs stay valid, so a RAG-burst
    re-admission adopts them by reference. Retained pages are reclaimable
    — :meth:`available` counts them, and an allocation that outgrows the
    free list evicts the least-recently-retired first (epoch bump, index
    entries lazily invalidate).

Everything here is host-side bookkeeping (python lists + small numpy
arrays); the engine owns the device tensors and consumes page ids.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["KVPagePool", "PageTable", "AppendPlan", "PoolExhausted"]


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list
    net of commitments — the paged equivalent of a full batch."""


@dataclass(eq=False)
class PageTable:
    """One request's view of the pool: ordered physical page ids plus the
    token count written so far and the pages still committed to it.
    Identity semantics — tables are keys in the pool's live set."""
    page_size: int
    pages: List[int]
    length: int                   # tokens written
    budget: int                   # pages still reserved for this table
    shared_tokens: int = 0        # prefix-index tokens adopted at alloc
    alive: bool = True

    @property
    def last_page_len(self) -> int:
        """Tokens held by the last page (flashinfer's ``last_page_len``)."""
        return self.length - (len(self.pages) - 1) * self.page_size


@dataclass(frozen=True)
class AppendPlan:
    """Where the next token's KV goes. ``cow_src`` set means the page was
    shared: the engine must copy page ``cow_src`` -> ``page`` on device
    before writing (copy-on-write)."""
    page: int                     # physical destination page
    slot: int                     # offset inside the page
    cow_src: Optional[int] = None


class KVPagePool:
    """Fixed-size page allocator with refcounts, CoW and a prefix index."""

    def __init__(self, num_pages: int, page_size: int,
                 prefix_keep_pages: int = 0):
        if num_pages < 1 or page_size < 1:
            raise ValueError(
                f"pool needs >= 1 page of >= 1 token, got "
                f"num_pages={num_pages}, page_size={page_size}")
        if prefix_keep_pages < 0:
            raise ValueError(
                f"prefix_keep_pages must be >= 0, got {prefix_keep_pages}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.prefix_keep_pages = prefix_keep_pages
        # stack popped from the end: pages hand out in 0, 1, 2, ... order
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref = np.zeros(num_pages, np.int32)
        self._epoch = np.zeros(num_pages, np.int64)
        # epoch at which page p last backed an index registration; equal
        # to _epoch[p] iff some index entry may still name it
        self._indexed_epoch = np.full(num_pages, -1, np.int64)
        # zero-ref prefix pages kept alive past their last sharer, oldest
        # retirement first (dict preserves insertion order)
        self._retained: Dict[int, None] = {}
        self._committed = 0
        self._tables: set = set()
        # prompt[:n*page_size].tobytes() -> (page ids, their epochs)
        self._index: Dict[bytes, Tuple[Tuple[int, ...], Tuple[int, ...]]] = {}
        self.prefix_hits = 0
        self.prefix_tokens_shared = 0
        self.cow_forks = 0
        self.peak_pages_in_use = 0
        # cumulative retained-page reclaims (LRU evictions): the ledger
        # the trace's eviction instants are derived from at drain points
        self.retention_evictions = 0

    # -- geometry ----------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free) - len(self._retained)

    @property
    def prefix_pages_retained(self) -> int:
        """Zero-ref prefix pages parked in the retention LRU."""
        return len(self._retained)

    @property
    def available(self) -> int:
        """Pages an admission may claim: free plus reclaimable retained,
        minus already-committed."""
        return len(self._free) + len(self._retained) - self._committed

    # -- internal page plumbing --------------------------------------------
    def _evict_retained(self) -> int:
        """Reclaim the least-recently-retired retained page: its epoch
        bump lazily invalidates any index entry naming it."""
        p = next(iter(self._retained))
        del self._retained[p]
        self._epoch[p] += 1
        self.retention_evictions += 1
        return p

    def _take(self) -> int:
        if self._free:
            p = self._free.pop()
        elif self._retained:
            p = self._evict_retained()
        else:
            raise PoolExhausted("KV page free list is empty")
        self._ref[p] = 1
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)
        return p

    @staticmethod
    def _tokens(prompt) -> np.ndarray:
        prompt = np.ascontiguousarray(np.asarray(prompt, np.int32))
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be [P], got {prompt.shape}")
        return prompt

    # -- prefix index ------------------------------------------------------
    def _match(self, prompt: np.ndarray) -> Tuple[List[int], int]:
        """Longest indexed full-page prefix of ``prompt`` whose pages are
        all still live (epoch unchanged since registration). Stale
        entries met along the way are dropped."""
        ps = self.page_size
        for n in range(len(prompt) // ps, 0, -1):
            key = prompt[:n * ps].tobytes()
            entry = self._index.get(key)
            if entry is None:
                continue
            pages, epochs = entry
            if all(self._epoch[p] == e for p, e in zip(pages, epochs)):
                return list(pages), n * ps
            del self._index[key]
        return [], 0

    def register(self, prompt, table: PageTable) -> None:
        """Index every full-page prefix of ``prompt`` against the table's
        leading physical pages. Call AFTER the KV is written to them —
        a lookup may adopt the pages on the very next admission. The
        partial last page (if any) is never indexed: decode appends
        mutate it."""
        prompt = self._tokens(prompt)
        ps = self.page_size
        for n in range(1, len(prompt) // ps + 1):
            pages = tuple(table.pages[:n])
            self._index[prompt[:n * ps].tobytes()] = (
                pages, tuple(int(self._epoch[p]) for p in pages))
            for p in pages:
                self._indexed_epoch[p] = self._epoch[p]
        if len(self._index) > 4 * self.num_pages:
            self._index = {
                k: (pgs, eps) for k, (pgs, eps) in self._index.items()
                if all(self._epoch[p] == e for p, e in zip(pgs, eps))}

    # -- request lifecycle -------------------------------------------------
    def can_admit(self, prompt, total_tokens: int) -> bool:
        """Would :meth:`alloc_prompt` succeed right now? (Admission
        capacity is a function of free pages and prefix hits, not slot
        count.)"""
        prompt = self._tokens(prompt)
        shared_pages, _ = self._match(prompt)
        need = self.pages_for(total_tokens) - len(shared_pages)
        return need <= self.available

    def alloc_prompt(self, prompt,
                     total_tokens: int) -> Tuple[PageTable, int]:
        """Claim pages for a prompt plus a committed budget through
        ``total_tokens`` (prompt + max new tokens). An indexed prefix
        supplies its pages by reference (no writes, no budget). Returns
        ``(table, shared_tokens)``."""
        prompt = self._tokens(prompt)
        P = len(prompt)
        if P < 1:
            raise ValueError("prompt must contain at least one token")
        if total_tokens < P:
            raise ValueError(
                f"total_tokens {total_tokens} < prompt length {P}")
        shared_pages, shared_toks = self._match(prompt)
        need_now = self.pages_for(P) - len(shared_pages)
        budget = self.pages_for(total_tokens) - self.pages_for(P)
        if need_now + budget > self.available:
            raise PoolExhausted(
                f"prompt needs {need_now} pages + {budget} committed, "
                f"pool has {self.available} available "
                f"({len(self._free)} free - {self._committed} committed)")
        for p in shared_pages:
            self._ref[p] += 1
            # a retained page's first new sharer revives it from the LRU
            if self._ref[p] == 1:
                self._retained.pop(p, None)
        pages = shared_pages + [self._take() for _ in range(need_now)]
        self._committed += budget
        table = PageTable(page_size=self.page_size, pages=pages, length=P,
                          budget=budget, shared_tokens=shared_toks)
        self._tables.add(table)
        if shared_toks:
            self.prefix_hits += 1
            self.prefix_tokens_shared += shared_toks
        return table, shared_toks

    def prepare_append(self, table: PageTable) -> AppendPlan:
        """Plan the write of token ``table.length`` (the engine writes
        the KV on device, then calls :meth:`commit_append`). Draws a
        fresh page from the table's budget at a page boundary, and
        copy-on-writes a shared partial last page. Idempotent until the
        commit — a step retried after a crash never double-allocates."""
        if not table.alive:
            raise RuntimeError("append on a freed page table")
        ps = self.page_size
        pos = table.length
        if len(table.pages) < pos // ps + 1:     # page boundary: grow
            if table.budget < 1:
                raise PoolExhausted(
                    "append beyond the table's committed budget")
            p = self._take()
            table.budget -= 1
            self._committed -= 1
            table.pages.append(p)
            return AppendPlan(page=p, slot=pos % ps)
        last = table.pages[-1]
        if self._ref[last] > 1:                  # shared partial page: CoW
            if table.budget < 1:
                raise PoolExhausted(
                    "copy-on-write beyond the table's committed budget")
            p = self._take()
            table.budget -= 1
            self._committed -= 1
            self._ref[last] -= 1
            table.pages[-1] = p
            self.cow_forks += 1
            return AppendPlan(page=p, slot=pos % ps, cow_src=last)
        return AppendPlan(page=last, slot=pos % ps)

    def commit_append(self, table: PageTable) -> None:
        """The planned token's KV is on device: account for it."""
        if not table.alive:
            raise RuntimeError("commit on a freed page table")
        table.length += 1

    def fork(self, table: PageTable, total_tokens: int) -> PageTable:
        """Clone a live table copy-on-write: the child references every
        physical page (zero copies now). A partial last page becomes
        shared-mutable, so BOTH sides gain +1 budget as a CoW reserve —
        whichever appends first copies; the other side's unused reserve
        returns at :meth:`free`."""
        if not table.alive:
            raise RuntimeError("fork of a freed page table")
        if total_tokens < table.length:
            raise ValueError(
                f"total_tokens {total_tokens} < forked length "
                f"{table.length}")
        reserve = 1 if table.length % self.page_size else 0
        child_budget = self.pages_for(total_tokens) \
            - self.pages_for(table.length)
        if child_budget + 2 * reserve > self.available:
            raise PoolExhausted(
                f"fork needs {child_budget + 2 * reserve} committed "
                f"pages, pool has {self.available} available")
        for p in table.pages:
            self._ref[p] += 1
        child = PageTable(page_size=self.page_size,
                          pages=list(table.pages), length=table.length,
                          budget=child_budget + reserve)
        table.budget += reserve
        self._committed += child_budget + 2 * reserve
        self._tables.add(child)
        return child

    def free(self, table: PageTable) -> None:
        """Release a table: refcounts drop, zero-ref pages return to the
        free list (their epoch bump lazily invalidates index entries),
        unused budget returns to the admission pool. With retention on,
        zero-ref pages that still back a live index entry park in the
        retention LRU instead (epoch untouched, so the prefix stays
        adoptable); pages deepest in the prompt retire as the coldest so
        trimming preserves the shortest (most reusable) prefixes longest.
        Raises on a second free of the same table."""
        if not table.alive:
            raise RuntimeError("page table already freed")
        table.alive = False
        self._tables.discard(table)
        self._committed -= table.budget
        table.budget = 0
        for p in reversed(table.pages):
            self._ref[p] -= 1
            if self._ref[p] == 0:
                if self.prefix_keep_pages > 0 \
                        and self._indexed_epoch[p] == self._epoch[p]:
                    self._retained[p] = None
                else:
                    self._epoch[p] += 1
                    self._free.append(p)
        while len(self._retained) > self.prefix_keep_pages:
            self._free.append(self._evict_retained())
        table.pages = []

    # -- views / self-checks ----------------------------------------------
    def page_table_arrays(self, tables: Sequence[PageTable]
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR page tables for a request batch — the flashinfer layout
        the Pallas paged kernel consumes: ``(page_indptr [B+1],
        page_indices [sum pages], last_page_len [B])``."""
        indptr = np.zeros(len(tables) + 1, np.int32)
        for i, t in enumerate(tables):
            indptr[i + 1] = indptr[i] + len(t.pages)
        indices = np.concatenate(
            [np.asarray(t.pages, np.int32) for t in tables]) \
            if tables else np.zeros(0, np.int32)
        lastlen = np.array([t.last_page_len for t in tables], np.int32)
        return indptr, indices, lastlen

    def check_invariants(self) -> None:
        """Every page is free XOR retained XOR referenced, refcounts
        equal the live tables' usage, the free list holds no duplicates,
        and commitments never exceed the reclaimable pages. The
        hypothesis property test drives this after every operation."""
        ref = np.zeros(self.num_pages, np.int64)
        for t in self._tables:
            assert t.alive, "freed table still registered live"
            assert 0 < t.length <= len(t.pages) * self.page_size, \
                (t.length, len(t.pages))
            assert t.budget >= 0
            for p in t.pages:
                ref[p] += 1
        assert (ref == self._ref).all(), "refcount drift"
        assert len(set(self._free)) == len(self._free), "double-freed page"
        assert all(self._ref[p] == 0 for p in self._free), \
            "referenced page on the free list"
        assert len(self._retained) <= self.prefix_keep_pages, \
            "retention LRU over its bound"
        assert not set(self._retained) & set(self._free), \
            "page both free and retained"
        assert all(self._ref[p] == 0 for p in self._retained), \
            "referenced page in the retention LRU"
        assert all(self._indexed_epoch[p] == self._epoch[p]
                   for p in self._retained), "retained page not indexed"
        assert len(self._free) + len(self._retained) \
            + int((self._ref > 0).sum()) == self.num_pages, "leaked pages"
        assert self._committed == sum(t.budget for t in self._tables), \
            "commitment drift"
        assert 0 <= self._committed <= len(self._free) \
            + len(self._retained), "over-committed"
