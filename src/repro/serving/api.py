"""One-call construction of the collaborative serving stack.

Every driver used to hand-assemble ``CacheConfig`` + ``EngineConfig`` +
``init_params`` + engine + scheduler slightly differently; :func:`build`
is the single front door: resolve the (reduced) architecture, derive
sensible cache defaults from it, initialize parameters, and return the
``(engine, scheduler)`` pair ready to ``submit()`` / ``stream()`` /
``run()``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax

from repro.config import CacheConfig, ModelConfig, get_config, reduced
from repro.models import init_params

from .engine import CollaborativeEngine, EngineConfig
from .scheduler import ContinuousBatchingScheduler

__all__ = ["build"]


def build(arch: Union[str, ModelConfig], *,
          cache: Union[None, CacheConfig, Dict] = None,
          serving: Union[None, EngineConfig, Dict] = None,
          seed: int = 0,
          params=None,
          reduce: bool = True,
          max_queue: Optional[int] = None,
          recorder=None
          ) -> Tuple[CollaborativeEngine, ContinuousBatchingScheduler]:
    """Build the collaborative engine + continuous-batching scheduler.

    arch    — architecture id (``"mixtral-8x7b"``) or a ModelConfig. A
              ModelConfig is used AS-IS (the caller already chose its
              geometry — and its ``params`` must match it); ``reduce``
              only applies when resolving an arch id.
    cache   — CacheConfig, or a dict of overrides on the default
              ``CacheConfig(num_indexes=num_layers, num_ways=2, "lru")``.
    serving — EngineConfig (its ``cache`` is replaced when ``cache`` is
              also given), or a dict of EngineConfig overrides
              (``max_batch`` / ``capacity`` / ``prefetch`` /
              ``prefill_chunk`` / ``admit_chunks_per_tick``).
    seed    — seeds parameter init, static cache placement and the
              scheduler's fallback sampling chains.
    params  — pre-initialized parameters (skips ``init_params``).
    reduce  — apply :func:`repro.config.reduced` (the CPU-container
              geometry) to arch-id lookups; pass False to serve the full
              config.
    max_queue — bound the scheduler's waiting line (None = unbounded);
              a full queue makes ``submit(..., block=False)`` raise
              :class:`~repro.serving.scheduler.QueueFull`.
    recorder — a :class:`repro.obs.TraceRecorder` to wire through the
              engine AND scheduler (request-lifecycle + step-phase
              tracing); None serves untraced with the no-op recorder.

    Returns ``(engine, scheduler)``.
    """
    if isinstance(arch, str):
        cfg = get_config(arch)
        if reduce:
            cfg = reduced(cfg)
    else:
        cfg = arch
    if cfg.moe is None or cfg.moe_every != 1 or cfg.is_encdec:
        raise ValueError(
            f"{cfg.name}: collaborative serving needs a homogeneous "
            f"decoder-only MoE stack (every layer MoE); use the generic "
            f"path in repro.launch.serve for other archs")

    if isinstance(cache, CacheConfig):
        ccfg = cache
    else:
        opts = dict(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
        opts.update(cache or {})
        ccfg = CacheConfig(**opts)

    if isinstance(serving, EngineConfig):
        ecfg = dataclasses.replace(serving, cache=ccfg) if cache is not None \
            else serving
    else:
        ecfg = EngineConfig(cache=ccfg, **(serving or {}))

    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_params(cfg, key)
    engine = CollaborativeEngine(cfg, params, ecfg, key=key,
                                 recorder=recorder)
    scheduler = ContinuousBatchingScheduler(
        engine, key=jax.random.fold_in(key, 1), max_queue=max_queue,
        recorder=recorder)
    return engine, scheduler
