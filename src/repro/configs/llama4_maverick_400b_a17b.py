"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048. MoE: 128 experts top-1 + 1 shared expert, interleaved with
dense FFN layers (early-fusion multimodal backbone).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.config import ModelConfig, MoEConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,  # dense FFN on non-MoE layers
        vocab_size=202048,
        moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192, num_shared_experts=1),
        moe_every=2,   # interleaved: every other layer is MoE
        moe_offset=1,
        rope_theta=500_000.0,
        max_seq_len=131072,
    )
