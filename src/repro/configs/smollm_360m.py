"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

Llama-architecture small model. [hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.config import ModelConfig, register


@register("smollm-360m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
        max_seq_len=8192,
    )
