# Architecture configs. Each module registers itself with
# repro.config.registry; use repro.config.get_config("<arch-id>").
