"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536. Mamba:attention 7:1 interleave; MoE 16 experts top-2 on every
other layer. [arXiv:2403.19887; hf]
"""
from repro.config import ModelConfig, MoEConfig, SSMConfig, register


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,   # dense FFN on non-MoE layers (and per-expert d_ff)
        vocab_size=65536,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336),
        moe_every=2,
        moe_offset=1,
        # 1 attention layer per 8 (position 4 of each period, as in Jamba)
        layer_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64),
        max_seq_len=262144,
    )
