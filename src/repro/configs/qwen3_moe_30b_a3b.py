"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936.

MoE: 128 experts, top-8, per-expert d_ff=768. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.config import ModelConfig, MoEConfig, register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=0,  # every FFN is MoE
        vocab_size=151936,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff=768),
        rope_theta=1_000_000.0,
        max_seq_len=131072,
    )
