"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128. SSD (state-space duality) blocks. [arXiv:2405.21060; unverified]
"""
from repro.config import ModelConfig, SSMConfig, register


@register("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        layer_pattern=("mamba",),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
        tie_embeddings=True,
        max_seq_len=1048576,
    )
