"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (3D temporal/height/width rotary), dynamic resolution. The vision
frontend is a STUB — inputs include precomputed patch embeddings via
input_specs(). [arXiv:2409.12191; hf]
"""
from repro.config import ModelConfig, register


@register("qwen2-vl-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        mrope=True,
        frontend_embed_dim=1280,   # precomputed vision patch embeddings
        rope_theta=1_000_000.0,
        max_seq_len=32768,
    )
