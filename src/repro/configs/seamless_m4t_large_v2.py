"""seamless-m4t-large-v2 [audio]: enc-dec, 24L each, d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206. Multimodal; the speech frontend is a STUB — inputs
are precomputed frame embeddings via input_specs(). [arXiv:2308.11596; hf]
"""
from repro.config import ModelConfig, register


@register("seamless-m4t-large-v2")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,          # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,        # MHA (no GQA)
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        frontend_embed_dim=1024,  # precomputed audio frame embeddings
        max_seq_len=8192,
    )
