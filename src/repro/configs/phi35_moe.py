"""phi35-moe [moe] — the paper's second evaluation model.

32L d_model=4096 32H (GQA kv=8) vocab=32064; MoE 16 experts top-2,
per-expert d_ff=6400 (152 MB/expert bf16). [arXiv:2404.14219]
"""
from repro.config import ModelConfig, MoEConfig, register


@register("phi35-moe")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi35-moe",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=0,
        vocab_size=32064,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400),
        max_seq_len=131072,
    )
