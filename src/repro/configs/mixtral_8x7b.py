"""mixtral-8x7b [moe] — the paper's primary evaluation model.

32L d_model=4096 32H (GQA kv=8) vocab=32000; MoE 8 experts top-2,
per-expert d_ff=14336 (340 MB/expert bf16). [arXiv:2401.04088]
"""
from repro.config import ModelConfig, MoEConfig, register


@register("mixtral-8x7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=0,
        vocab_size=32000,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=14336),
        rope_theta=1_000_000.0,
        max_seq_len=32768,
    )
