"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local(sliding-window 1024):global attention interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.config import ModelConfig, register


@register("gemma3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        window_pattern=(1024, 1024, 1024, 1024, 1024, -1),  # 5 local : 1 global
        rope_theta=1_000_000.0,
        logit_softcap=30.0,
        tie_embeddings=True,
        max_seq_len=131072,
    )
