"""Beyond-paper: hot-expert replication cache for expert parallelism.

At multi-pod scale the paper's memory relation recurs one level up: each
device's *local HBM* holds only its EP shard of experts (E/ep_degree);
tokens routed to remote experts pay ICI all-to-all — the analogue of the
paper's PCIe fetch. The same cache mathematics applies:

  * each device keeps an LRU cache of M_hot *remote* experts, refreshed
    from batch-level routing statistics (the paper's Consecutive-Tokens
    locality becomes step-over-step skew locality of the batch);
  * a token whose expert is local-or-cached computes locally; only true
    misses cross the ICI;
  * cache refresh (the post-fetch) is an all-gather of the newly-hot
    experts' weights, overlapped with the next step's attention compute.

This module provides the planning/accounting layer (which experts to
replicate, the dispatch split, the saved all-to-all bytes) as pure
functions over routing counts — exercised by unit tests and the serve
driver; the collective itself is GSPMD's when the plan's sharding is
applied. The measured win is reported in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class EPCachePlan:
    """Per-device replication decision for one refresh interval."""
    hot_experts: np.ndarray        # [ep_degree, M_hot] expert ids per shard
    local_fraction: float          # tokens served locally after replication
    a2a_bytes_baseline: int
    a2a_bytes_with_cache: int
    refresh_bytes: int             # weight all-gather cost of the refresh

    @property
    def traffic_ratio(self) -> float:
        total = self.a2a_bytes_with_cache + self.refresh_bytes
        return total / max(self.a2a_bytes_baseline, 1)


def home_shard(expert: np.ndarray, num_experts: int, ep: int) -> np.ndarray:
    """Contiguous EP placement: expert e lives on shard e // (E/ep)."""
    return expert // (num_experts // ep)


def plan_replication(counts: np.ndarray, ep_degree: int, m_hot: int,
                     expert_bytes: int, token_bytes: int,
                     prev_hot: np.ndarray | None = None) -> EPCachePlan:
    """Plan hot-expert replication from a step's routing counts.

    counts: [T_shards..., E] or [E] aggregate token counts per expert
    (from the router; already available on every device after the step).
    ep_degree: EP mesh size. m_hot: replication slots per device.
    token_bytes: bytes of one token's activation row (D * dtype).
    """
    counts = counts.reshape(-1, counts.shape[-1]).sum(axis=0)   # [E]
    E = counts.shape[0]
    assert E % ep_degree == 0
    per = E // ep_degree
    total_tokens = int(counts.sum())

    # baseline: every token for a non-local expert crosses the wire
    # (uniform token origin over shards -> (ep-1)/ep of traffic is remote)
    remote_frac = (ep_degree - 1) / ep_degree
    baseline = int(2 * total_tokens * remote_frac * token_bytes)  # there+back

    hot = np.zeros((ep_degree, m_hot), np.int64)
    served_locally = counts.astype(np.float64) / ep_degree  # home shard share
    extra_local = 0.0
    refresh = 0
    for shard in range(ep_degree):
        own = np.arange(shard * per, (shard + 1) * per)
        remote = np.setdiff1d(np.arange(E), own)
        order = remote[np.argsort(-counts[remote])]
        pick = order[:m_hot]
        hot[shard] = pick
        # replicated experts serve this shard's tokens locally
        extra_local += counts[pick].sum() / ep_degree
        if prev_hot is not None:
            new = np.setdiff1d(pick, prev_hot[shard])
            refresh += int(len(new)) * expert_bytes
        else:
            refresh += m_hot * expert_bytes

    local_tokens = counts.sum() / ep_degree + extra_local
    local_frac = float(min(local_tokens / max(total_tokens, 1), 1.0))
    with_cache = int(baseline * max(0.0, 1 - (local_frac - 1 / ep_degree)
                                    / max(remote_frac, 1e-9)))
    return EPCachePlan(hot_experts=hot, local_fraction=local_frac,
                       a2a_bytes_baseline=baseline,
                       a2a_bytes_with_cache=with_cache,
                       refresh_bytes=refresh)


def simulate_ep_cache(trace: np.ndarray, ep_degree: int, m_hot: int,
                      expert_bytes: int, token_bytes: int,
                      refresh_every: int = 1) -> Tuple[float, float]:
    """Replay a routing trace [T, L, K]; returns (mean local fraction,
    mean traffic ratio vs baseline all-to-all)."""
    T, L, K = trace.shape
    E = int(trace.max()) + 1
    prev = None
    fracs, ratios = [], []
    for t in range(0, T, max(refresh_every, 1)):
        window = trace[t: t + refresh_every]
        counts = np.zeros(E, np.int64)
        np.add.at(counts, window.reshape(-1), 1)
        plan = plan_replication(counts, ep_degree, m_hot, expert_bytes,
                                token_bytes, prev_hot=prev)
        prev = plan.hot_experts
        fracs.append(plan.local_fraction)
        ratios.append(plan.traffic_ratio)
    return float(np.mean(fracs)), float(np.mean(ratios))
