"""The paper's contribution: two-tier collaborative MoE inference with a
set-associative expert cache and asynchronous post-fetch."""
from .cache import CacheState, access, init_cache_state, lookup, slot_id
from .collaborative import ExpertTiers, collaborative_moe, init_tiers
from .policies import NumpyCache, random_policy_hit_probs
from .router_trace import TraceConfig, synthetic_trace, trace_stats

__all__ = [
    "CacheState", "access", "init_cache_state", "lookup", "slot_id",
    "ExpertTiers", "collaborative_moe", "init_tiers",
    "NumpyCache", "random_policy_hit_probs",
    "TraceConfig", "synthetic_trace", "trace_stats",
]
