"""The paper's contribution: two-tier collaborative MoE inference with a
set-associative expert cache, grouped gmm-backed execution and
asynchronous post-fetch."""
from .cache import CacheState, FLAG_DEMAND, FLAG_PENDING, FLAG_SPEC, \
    access, access_ex, access_scan_reference, init_cache_state, land, \
    lookup, reserve, slot_id
from .collaborative import ExpertTiers, ProbeResult, collaborative_moe, \
    collaborative_moe_offloaded, collaborative_moe_reference, commit, \
    execute, host_offload_supported, init_tiers, memory_kinds, \
    offload_host_tier, prefetch, probe
from .policies import NumpyCache, PolicySpec, policy_spec, \
    random_policy_hit_probs
from .router_trace import TraceConfig, synthetic_trace, trace_stats

__all__ = [
    "CacheState", "FLAG_DEMAND", "FLAG_PENDING", "FLAG_SPEC",
    "access", "access_ex", "access_scan_reference", "init_cache_state",
    "land", "lookup", "reserve", "slot_id",
    "ExpertTiers", "ProbeResult", "collaborative_moe",
    "collaborative_moe_offloaded", "collaborative_moe_reference",
    "commit", "execute", "host_offload_supported", "init_tiers",
    "memory_kinds", "offload_host_tier", "prefetch", "probe",
    "NumpyCache", "PolicySpec", "policy_spec", "random_policy_hit_probs",
    "TraceConfig", "synthetic_trace", "trace_stats",
]
