"""The paper's contribution: two-tier collaborative MoE inference with a
set-associative expert cache, grouped gmm-backed execution and
asynchronous post-fetch."""
from .cache import CacheState, access, access_scan_reference, \
    init_cache_state, lookup, slot_id
from .collaborative import ExpertTiers, collaborative_moe, \
    collaborative_moe_offloaded, collaborative_moe_reference, \
    host_offload_supported, init_tiers, memory_kinds, offload_host_tier
from .policies import NumpyCache, PolicySpec, policy_spec, \
    random_policy_hit_probs
from .router_trace import TraceConfig, synthetic_trace, trace_stats

__all__ = [
    "CacheState", "access", "access_scan_reference", "init_cache_state",
    "lookup", "slot_id",
    "ExpertTiers", "collaborative_moe", "collaborative_moe_offloaded",
    "collaborative_moe_reference", "host_offload_supported", "init_tiers",
    "memory_kinds", "offload_host_tier",
    "NumpyCache", "PolicySpec", "policy_spec", "random_policy_hit_probs",
    "TraceConfig", "synthetic_trace", "trace_stats",
]
