"""The paper's expert cache: N-index, M-way set-associative, pure JAX.

One cache *set* (index) per MoE layer 0..N-1; M expert-weight slots per
set (paper §III-B: S = mem/expert_bytes slots total, N = floor(S/M)).
State is three small arrays, so every operation is branchless and
jit/scan-compatible — the cache lives inside the serving step:

  tags  [N, M] int32 — resident expert id per slot, -1 = empty
  age   [N, M] int32 — last-access clock (LRU) / insertion clock (FIFO)
  clock []     int32 — global access counter

Policies (paper §IV-D):
  lru    — refresh age on hit and insert; evict min-age way.
  fifo   — age set on insert only; evict min-age way.
  random — the paper's static-random baseline: a fixed random expert set is
           pinned at init and never replaced (hit rates then follow the
           closed-form equations of §IV-D, which tests verify exactly).

Layers >= N are beyond cache coverage (paper's "layer Z"): accesses miss
and inserts are suppressed — handled branchlessly so the layer index may
be a traced scan counter.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig


class CacheState(NamedTuple):
    tags: jax.Array
    age: jax.Array
    clock: jax.Array

    @property
    def num_indexes(self) -> int:
        return self.tags.shape[0]

    @property
    def num_ways(self) -> int:
        return self.tags.shape[1]


def init_cache_state(ccfg: CacheConfig, num_experts: int = 0,
                     key=None) -> CacheState:
    tags = jnp.full((ccfg.num_indexes, ccfg.num_ways), -1, jnp.int32)
    if ccfg.policy == "random":
        assert key is not None and num_experts > 0, \
            "static-random policy needs a key and the expert count"
        # pin M distinct random experts per set, fixed forever
        def pick(k):
            return jax.random.permutation(k, num_experts)[:ccfg.num_ways]
        tags = jax.vmap(pick)(jax.random.split(key, ccfg.num_indexes)).astype(jnp.int32)
    age = jnp.zeros((ccfg.num_indexes, ccfg.num_ways), jnp.int32)
    return CacheState(tags=tags, age=age, clock=jnp.zeros((), jnp.int32))


def lookup(state: CacheState, layer: jax.Array, experts: jax.Array
           ) -> Tuple[jax.Array, jax.Array]:
    """Read-only probe. experts: [A] -> (hit [A] bool, way [A] int32)."""
    n = state.num_indexes
    row = jnp.where(layer < n, layer, 0)
    tags_l = jax.lax.dynamic_index_in_dim(state.tags, row, 0, keepdims=False)
    eq = tags_l[None, :] == experts[:, None]            # [A, M]
    hit = eq.any(axis=1) & (layer < n) & (experts[:, None] >= 0).any(axis=1)
    way = jnp.argmax(eq, axis=1).astype(jnp.int32)
    return hit, way


def access(state: CacheState, layer: jax.Array, experts: jax.Array,
           policy: str) -> Tuple[CacheState, jax.Array, jax.Array]:
    """Probe + update for one layer's required experts (sequential
    semantics over ``experts``, matching a hardware cache servicing the
    router's picks in order).

    experts: [A] int32 (may contain duplicates; dup hits refresh age once
    more, as in the paper's implementation). Returns (new state,
    hit [A] bool — hit *before* any insertion this call, way [A] int32 —
    the slot each expert resides in afterwards; for `random` policy missed
    experts get way=-1 since nothing is inserted).
    """
    n, m = state.num_indexes, state.num_ways
    covered = layer < n
    row = jnp.where(covered, layer, 0)

    def step(carry, e):
        tags, age, clock = carry
        tags_l = jax.lax.dynamic_index_in_dim(tags, row, 0, keepdims=False)
        age_l = jax.lax.dynamic_index_in_dim(age, row, 0, keepdims=False)
        eq = tags_l == e
        hit = eq.any() & covered
        hit_way = jnp.argmax(eq).astype(jnp.int32)

        if policy == "random":
            way = jnp.where(hit, hit_way, -1)
            return (tags, age, clock), (hit, way)

        # victim: empty slots win (score -1), else least-recently-used/inserted
        victim_score = jnp.where(tags_l < 0, -1, age_l)
        victim = jnp.argmin(victim_score).astype(jnp.int32)
        way = jnp.where(hit, hit_way, victim)

        do_write = covered & (e >= 0)
        new_tag = jnp.where(do_write, e, tags_l[way])
        # LRU refreshes age on hit and insert; FIFO only stamps on insert.
        refresh = (do_write & ~hit) if policy == "fifo" else do_write
        new_age = jnp.where(refresh, clock, age_l[way])

        tags_l = tags_l.at[way].set(new_tag)
        age_l = age_l.at[way].set(new_age)
        tags = jax.lax.dynamic_update_index_in_dim(tags, tags_l, row, 0)
        age = jax.lax.dynamic_update_index_in_dim(age, age_l, row, 0)
        return (tags, age, clock + 1), (hit, jnp.where(do_write, way, -1))

    (tags, age, clock), (hits, ways) = jax.lax.scan(
        step, (state.tags, state.age, state.clock), experts)
    return CacheState(tags, age, clock), hits, ways


def slot_id(layer: jax.Array, way: jax.Array, num_ways: int) -> jax.Array:
    """Flat slot index into the [N*M, ...] cache weight buffer."""
    return layer * num_ways + way
