"""The paper's expert cache: N-index, M-way set-associative, pure JAX.

One cache *set* (index) per MoE layer 0..N-1; M expert-weight slots per
set (paper §III-B: S = mem/expert_bytes slots total, N = floor(S/M)).
State is three small arrays, so every operation is branchless and
jit/scan-compatible — the cache lives inside the serving step:

  tags  [N, M] int32 — resident expert id per slot, -1 = empty
  age   [N, M] int32 — last-access clock (LRU) / insertion clock (FIFO)
  clock []     int32 — global access counter

Policies are described by :class:`repro.core.policies.PolicySpec` (shared
with the numpy twin so the two implementations cannot drift):
  lru    — refresh age on hit and insert; evict min-age way.
  fifo   — age set on insert only; evict min-age way.
  random — the paper's static-random baseline: a fixed random expert set is
           pinned at init and never replaced (hit rates then follow the
           closed-form equations of §IV-D, which tests verify exactly).

Layers >= N are beyond cache coverage (paper's "layer Z"): accesses miss
and inserts are suppressed — handled branchlessly so the layer index may
be a traced scan counter.

Speculative prefetch (cross-layer pre-gating) adds a fourth array:

  in_flight [N, M] int32 — slot provenance/transfer flag:
      FLAG_DEMAND  (0)  demand-resident or empty slot;
      FLAG_SPEC    (1)  speculatively inserted, transfer landed;
      FLAG_PENDING (2)  speculatively inserted, transfer still in flight.

``reserve`` inserts *predicted* experts with the policy's normal victim
selection but does not count as a demand access: it never reports hits and
never refreshes an already-resident entry. A fresh reservation is PENDING —
mirroring the simulator's async fetch engine, a demand probe in the same
step still misses it (and, like the simulator, does not enqueue a duplicate
fetch because the tag is already present). ``land`` marks every PENDING
reservation as arrived (SPEC); the serving pipeline lands at the start of
the next layer's probe, so a reservation made while executing layer *l*
serves hits from layer *l+1* on. The first demand hit on a SPEC entry
promotes it to DEMAND and is reported separately (``spec_served``) so the
engine can count demand hits that prefetch manufactured — the
HybriMoE-style demand/speculative distinction. With ``in_flight`` all zero
(no reservations ever made) every operation below is bit-identical to the
flag-free cache, which the parity suites rely on.

``access`` services one decode step's picks for one layer. All picks hit
the *same* set, so the update is row-local: the set row is gathered once,
each pick is serviced with O(M) vector ops (rank-based victim selection =
argmin over the way scores), and the row is scattered back once. This
replaces the seed implementation's per-pick ``lax.scan`` whose every step
sliced and re-wrote the full [N, M] arrays — the seed path is retained as
:func:`access_scan_reference` for parity tests and the microbenchmark.
Sequential semantics (a hardware cache servicing the router's picks in
order, duplicates refreshing twice, an insert at pick i visible to pick
i+1) are preserved exactly; work-dedup across duplicate picks happens at
the execution layer (repro.core.collaborative groups FFN work and weight
fetches per *unique* expert).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig
from .policies import FLAG_DEMAND, FLAG_PENDING, FLAG_SPEC, PolicySpec, \
    policy_spec


class CacheState(NamedTuple):
    tags: jax.Array
    age: jax.Array
    clock: jax.Array
    in_flight: jax.Array

    @property
    def num_indexes(self) -> int:
        return self.tags.shape[0]

    @property
    def num_ways(self) -> int:
        return self.tags.shape[1]


def init_cache_state(ccfg: CacheConfig, num_experts: int = 0,
                     key=None) -> CacheState:
    spec = policy_spec(ccfg.policy)
    tags = jnp.full((ccfg.num_indexes, ccfg.num_ways), -1, jnp.int32)
    if spec.needs_key:
        assert key is not None and num_experts > 0, \
            "static-random policy needs a key and the expert count"
        # pin M distinct random experts per set, fixed forever
        def pick(k):
            return jax.random.permutation(k, num_experts)[:ccfg.num_ways]
        tags = jax.vmap(pick)(jax.random.split(key, ccfg.num_indexes)).astype(jnp.int32)
    age = jnp.zeros((ccfg.num_indexes, ccfg.num_ways), jnp.int32)
    return CacheState(tags=tags, age=age, clock=jnp.zeros((), jnp.int32),
                      in_flight=jnp.zeros_like(tags))


def lookup(state: CacheState, layer: jax.Array, experts: jax.Array
           ) -> Tuple[jax.Array, jax.Array]:
    """Read-only probe. experts: [A] -> (hit [A] bool, way [A] int32).

    An expert whose reservation is still PENDING is *not* a hit — its
    transfer has not landed, so the execution tier must read the host
    table (the simulator's in-flight-miss semantics)."""
    n = state.num_indexes
    row = jnp.where(layer < n, layer, 0)
    tags_l = jax.lax.dynamic_index_in_dim(state.tags, row, 0, keepdims=False)
    flag_l = jax.lax.dynamic_index_in_dim(state.in_flight, row, 0,
                                          keepdims=False)
    eq = tags_l[None, :] == experts[:, None]            # [A, M]
    way = jnp.argmax(eq, axis=1).astype(jnp.int32)
    hit = eq.any(axis=1) & (layer < n) & (experts >= 0) \
        & (flag_l[way] != FLAG_PENDING)
    return hit, way


def _service_one(spec: PolicySpec, covered, tags_l, age_l, flag_l, clock, e):
    """Service one pick against the [M] set row. Pure vector ops."""
    eq = tags_l == e
    valid = covered & (e >= 0)
    tag_hit = eq.any() & valid
    hit_way = jnp.argmax(eq).astype(jnp.int32)
    # rank-based victim selection: empty slots outrank (score -1), else the
    # least-recently-used/inserted way; argmin = rank-1 under (score, way)
    victim_score = jnp.where(tags_l < 0, -1, age_l)
    victim = jnp.argmin(victim_score).astype(jnp.int32)
    way = jnp.where(tag_hit, hit_way, victim)
    # A tag hit on a PENDING reservation is serviced as a miss (the weights
    # have not landed) but neither re-inserts nor enqueues a second fetch —
    # the tag is already present. SPEC entries serve hits like any resident
    # entry; the first demand hit promotes them to DEMAND.
    pending = tag_hit & (flag_l[way] == FLAG_PENDING)
    hit = tag_hit & ~pending
    spec_served = tag_hit & (flag_l[way] == FLAG_SPEC)
    # Bookkeeping (tags/age) keys off the *tag* hit so the LRU/FIFO order
    # is identical with and without prefetch — only the reported hit and
    # the provenance flag see the in-flight distinction.
    refresh = valid if spec.refresh_on_hit else (valid & ~tag_hit)
    tags_l = tags_l.at[way].set(jnp.where(valid, e, tags_l[way]))
    age_l = age_l.at[way].set(jnp.where(refresh, clock, age_l[way]))
    # demand insert (miss) and demand-hit promotion both clear to DEMAND;
    # a pending entry stays PENDING until land().
    clear = valid & ~pending
    flag_l = flag_l.at[way].set(jnp.where(clear, FLAG_DEMAND, flag_l[way]))
    return (tags_l, age_l, flag_l, clock + 1, hit, spec_served,
            jnp.where(valid, way, -1))


def access_ex(state: CacheState, layer: jax.Array, experts: jax.Array,
              policy: str
              ) -> Tuple[CacheState, jax.Array, jax.Array, jax.Array]:
    """Probe + update for one layer's required experts.

    experts: [A] int32 (may contain duplicates; dup hits refresh age once
    more, as in the paper's implementation; entries < 0 are masked — they
    neither hit nor insert, matching the numpy twin). Returns (new state,
    hit [A] bool, way [A] int32 — the slot each expert resides in
    afterwards; masked/uncovered picks and `random`-policy misses get
    way=-1 since nothing is inserted, spec_served [A] bool — hits that a
    landed speculative reservation manufactured; the hit promotes the
    entry to demand provenance so each prefetch is credited once).
    """
    spec = policy_spec(policy)
    n = state.num_indexes
    covered = layer < n
    row = jnp.where(covered, layer, 0)
    tags_l = jax.lax.dynamic_index_in_dim(state.tags, row, 0, keepdims=False)

    if spec.is_static:
        # static placement never mutates: one vectorized [A, M] probe
        eq = tags_l[None, :] == experts[:, None]
        hits = eq.any(axis=1) & covered & (experts >= 0)
        ways = jnp.where(hits, jnp.argmax(eq, axis=1).astype(jnp.int32), -1)
        return state, hits, ways, jnp.zeros_like(hits)

    age_l = jax.lax.dynamic_index_in_dim(state.age, row, 0, keepdims=False)
    flag_l = jax.lax.dynamic_index_in_dim(state.in_flight, row, 0,
                                          keepdims=False)

    def step(carry, e):
        t, a, f, c = carry
        t, a, f, c, h, sp, w = _service_one(spec, covered, t, a, f, c, e)
        return (t, a, f, c), (h, sp, w)

    (tags_l, age_l, flag_l, clock), (hits, spec_served, ways) = jax.lax.scan(
        step, (tags_l, age_l, flag_l, state.clock), experts)

    tags = jax.lax.dynamic_update_index_in_dim(state.tags, tags_l, row, 0)
    age = jax.lax.dynamic_update_index_in_dim(state.age, age_l, row, 0)
    flags = jax.lax.dynamic_update_index_in_dim(state.in_flight, flag_l,
                                                row, 0)
    return CacheState(tags, age, clock, flags), hits, ways, spec_served


def access(state: CacheState, layer: jax.Array, experts: jax.Array,
           policy: str) -> Tuple[CacheState, jax.Array, jax.Array]:
    """:func:`access_ex` without the speculative-hit channel."""
    new_state, hits, ways, _ = access_ex(state, layer, experts, policy)
    return new_state, hits, ways


def reserve(state: CacheState, layer: jax.Array, experts: jax.Array,
            policy: str, protect: Optional[jax.Array] = None,
            priority: Optional[jax.Array] = None
            ) -> Tuple[CacheState, jax.Array, jax.Array]:
    """Speculatively insert *predicted* experts for a future probe.

    Policy-correct eviction (same empty-first/min-age victim rule as the
    demand path) but none of a demand access's observable effects: no hit
    is ever reported, an already-present expert (resident OR in flight) is
    left untouched — no age refresh, no duplicate fetch — and the static
    `random` policy never reserves at all. *Batch protection*: a way
    holding any expert of the protected set (``protect``, defaulting to
    the insert batch itself) is never the victim — reserving pick B must
    not evict predicted pick A out from under the very probe the batch is
    staged for (fatal at low associativity: with M = top_k the batch
    would otherwise evict itself); if every way is protected the pick is
    skipped, not forced. Callers that issue picks one at a time (e.g. a
    transfer-budget gate) pass the full prediction batch as ``protect``.
    ``priority`` ([A] int32, >= 0, default 0) adds to the inserted
    entry's age stamp: a higher-priority reservation reads as more
    recently used, so later min-age evictions take the low-priority
    reservations first — retention ranking WITHIN the reserved cohort
    without perturbing the claim order. Keep priorities small (batch
    counts, not clocks): they must stay far below the per-step clock
    advance so a boost expires after the next demand pass instead of
    pinning the entry. Newly inserted entries are PENDING until
    :func:`land`, so a probe in the same step still misses them.
    experts: [A] int32, duplicates and -1 masks allowed. Returns (new
    state, issued [A] bool — picks whose reservation actually claimed a
    slot and therefore needs its weights fetched, way [A] int32 — the
    claimed way; -1 where nothing was issued).
    """
    spec = policy_spec(policy)
    n = state.num_indexes
    covered = layer < n
    row = jnp.where(covered, layer, 0)
    protect = experts if protect is None else protect
    if priority is None:
        priority = jnp.zeros(experts.shape, jnp.int32)

    if spec.is_static:
        zeros = jnp.zeros(experts.shape, bool)
        return state, zeros, jnp.full(experts.shape, -1, jnp.int32)

    tags_l = jax.lax.dynamic_index_in_dim(state.tags, row, 0, keepdims=False)
    age_l = jax.lax.dynamic_index_in_dim(state.age, row, 0, keepdims=False)
    flag_l = jax.lax.dynamic_index_in_dim(state.in_flight, row, 0,
                                          keepdims=False)
    # protected ways rank above every real age (ages are < clock, and
    # pinning to the max avoids the int32 overflow an additive penalty
    # would hit once the clock passes 2^30); ties between protected ways
    # are irrelevant — a protected victim is never inserted over
    PROTECT = jnp.iinfo(jnp.int32).max

    def step(carry, xs):
        e, p = xs
        t, a, f, c = carry
        valid = covered & (e >= 0)
        present = (t == e).any() & valid
        # ways holding a protected expert are never victims (empty ways'
        # -1 sentinel must not match masked -1 picks)
        prot = (t[:, None] == protect[None, :]).any(1) & (t >= 0)
        victim_score = jnp.where(t < 0, -1, jnp.where(prot, PROTECT, a))
        victim = jnp.argmin(victim_score).astype(jnp.int32)
        insert = valid & ~present & ~prot[victim]
        t = t.at[victim].set(jnp.where(insert, e, t[victim]))
        a = a.at[victim].set(jnp.where(insert, c + p, a[victim]))
        f = f.at[victim].set(jnp.where(insert, FLAG_PENDING, f[victim]))
        return (t, a, f, c + 1), (insert, jnp.where(insert, victim, -1))

    (tags_l, age_l, flag_l, clock), (issued, ways) = jax.lax.scan(
        step, (tags_l, age_l, flag_l, state.clock),
        (experts, priority.astype(jnp.int32)))

    tags = jax.lax.dynamic_update_index_in_dim(state.tags, tags_l, row, 0)
    age = jax.lax.dynamic_update_index_in_dim(state.age, age_l, row, 0)
    flags = jax.lax.dynamic_update_index_in_dim(state.in_flight, flag_l,
                                                row, 0)
    return CacheState(tags, age, clock, flags), issued, ways


def land(state: CacheState) -> CacheState:
    """Mark every PENDING reservation as arrived (PENDING -> SPEC).

    The serving pipeline lands at the start of each probe: a reservation
    issued while layer *l* executed has one attention's worth of compute to
    cover its transfer and serves demand hits from layer *l+1* on."""
    return state._replace(in_flight=jnp.where(
        state.in_flight == FLAG_PENDING, FLAG_SPEC, state.in_flight))


def access_scan_reference(state: CacheState, layer: jax.Array,
                          experts: jax.Array, policy: str
                          ) -> Tuple[CacheState, jax.Array, jax.Array]:
    """The seed implementation: per-pick ``lax.scan`` that slices and
    rewrites the full [N, M] arrays at every step. Kept as the parity
    oracle for :func:`access` and as the "old path" in the cache-access
    microbenchmark — do not use in serving code. Predates speculative
    prefetch: only valid on flag-free states (``in_flight`` all zero),
    which it passes through untouched.
    """
    spec = policy_spec(policy)
    n, m = state.num_indexes, state.num_ways
    covered = layer < n
    row = jnp.where(covered, layer, 0)

    def step(carry, e):
        tags, age, clock = carry
        tags_l = jax.lax.dynamic_index_in_dim(tags, row, 0, keepdims=False)
        age_l = jax.lax.dynamic_index_in_dim(age, row, 0, keepdims=False)
        eq = tags_l == e
        hit = eq.any() & covered & (e >= 0)
        hit_way = jnp.argmax(eq).astype(jnp.int32)

        if spec.is_static:
            way = jnp.where(hit, hit_way, -1)
            return (tags, age, clock), (hit, way)

        victim_score = jnp.where(tags_l < 0, -1, age_l)
        victim = jnp.argmin(victim_score).astype(jnp.int32)
        way = jnp.where(hit, hit_way, victim)

        do_write = covered & (e >= 0)
        new_tag = jnp.where(do_write, e, tags_l[way])
        refresh = do_write if spec.refresh_on_hit else (do_write & ~hit)
        new_age = jnp.where(refresh, clock, age_l[way])

        tags_l = tags_l.at[way].set(new_tag)
        age_l = age_l.at[way].set(new_age)
        tags = jax.lax.dynamic_update_index_in_dim(tags, tags_l, row, 0)
        age = jax.lax.dynamic_update_index_in_dim(age, age_l, row, 0)
        return (tags, age, clock + 1), (hit, jnp.where(do_write, way, -1))

    (tags, age, clock), (hits, ways) = jax.lax.scan(
        step, (state.tags, state.age, state.clock), experts)
    if spec.is_static:
        return state, hits, ways
    return CacheState(tags, age, clock, state.in_flight), hits, ways


def slot_id(layer: jax.Array, way: jax.Array, num_ways: int) -> jax.Array:
    """Flat slot index into the [N*M, ...] cache weight buffer."""
    return layer * num_ways + way
