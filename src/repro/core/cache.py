"""The paper's expert cache: N-index, M-way set-associative, pure JAX.

One cache *set* (index) per MoE layer 0..N-1; M expert-weight slots per
set (paper §III-B: S = mem/expert_bytes slots total, N = floor(S/M)).
State is three small arrays, so every operation is branchless and
jit/scan-compatible — the cache lives inside the serving step:

  tags  [N, M] int32 — resident expert id per slot, -1 = empty
  age   [N, M] int32 — last-access clock (LRU) / insertion clock (FIFO)
  clock []     int32 — global access counter

Policies are described by :class:`repro.core.policies.PolicySpec` (shared
with the numpy twin so the two implementations cannot drift):
  lru    — refresh age on hit and insert; evict min-age way.
  fifo   — age set on insert only; evict min-age way.
  random — the paper's static-random baseline: a fixed random expert set is
           pinned at init and never replaced (hit rates then follow the
           closed-form equations of §IV-D, which tests verify exactly).

Layers >= N are beyond cache coverage (paper's "layer Z"): accesses miss
and inserts are suppressed — handled branchlessly so the layer index may
be a traced scan counter.

``access`` services one decode step's picks for one layer. All picks hit
the *same* set, so the update is row-local: the set row is gathered once,
each pick is serviced with O(M) vector ops (rank-based victim selection =
argmin over the way scores), and the row is scattered back once. This
replaces the seed implementation's per-pick ``lax.scan`` whose every step
sliced and re-wrote the full [N, M] arrays — the seed path is retained as
:func:`access_scan_reference` for parity tests and the microbenchmark.
Sequential semantics (a hardware cache servicing the router's picks in
order, duplicates refreshing twice, an insert at pick i visible to pick
i+1) are preserved exactly; work-dedup across duplicate picks happens at
the execution layer (repro.core.collaborative groups FFN work and weight
fetches per *unique* expert).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig
from .policies import PolicySpec, policy_spec

class CacheState(NamedTuple):
    tags: jax.Array
    age: jax.Array
    clock: jax.Array

    @property
    def num_indexes(self) -> int:
        return self.tags.shape[0]

    @property
    def num_ways(self) -> int:
        return self.tags.shape[1]


def init_cache_state(ccfg: CacheConfig, num_experts: int = 0,
                     key=None) -> CacheState:
    spec = policy_spec(ccfg.policy)
    tags = jnp.full((ccfg.num_indexes, ccfg.num_ways), -1, jnp.int32)
    if spec.needs_key:
        assert key is not None and num_experts > 0, \
            "static-random policy needs a key and the expert count"
        # pin M distinct random experts per set, fixed forever
        def pick(k):
            return jax.random.permutation(k, num_experts)[:ccfg.num_ways]
        tags = jax.vmap(pick)(jax.random.split(key, ccfg.num_indexes)).astype(jnp.int32)
    age = jnp.zeros((ccfg.num_indexes, ccfg.num_ways), jnp.int32)
    return CacheState(tags=tags, age=age, clock=jnp.zeros((), jnp.int32))


def lookup(state: CacheState, layer: jax.Array, experts: jax.Array
           ) -> Tuple[jax.Array, jax.Array]:
    """Read-only probe. experts: [A] -> (hit [A] bool, way [A] int32)."""
    n = state.num_indexes
    row = jnp.where(layer < n, layer, 0)
    tags_l = jax.lax.dynamic_index_in_dim(state.tags, row, 0, keepdims=False)
    eq = tags_l[None, :] == experts[:, None]            # [A, M]
    hit = eq.any(axis=1) & (layer < n) & (experts >= 0)
    way = jnp.argmax(eq, axis=1).astype(jnp.int32)
    return hit, way


def _service_one(spec: PolicySpec, covered, tags_l, age_l, clock, e):
    """Service one pick against the [M] set row. Pure vector ops."""
    eq = tags_l == e
    valid = covered & (e >= 0)
    hit = eq.any() & valid
    hit_way = jnp.argmax(eq).astype(jnp.int32)
    # rank-based victim selection: empty slots outrank (score -1), else the
    # least-recently-used/inserted way; argmin = rank-1 under (score, way)
    victim_score = jnp.where(tags_l < 0, -1, age_l)
    victim = jnp.argmin(victim_score).astype(jnp.int32)
    way = jnp.where(hit, hit_way, victim)
    # LRU refreshes age on hit and insert; FIFO only stamps on insert.
    refresh = valid if spec.refresh_on_hit else (valid & ~hit)
    tags_l = tags_l.at[way].set(jnp.where(valid, e, tags_l[way]))
    age_l = age_l.at[way].set(jnp.where(refresh, clock, age_l[way]))
    return tags_l, age_l, clock + 1, hit, jnp.where(valid, way, -1)


def access(state: CacheState, layer: jax.Array, experts: jax.Array,
           policy: str) -> Tuple[CacheState, jax.Array, jax.Array]:
    """Probe + update for one layer's required experts.

    experts: [A] int32 (may contain duplicates; dup hits refresh age once
    more, as in the paper's implementation; entries < 0 are masked — they
    neither hit nor insert, matching the numpy twin). Returns (new state,
    hit [A] bool, way [A] int32 — the slot each expert resides in
    afterwards; masked/uncovered picks and `random`-policy misses get
    way=-1 since nothing is inserted).
    """
    spec = policy_spec(policy)
    n = state.num_indexes
    covered = layer < n
    row = jnp.where(covered, layer, 0)
    tags_l = jax.lax.dynamic_index_in_dim(state.tags, row, 0, keepdims=False)

    if spec.is_static:
        # static placement never mutates: one vectorized [A, M] probe
        eq = tags_l[None, :] == experts[:, None]
        hits = eq.any(axis=1) & covered & (experts >= 0)
        ways = jnp.where(hits, jnp.argmax(eq, axis=1).astype(jnp.int32), -1)
        return state, hits, ways

    age_l = jax.lax.dynamic_index_in_dim(state.age, row, 0, keepdims=False)

    def step(carry, e):
        t, a, c = carry
        t, a, c, h, w = _service_one(spec, covered, t, a, c, e)
        return (t, a, c), (h, w)

    (tags_l, age_l, clock), (hits, ways) = jax.lax.scan(
        step, (tags_l, age_l, state.clock), experts)

    tags = jax.lax.dynamic_update_index_in_dim(state.tags, tags_l, row, 0)
    age = jax.lax.dynamic_update_index_in_dim(state.age, age_l, row, 0)
    return CacheState(tags, age, clock), hits, ways


def access_scan_reference(state: CacheState, layer: jax.Array,
                          experts: jax.Array, policy: str
                          ) -> Tuple[CacheState, jax.Array, jax.Array]:
    """The seed implementation: per-pick ``lax.scan`` that slices and
    rewrites the full [N, M] arrays at every step. Kept as the parity
    oracle for :func:`access` and as the "old path" in the cache-access
    microbenchmark — do not use in serving code.
    """
    spec = policy_spec(policy)
    n, m = state.num_indexes, state.num_ways
    covered = layer < n
    row = jnp.where(covered, layer, 0)

    def step(carry, e):
        tags, age, clock = carry
        tags_l = jax.lax.dynamic_index_in_dim(tags, row, 0, keepdims=False)
        age_l = jax.lax.dynamic_index_in_dim(age, row, 0, keepdims=False)
        eq = tags_l == e
        hit = eq.any() & covered & (e >= 0)
        hit_way = jnp.argmax(eq).astype(jnp.int32)

        if spec.is_static:
            way = jnp.where(hit, hit_way, -1)
            return (tags, age, clock), (hit, way)

        victim_score = jnp.where(tags_l < 0, -1, age_l)
        victim = jnp.argmin(victim_score).astype(jnp.int32)
        way = jnp.where(hit, hit_way, victim)

        do_write = covered & (e >= 0)
        new_tag = jnp.where(do_write, e, tags_l[way])
        refresh = do_write if spec.refresh_on_hit else (do_write & ~hit)
        new_age = jnp.where(refresh, clock, age_l[way])

        tags_l = tags_l.at[way].set(new_tag)
        age_l = age_l.at[way].set(new_age)
        tags = jax.lax.dynamic_update_index_in_dim(tags, tags_l, row, 0)
        age = jax.lax.dynamic_update_index_in_dim(age, age_l, row, 0)
        return (tags, age, clock + 1), (hit, jnp.where(do_write, way, -1))

    (tags, age, clock), (hits, ways) = jax.lax.scan(
        step, (state.tags, state.age, state.clock), experts)
    if spec.is_static:
        return state, hits, ways
    return CacheState(tags, age, clock), hits, ways


def slot_id(layer: jax.Array, way: jax.Array, num_ways: int) -> jax.Array:
    """Flat slot index into the [N*M, ...] cache weight buffer."""
    return layer * num_ways + way
