"""Two-tier collaborative MoE execution — the paper's workflow (Fig. 4),
decomposed into composable stages:

  probe    — land any in-flight reservations, service the router's top-k
             picks against the set-associative cache (demand bookkeeping,
             speculative-hit attribution) and bucket the step's
             assignments by unique expert (repro.core.cache, inside jit).
  execute  — *grouped*: the assignments run through an [G, C, D] dispatch
             buffer and the grouped Pallas kernels
             (repro.kernels.moe_gmm.ops.moe_ffn). Each unique expert's
             weights are gathered ONCE per step — resident experts from
             the *device tier* (the [N*M, ...] cache slot buffer in fast
             memory), non-resident experts from the *host tier* (full
             expert table, host memory space on real hardware).
  commit   — state update + post-fetch: newly inserted experts' weights
             are written into their assigned cache slots, once per unique
             expert. The write feeds only *future* steps (no data path to
             this layer's output), so XLA overlaps the copy with
             downstream compute — the TPU analogue of the paper's second
             copy engine / dual CUDA streams.
  prefetch — speculative cross-layer pre-fetch (DAOP / Pre-gated style):
             reserve slots for the experts the *next* layer's router is
             predicted to pick and stream their weights in ahead of the
             next probe. Reservations are invisible until the next
             probe lands them, so a prefetch issued at layer *l* serves
             demand hits from layer *l+1* on — the live-path twin of the
             simulator's async fetch engine.

:func:`collaborative_moe` is the probe→execute→commit composition (no
prefetch); the serving engine drives the stages directly so it can overlap
the prefetch for layer *l+1* with layer *l*'s commit.

The seed implementation executed every assignment separately (dense
per-assignment weight gathers + a vmapped single-row FFN) — it is retained
as :func:`collaborative_moe_reference` for parity tests and benchmarks.
Grouping also fixes a latent seed bug: when two concurrent requests picked
the same non-resident expert, the seed's second assignment was marked a
cache hit (the bookkeeping insert from the first assignment) and read the
*stale* slot buffer; the grouped path derives each unique expert's tier
from its residency *before* the step, so both assignments read the host
tier and compute correctly.

All state (CacheState + slot buffer) threads functionally through the
serving step; donate both so the updates are in-place on device.

TPU note: on real hardware ``host`` lives in pinned host memory
(``jax.device_put(..., TransferToMemoryKind("pinned_host"))``); on this CPU
container both tiers are ordinary buffers and the *cost model*
(repro.core.costmodel) carries the performance semantics.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig
from repro.kernels.moe_gmm.ops import moe_ffn
from repro.kernels.moe_gmm.ref import moe_ffn_ref
from . import cache as cache_lib

Params = Dict[str, jax.Array]


class ExpertTiers(NamedTuple):
    """The two memory tiers for one model's MoE expert weights.

    host_*: [L_moe, E, ...] — the full expert table (slow tier).
    slot_*: [N*M, ...]      — the device cache slot buffer (fast tier).
    state : CacheState      — tags/age/clock.
    """
    host_w1: jax.Array     # [L, E, D, F]
    host_w3: jax.Array
    host_w2: jax.Array     # [L, E, F, D]
    slot_w1: jax.Array     # [N*M, D, F]
    slot_w3: jax.Array
    slot_w2: jax.Array     # [N*M, F, D]
    state: cache_lib.CacheState


def memory_kinds() -> Tuple[Optional[str], str]:
    """(host_kind, device_kind) for the literal two-tier placement.

    host_kind prefers ``pinned_host`` (TPU: host DRAM over PCIe) and falls
    back to ``unpinned_host``; None when the backend exposes no host space.
    device_kind is the backend's default memory. On this CPU container both
    resolve to ``unpinned_host`` — the placement degenerates to ordinary
    buffers but the program structure (and tests) stay identical.
    """
    dev = jax.devices()[0]
    kinds = {m.kind for m in dev.addressable_memories()}
    host = next((k for k in ("pinned_host", "unpinned_host") if k in kinds),
                None)
    return host, dev.default_memory().kind


def host_offload_supported() -> bool:
    return memory_kinds()[0] is not None


def offload_host_tier(tiers: ExpertTiers, device=None) -> ExpertTiers:
    """Place the host-tier expert table in the host memory space.

    This is the literal JAX expression of the paper's slow tier: the full
    expert table leaves accelerator HBM; hit-path reads touch only the
    HBM-resident slot buffers, miss-path reads stream over the host link.
    (Works on CPU and TPU backends; on TPU this is host DRAM over PCIe.)
    """
    from jax.sharding import SingleDeviceSharding
    host_kind, _ = memory_kinds()
    if host_kind is None:
        raise RuntimeError(
            "backend exposes no host memory space "
            "(need pinned_host or unpinned_host)")
    dev = device or jax.devices()[0]
    s = SingleDeviceSharding(dev, memory_kind=host_kind)
    return tiers._replace(
        host_w1=jax.device_put(tiers.host_w1, s),
        host_w3=jax.device_put(tiers.host_w3, s),
        host_w2=jax.device_put(tiers.host_w2, s),
    )


def init_tiers(host_w1, host_w3, host_w2, ccfg: CacheConfig,
               num_experts: int = 0, key=None) -> ExpertTiers:
    S = ccfg.num_slots
    D, F = host_w1.shape[-2], host_w1.shape[-1]
    state = cache_lib.init_cache_state(ccfg, num_experts, key)
    tiers = ExpertTiers(
        host_w1=host_w1, host_w3=host_w3, host_w2=host_w2,
        slot_w1=jnp.zeros((S, D, F), host_w1.dtype),
        slot_w3=jnp.zeros((S, D, F), host_w3.dtype),
        slot_w2=jnp.zeros((S, F, D), host_w2.dtype),
        state=state,
    )
    if ccfg.policy == "random":
        # static placement: preload the pinned experts once
        tiers = _preload_static(tiers, ccfg)
    return tiers


def _preload_static(tiers: ExpertTiers, ccfg: CacheConfig) -> ExpertTiers:
    n, m = ccfg.num_indexes, ccfg.num_ways
    layers = jnp.repeat(jnp.arange(n), m)
    experts = tiers.state.tags.reshape(-1)
    w1 = tiers.host_w1[layers, experts]
    w3 = tiers.host_w3[layers, experts]
    w2 = tiers.host_w2[layers, experts]
    return tiers._replace(slot_w1=w1, slot_w3=w3, slot_w2=w2)


def _ffn_one(w1, w3, w2, x):
    """SwiGLU expert FFN for one token row x: [D]."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def _group_by_expert(flat_e: jax.Array, num_experts: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bucket assignments by expert id (sort-based, static shapes).

    flat_e: [A] int32 (−1 = masked). Returns (gid [A] — group index per
    assignment, pos [A] — row within the group's capacity, rep_e [G] —
    expert id per group, padded groups get −1). The group axis is
    G = min(A, E+1): at most A distinct picks and at most E experts plus
    one group of masked (−1) assignments, which sort first into group 0.
    Group capacity stays A (worst case: every assignment picks the same
    expert), so the dispatch buffer is [G, A, D].
    """
    A = flat_e.shape[0]
    G = min(A, num_experts + 1)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), se[1:] != se[:-1]]) if A > 1 else \
        jnp.ones((1,), bool)
    gid_sorted = jnp.cumsum(first) - 1
    seg_start = jax.lax.cummax(jnp.where(first, jnp.arange(A), 0))
    pos_sorted = jnp.arange(A) - seg_start
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(A))
    rep_e = jnp.full((G,), -1, flat_e.dtype).at[gid_sorted].set(
        se, mode="drop")
    return gid_sorted[inv], pos_sorted[inv], rep_e


class ProbeResult(NamedTuple):
    """Everything probe() learned about one layer's demand picks.

    state    — post-access cache bookkeeping (landed, tags/age/flags
               updated); commit() installs it.
    hits     — [A] reported demand hits (in-flight reservations miss).
    spec_hits— [A] demand hits manufactured by a landed reservation.
    valid    — [A] unmasked assignments (active row, expert >= 0).
    flat_e   — [A] expert id per assignment (-1 = masked).
    gid/pos  — [A] dispatch coordinates (group index / row in group).
    rep_e    — [G] unique expert id per group (-1 = padded group).
    resident — [G] group residency at probe time: execute() reads these
               groups from the slot buffer, the rest from the host tier.
    res_way  — [G] way of resident groups.
    """
    state: cache_lib.CacheState
    hits: jax.Array
    spec_hits: jax.Array
    valid: jax.Array
    flat_e: jax.Array
    gid: jax.Array
    pos: jax.Array
    rep_e: jax.Array
    resident: jax.Array
    res_way: jax.Array


def probe(tiers: ExpertTiers, layer: jax.Array, top_i: jax.Array,
          ccfg: CacheConfig,
          active: Optional[jax.Array] = None) -> ProbeResult:
    """Stage 1 — cache check + grouping for one layer's top-k picks.

    Lands outstanding reservations first (one probe boundary = one
    transfer deadline), services the demand access, and buckets the
    step's assignments by unique expert for the grouped kernels.
    Residency for *execution* is probed against the landed PRE-access
    state: a slot claimed this step holds its weights only from the next
    step on (the post-fetch is off the critical path)."""
    T, K = top_i.shape
    flat_e = top_i.reshape(-1).astype(jnp.int32)
    if active is not None:
        flat_e = jnp.where(jnp.repeat(active, K), flat_e, -1)
    valid = flat_e >= 0
    state0 = cache_lib.land(tiers.state)
    new_state, hits, _, spec_hits = cache_lib.access_ex(
        state0, layer, flat_e, ccfg.policy)
    gid, pos, rep_e = _group_by_expert(flat_e, tiers.host_w1.shape[1])
    resident, res_way = cache_lib.lookup(state0, layer, rep_e)
    return ProbeResult(state=new_state, hits=hits, spec_hits=spec_hits,
                       valid=valid, flat_e=flat_e, gid=gid, pos=pos,
                       rep_e=rep_e, resident=resident, res_way=res_way)


def _gather_group_weights(tiers: ExpertTiers, layer, pr: ProbeResult,
                          ccfg: CacheConfig):
    """Gather each unique expert's weights once — resident experts from the
    slot buffer (fast tier), others from the host table (slow tier)."""
    resident, way = pr.resident, pr.res_way
    slots = cache_lib.slot_id(layer, jnp.maximum(way, 0), ccfg.num_ways)
    slots = jnp.where(resident, slots, 0)
    e_ix = jnp.maximum(pr.rep_e, 0)
    r3 = resident[:, None, None]
    host_w1 = tiers.host_w1[layer, e_ix]
    host_w3 = tiers.host_w3[layer, e_ix]
    host_w2 = tiers.host_w2[layer, e_ix]
    w1 = jnp.where(r3, tiers.slot_w1[slots], host_w1)
    w3 = jnp.where(r3, tiers.slot_w3[slots], host_w3)
    w2 = jnp.where(r3, tiers.slot_w2[slots], host_w2)
    return (w1, w3, w2), (host_w1, host_w3, host_w2)


def _stage_dispatch(x: jax.Array, K: int, pr: ProbeResult
                    ) -> Tuple[jax.Array, jax.Array]:
    """Assemble the [G, A, D] per-unique-expert dispatch buffer for one
    layer's assignments. Returns (tok [A] — token row per assignment,
    xbuf). ONE copy of this math feeds execute(), the offloaded variant
    and the hostexec dispatcher — the bit-exactness contracts between
    those paths ride on it."""
    T = x.shape[0]
    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]                                            # [A, D]
    A, G = pr.flat_e.shape[0], pr.rep_e.shape[0]
    xbuf = jnp.zeros((G, A, x.shape[-1]), x.dtype).at[pr.gid, pr.pos].set(xa)
    return tok, xbuf


def execute(tiers: ExpertTiers, layer: jax.Array, x: jax.Array,
            top_w: jax.Array, pr: ProbeResult, ccfg: CacheConfig
            ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """Stage 2 — grouped tiered execution through the gmm kernels.

    Returns (y [T, D], host-tier gathers of the step's unique experts —
    reused by commit()'s post-fetch so each expert's host read happens
    once per step)."""
    T, K = top_w.shape
    tok, xbuf = _stage_dispatch(x, K, pr)
    w, host_w = _gather_group_weights(tiers, layer, pr, ccfg)
    ybuf = moe_ffn(xbuf, *w)                               # [G, A, D]
    y = _combine(ybuf, pr.gid, pr.pos, tok, top_w, pr.valid, T, x.dtype)
    return y, host_w


def commit(tiers: ExpertTiers, layer: jax.Array, pr: ProbeResult, host_w,
           ccfg: CacheConfig) -> Tuple[ExpertTiers, jax.Array]:
    """Stage 3 — install the probe's cache state and post-fetch the newly
    inserted experts' weights into their slots (async-schedulable: no data
    path back to this layer's output). Returns (tiers, fetch [G] bool)."""
    s_w1, s_w3, s_w2, fetch = _post_fetch(
        tiers, layer, pr.rep_e, pr.resident, pr.res_way, pr.state, host_w,
        ccfg)
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=pr.state)
    return tiers, fetch


def prediction_votes(flat_p: jax.Array) -> jax.Array:
    """Cross-batch vote count per predicted pick.

    flat_p: [A] int32 (-1 = masked). Votes are pairwise equality counts:
    an expert predicted by V assignments scores V on each of its picks;
    masked picks score 0. The count is the reservation's retention rank —
    :func:`prefetch` passes it as ``reserve``'s age-stamp priority, so
    when a later eviction must take a reserved way it takes the
    least-voted reservation first. Deliberately NOT an insertion reorder:
    claims are first-come-first-served and the demand probes that land
    reservations run in the same row order the picks arrive in, so
    reordering picks misaligns the claimed set from the earliest probes
    (measured: reordering by votes in either direction LOSES speculative
    hits on the live fig6 workload; priority-stamping gains them)."""
    valid = flat_p >= 0
    votes = ((flat_p[:, None] == flat_p[None, :])
             & valid[:, None] & valid[None, :]).sum(-1)
    return votes.astype(jnp.int32)


def prefetch(tiers: ExpertTiers, layer: jax.Array, pred_i: jax.Array,
             ccfg: CacheConfig, active: Optional[jax.Array] = None,
             rank_votes: bool = False
             ) -> Tuple[ExpertTiers, jax.Array, jax.Array, jax.Array]:
    """Stage 4 — speculative cross-layer prefetch into reserved slots.

    pred_i: [T, K] *predicted* expert picks for ``layer`` (typically the
    next layer's router run on the current hidden state). Reserves slots
    with policy-correct eviction but no demand accounting, then writes the
    issued experts' host-tier weights into the claimed slots, once per
    unique predicted expert. The reservations stay in-flight until the
    next probe lands them — a same-step probe still reads the host tier.

    ``rank_votes`` ranks the reservations by cross-batch vote count (see
    :func:`prediction_votes`): an expert several rows predict keeps its
    way longer than a single row's pick — batch-aware retention priority,
    computed after the ``active`` fold so padded rows never vote. Claim
    order is untouched (reordering picks misaligns the claimed set from
    the demand probes' row order — measured loss).

    Returns (tiers, rep_p [G] unique predicted expert per group,
    issued [G] bool — groups whose reservation claimed a slot (one host
    fetch each), n_issued scalar)."""
    T, K = pred_i.shape
    flat_p = pred_i.reshape(-1).astype(jnp.int32)
    if active is not None:
        flat_p = jnp.where(jnp.repeat(active, K), flat_p, -1)
    priority = prediction_votes(flat_p) if rank_votes else None
    new_state, issued_a, ways_a = cache_lib.reserve(
        tiers.state, layer, flat_p, ccfg.policy, priority=priority)
    gid, _, rep_p = _group_by_expert(flat_p, tiers.host_w1.shape[1])
    G = rep_p.shape[0]
    # duplicates of one expert reserve at most once, so at most one pick
    # per group carries issued=True — fold picks onto their groups
    issued = jnp.zeros((G,), bool).at[gid].max(issued_a)
    way = jnp.zeros((G,), jnp.int32).at[gid].add(
        jnp.where(issued_a, ways_a, 0))
    # stream the issued experts' weights into the reserved slots (the
    # speculative transfer the in-flight flag models; next probe lands it)
    e_ix = jnp.maximum(rep_p, 0)
    S = tiers.slot_w1.shape[0]
    dst = cache_lib.slot_id(layer, way, ccfg.num_ways)
    dst = jnp.where(issued, dst, S)    # out-of-range + drop = no write
    s_w1 = tiers.slot_w1.at[dst].set(tiers.host_w1[layer, e_ix], mode="drop")
    s_w3 = tiers.slot_w3.at[dst].set(tiers.host_w3[layer, e_ix], mode="drop")
    s_w2 = tiers.slot_w2.at[dst].set(tiers.host_w2[layer, e_ix], mode="drop")
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return tiers, rep_p, issued, issued_a.sum()


def _post_fetch(tiers: ExpertTiers, layer, rep_e, resident, res_way,
                new_state, host_w, ccfg: CacheConfig):
    """Write inserted experts' weights into their slots, once per unique
    expert. Probes the POST-step state: an expert is fetched iff its final
    (expert -> way) mapping is not already backed by the buffer — newly
    resident, or evicted-and-reinserted at a different way within the step
    (possible when picks exceed the ways). An expert inserted then evicted
    within the same step is correctly skipped. Output `y` never reads
    these writes."""
    new_res, new_way = cache_lib.lookup(new_state, layer, rep_e)
    fetch = new_res & ~(resident & (new_way == res_way))
    dst = cache_lib.slot_id(layer, new_way, ccfg.num_ways)
    # out-of-range destination + mode="drop" suppresses non-fetched rows
    dst = jnp.where(fetch, dst, tiers.slot_w1.shape[0])
    host_w1, host_w3, host_w2 = host_w
    s_w1 = tiers.slot_w1.at[dst].set(host_w1, mode="drop")
    s_w3 = tiers.slot_w3.at[dst].set(host_w3, mode="drop")
    s_w2 = tiers.slot_w2.at[dst].set(host_w2, mode="drop")
    return s_w1, s_w3, s_w2, fetch


def _combine(ybuf, gid, pos, tok, top_w, valid, T, x_dtype):
    ya = ybuf[gid, pos]
    scale = top_w.reshape(-1) * valid.astype(jnp.float32)
    ya = ya * scale[:, None].astype(ya.dtype)
    return jnp.zeros((T, ybuf.shape[-1]), x_dtype).at[tok].add(ya) \
        .astype(x_dtype)


def _stats(pr: ProbeResult, fetch):
    return {
        "hits": pr.hits.sum(),
        "accesses": pr.valid.sum().astype(jnp.int32),
        "host_flops_assignments": (pr.valid & ~pr.hits).sum(),
        "fetched_experts": fetch.sum(),
        "prefetch_hits": pr.spec_hits.sum(),
    }


def collaborative_moe(tiers: ExpertTiers, layer: jax.Array, x: jax.Array,
                      top_i: jax.Array, top_w: jax.Array, ccfg: CacheConfig,
                      active: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, ExpertTiers, Dict[str, jax.Array]]:
    """Execute one MoE layer for a decode micro-batch through the tiers —
    the probe → execute → commit composition (no prefetch stage; the
    serving engine drives the stages itself to interleave prefetch).

    x: [T, D]; top_i/top_w: [T, K]. layer: traced scalar (the scan
    counter). active: optional [T] bool — rows of padded scheduler slots
    are masked out of the cache, the stats and the output when False.
    Returns (y [T, D], updated tiers, stats).
    """
    pr = probe(tiers, layer, top_i, ccfg, active=active)
    y, host_w = execute(tiers, layer, x, top_w, pr, ccfg)
    tiers, fetch = commit(tiers, layer, pr, host_w, ccfg)
    return y, tiers, _stats(pr, fetch)


def collaborative_moe_offloaded(tiers: ExpertTiers, layer: jax.Array,
                                x: jax.Array, top_i: jax.Array,
                                top_w: jax.Array, ccfg: CacheConfig,
                                active: Optional[jax.Array] = None
                                ) -> Tuple[jax.Array, ExpertTiers,
                                           Dict[str, jax.Array]]:
    """The paper's workflow with *literal* memory-space semantics.

    Requires ``offload_host_tier(tiers)`` first (host weights in the host
    memory space). Then, inside one jitted step:
      * non-resident experts' grouped FFNs execute under
        ``compute_on("device_host")`` reading host-space weights — the
        paper's CPU compute;
      * the dispatch buffer crosses to host and the results cross back —
        the paper's 0.11 ms activation round-trip;
      * post-fetch gathers newly inserted experts' weights host-side (once
        per unique expert) and device_puts them into the cache slot
        buffers — the paper's asynchronous PCIe weight copy (XLA schedules
        it off the output's critical path exactly as in the default
        implementation).

    Same numerics as :func:`collaborative_moe` (tested); use this variant
    on hardware where the host tier genuinely does not fit HBM. Resident
    groups run through the same grouped gmm kernels as the default path;
    host groups use the jnp oracle (Pallas does not lower to the host
    compute stream).
    """
    from jax.experimental.compute_on import compute_on
    from jax.sharding import SingleDeviceSharding

    # single-device serving path (the paper's setting); must run under
    # jit — memory-space transfers are compile-time placements
    host_kind, dev_kind = memory_kinds()
    if host_kind is None:
        raise RuntimeError("backend exposes no host memory space")
    dev = jax.devices()[0]
    host_s = SingleDeviceSharding(dev, memory_kind=host_kind)
    dev_s = SingleDeviceSharding(dev, memory_kind=dev_kind)

    # shared staged preamble: cache check + grouping (stage 1)
    T, K = top_i.shape
    pr = probe(tiers, layer, top_i, ccfg, active=active)
    gid, pos, rep_e = pr.gid, pr.pos, pr.rep_e
    resident, way = pr.resident, pr.res_way

    tok, xbuf = _stage_dispatch(x, K, pr)
    slots = jnp.where(resident,
                      cache_lib.slot_id(layer, jnp.maximum(way, 0),
                                        ccfg.num_ways), 0)
    e_ix = jnp.maximum(rep_e, 0)

    # device path (resident groups): reads only the HBM slot buffers
    ybuf_dev = moe_ffn(xbuf, tiers.slot_w1[slots], tiers.slot_w3[slots],
                       tiers.slot_w2[slots])

    # host path (non-resident groups): dispatch buffer crosses to host,
    # the grouped FFN runs there against host-space weights
    @compute_on("device_host")
    @jax.jit
    def host_groups(hw1, hw3, hw2, xh, eh, lh):
        # two-step indexing: mixed-space index broadcasting inside
        # compute_on trips XLA; dynamic layer slice + row gather doesn't
        w1 = jax.lax.dynamic_index_in_dim(hw1, lh, 0, keepdims=False)[eh]
        w3 = jax.lax.dynamic_index_in_dim(hw3, lh, 0, keepdims=False)[eh]
        w2 = jax.lax.dynamic_index_in_dim(hw2, lh, 0, keepdims=False)[eh]
        return moe_ffn_ref(xh, w1, w3, w2)

    xb_h = jax.device_put(xbuf, host_s)
    e_h = jax.device_put(e_ix, host_s)
    l_h = jax.device_put(layer, host_s)
    ybuf_host = jax.device_put(
        host_groups(tiers.host_w1, tiers.host_w3, tiers.host_w2,
                    xb_h, e_h, l_h), dev_s)
    ybuf = jnp.where(resident[:, None, None], ybuf_dev, ybuf_host)
    y = _combine(ybuf, gid, pos, tok, top_w, pr.valid, T, x.dtype)

    # post-fetch: host-side gather of the newly inserted experts (once per
    # unique expert), then the explicit host->device copy into the slots
    @compute_on("device_host")
    @jax.jit
    def host_gather(hw, eh, lh):
        return jax.lax.dynamic_index_in_dim(hw, lh, 0, keepdims=False)[eh]

    src1 = jax.device_put(host_gather(tiers.host_w1, e_h, l_h), dev_s)
    src3 = jax.device_put(host_gather(tiers.host_w3, e_h, l_h), dev_s)
    src2 = jax.device_put(host_gather(tiers.host_w2, e_h, l_h), dev_s)
    tiers, fetch = commit(tiers, layer, pr, (src1, src3, src2), ccfg)
    return y, tiers, _stats(pr, fetch)


def collaborative_moe_reference(tiers: ExpertTiers, layer: jax.Array,
                                x: jax.Array, top_i: jax.Array,
                                top_w: jax.Array, ccfg: CacheConfig
                                ) -> Tuple[jax.Array, ExpertTiers,
                                           Dict[str, jax.Array]]:
    """The seed per-assignment path: dense dual gathers + vmapped
    single-row FFNs + a sequential post-fetch scan. Kept as the parity
    oracle and benchmark baseline for :func:`collaborative_moe` — do not
    use in serving code. (Known limitation, inherited: duplicate picks of
    a non-resident expert across concurrent tokens read the stale slot
    buffer — the grouped path fixes this.)
    """
    T, K = top_i.shape
    A = T * K
    flat_e = top_i.reshape(-1)

    new_state, hits, ways = cache_lib.access_scan_reference(
        tiers.state, layer, flat_e, ccfg.policy)
    slots = cache_lib.slot_id(layer, jnp.maximum(ways, 0), ccfg.num_ways)
    slots = jnp.where(ways >= 0, slots, 0)

    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]                                            # [A, D]
    w1_dev = tiers.slot_w1[slots]
    w3_dev = tiers.slot_w3[slots]
    w2_dev = tiers.slot_w2[slots]
    w1_host = tiers.host_w1[layer, flat_e]
    w3_host = tiers.host_w3[layer, flat_e]
    w2_host = tiers.host_w2[layer, flat_e]

    y_dev = jax.vmap(_ffn_one)(w1_dev, w3_dev, w2_dev, xa)      # GPU path
    y_host = jax.vmap(_ffn_one)(w1_host, w3_host, w2_host, xa)  # CPU path
    ya = jnp.where(hits[:, None], y_dev, y_host)
    ya = ya * top_w.reshape(-1)[:, None].astype(ya.dtype)
    y = jnp.zeros_like(x).at[tok].add(ya)

    do_fetch = (~hits) & (ways >= 0)

    def fetch(carry, inp):
        s_w1, s_w3, s_w2 = carry
        slot, e, do = inp
        src1 = tiers.host_w1[layer, e]
        src3 = tiers.host_w3[layer, e]
        src2 = tiers.host_w2[layer, e]
        upd = lambda buf, src: jax.lax.dynamic_update_index_in_dim(
            buf, jnp.where(do, src, buf[slot]), slot, 0)
        return (upd(s_w1, src1), upd(s_w3, src3), upd(s_w2, src2)), None

    (s_w1, s_w3, s_w2), _ = jax.lax.scan(
        fetch, (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2),
        (slots, flat_e, do_fetch))

    stats = {
        "hits": hits.sum(),
        "accesses": jnp.asarray(A, jnp.int32),
        "host_flops_assignments": (~hits).sum(),
        "fetched_experts": do_fetch.sum(),
    }
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return y, tiers, stats
