"""Two-tier collaborative MoE execution — the paper's workflow (Fig. 4).

Per MoE layer of a decode step:

  (1) cache check    — probe the set-associative cache for the router's
                       top-k experts (repro.core.cache, inside the jit).
  (2) execute        — *grouped*: the step's assignments are bucketed by
                       unique expert into an [G, C, D] dispatch buffer and
                       executed by the grouped Pallas kernels
                       (repro.kernels.moe_gmm.ops.moe_ffn). Each unique
                       expert's weights are gathered ONCE per step —
                       resident experts from the *device tier* (the
                       [N*M, ...] cache slot buffer in fast memory),
                       non-resident experts from the *host tier* (full
                       expert table, host memory space on real hardware).
  (3) post-fetch     — newly inserted experts' weights are written into
                       their assigned cache slots, once per unique expert.
                       The write feeds only *future* steps (no data path to
                       this layer's output), so XLA overlaps the copy with
                       downstream compute — the TPU analogue of the paper's
                       second copy engine / dual CUDA streams.

The seed implementation executed every assignment separately (dense
per-assignment weight gathers + a vmapped single-row FFN) — it is retained
as :func:`collaborative_moe_reference` for parity tests and benchmarks.
Grouping also fixes a latent seed bug: when two concurrent requests picked
the same non-resident expert, the seed's second assignment was marked a
cache hit (the bookkeeping insert from the first assignment) and read the
*stale* slot buffer; the grouped path derives each unique expert's tier
from its residency *before* the step, so both assignments read the host
tier and compute correctly.

All state (CacheState + slot buffer) threads functionally through the
serving step; donate both so the updates are in-place on device.

TPU note: on real hardware ``host`` lives in pinned host memory
(``jax.device_put(..., TransferToMemoryKind("pinned_host"))``); on this CPU
container both tiers are ordinary buffers and the *cost model*
(repro.core.costmodel) carries the performance semantics.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig
from repro.kernels.moe_gmm.ops import moe_ffn
from repro.kernels.moe_gmm.ref import moe_ffn_ref
from . import cache as cache_lib

Params = Dict[str, jax.Array]


class ExpertTiers(NamedTuple):
    """The two memory tiers for one model's MoE expert weights.

    host_*: [L_moe, E, ...] — the full expert table (slow tier).
    slot_*: [N*M, ...]      — the device cache slot buffer (fast tier).
    state : CacheState      — tags/age/clock.
    """
    host_w1: jax.Array     # [L, E, D, F]
    host_w3: jax.Array
    host_w2: jax.Array     # [L, E, F, D]
    slot_w1: jax.Array     # [N*M, D, F]
    slot_w3: jax.Array
    slot_w2: jax.Array     # [N*M, F, D]
    state: cache_lib.CacheState


def memory_kinds() -> Tuple[Optional[str], str]:
    """(host_kind, device_kind) for the literal two-tier placement.

    host_kind prefers ``pinned_host`` (TPU: host DRAM over PCIe) and falls
    back to ``unpinned_host``; None when the backend exposes no host space.
    device_kind is the backend's default memory. On this CPU container both
    resolve to ``unpinned_host`` — the placement degenerates to ordinary
    buffers but the program structure (and tests) stay identical.
    """
    dev = jax.devices()[0]
    kinds = {m.kind for m in dev.addressable_memories()}
    host = next((k for k in ("pinned_host", "unpinned_host") if k in kinds),
                None)
    return host, dev.default_memory().kind


def host_offload_supported() -> bool:
    return memory_kinds()[0] is not None


def offload_host_tier(tiers: ExpertTiers, device=None) -> ExpertTiers:
    """Place the host-tier expert table in the host memory space.

    This is the literal JAX expression of the paper's slow tier: the full
    expert table leaves accelerator HBM; hit-path reads touch only the
    HBM-resident slot buffers, miss-path reads stream over the host link.
    (Works on CPU and TPU backends; on TPU this is host DRAM over PCIe.)
    """
    from jax.sharding import SingleDeviceSharding
    host_kind, _ = memory_kinds()
    if host_kind is None:
        raise RuntimeError(
            "backend exposes no host memory space "
            "(need pinned_host or unpinned_host)")
    dev = device or jax.devices()[0]
    s = SingleDeviceSharding(dev, memory_kind=host_kind)
    return tiers._replace(
        host_w1=jax.device_put(tiers.host_w1, s),
        host_w3=jax.device_put(tiers.host_w3, s),
        host_w2=jax.device_put(tiers.host_w2, s),
    )


def init_tiers(host_w1, host_w3, host_w2, ccfg: CacheConfig,
               num_experts: int = 0, key=None) -> ExpertTiers:
    S = ccfg.num_slots
    D, F = host_w1.shape[-2], host_w1.shape[-1]
    state = cache_lib.init_cache_state(ccfg, num_experts, key)
    tiers = ExpertTiers(
        host_w1=host_w1, host_w3=host_w3, host_w2=host_w2,
        slot_w1=jnp.zeros((S, D, F), host_w1.dtype),
        slot_w3=jnp.zeros((S, D, F), host_w3.dtype),
        slot_w2=jnp.zeros((S, F, D), host_w2.dtype),
        state=state,
    )
    if ccfg.policy == "random":
        # static placement: preload the pinned experts once
        tiers = _preload_static(tiers, ccfg)
    return tiers


def _preload_static(tiers: ExpertTiers, ccfg: CacheConfig) -> ExpertTiers:
    n, m = ccfg.num_indexes, ccfg.num_ways
    layers = jnp.repeat(jnp.arange(n), m)
    experts = tiers.state.tags.reshape(-1)
    w1 = tiers.host_w1[layers, experts]
    w3 = tiers.host_w3[layers, experts]
    w2 = tiers.host_w2[layers, experts]
    return tiers._replace(slot_w1=w1, slot_w3=w3, slot_w2=w2)


def _ffn_one(w1, w3, w2, x):
    """SwiGLU expert FFN for one token row x: [D]."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def _group_by_expert(flat_e: jax.Array, num_experts: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bucket assignments by expert id (sort-based, static shapes).

    flat_e: [A] int32 (−1 = masked). Returns (gid [A] — group index per
    assignment, pos [A] — row within the group's capacity, rep_e [G] —
    expert id per group, padded groups get −1). The group axis is
    G = min(A, E+1): at most A distinct picks and at most E experts plus
    one group of masked (−1) assignments, which sort first into group 0.
    Group capacity stays A (worst case: every assignment picks the same
    expert), so the dispatch buffer is [G, A, D].
    """
    A = flat_e.shape[0]
    G = min(A, num_experts + 1)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), se[1:] != se[:-1]]) if A > 1 else \
        jnp.ones((1,), bool)
    gid_sorted = jnp.cumsum(first) - 1
    seg_start = jax.lax.cummax(jnp.where(first, jnp.arange(A), 0))
    pos_sorted = jnp.arange(A) - seg_start
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(A))
    rep_e = jnp.full((G,), -1, flat_e.dtype).at[gid_sorted].set(
        se, mode="drop")
    return gid_sorted[inv], pos_sorted[inv], rep_e


def _grouped_weights(tiers: ExpertTiers, layer, rep_e, ccfg: CacheConfig):
    """Gather each unique expert's weights once — resident experts from the
    slot buffer (fast tier), others from the host table (slow tier).
    Residency is probed against the PRE-step cache state: a slot assigned
    to an expert this step holds its weights only from the next step on
    (the post-fetch is off the critical path)."""
    resident, way = cache_lib.lookup(tiers.state, layer, rep_e)
    slots = cache_lib.slot_id(layer, jnp.maximum(way, 0), ccfg.num_ways)
    slots = jnp.where(resident, slots, 0)
    e_ix = jnp.maximum(rep_e, 0)
    r3 = resident[:, None, None]
    host_w1 = tiers.host_w1[layer, e_ix]
    host_w3 = tiers.host_w3[layer, e_ix]
    host_w2 = tiers.host_w2[layer, e_ix]
    w1 = jnp.where(r3, tiers.slot_w1[slots], host_w1)
    w3 = jnp.where(r3, tiers.slot_w3[slots], host_w3)
    w2 = jnp.where(r3, tiers.slot_w2[slots], host_w2)
    return resident, way, (w1, w3, w2), (host_w1, host_w3, host_w2)


def _post_fetch(tiers: ExpertTiers, layer, rep_e, resident, res_way,
                new_state, host_w, ccfg: CacheConfig):
    """Write inserted experts' weights into their slots, once per unique
    expert. Probes the POST-step state: an expert is fetched iff its final
    (expert -> way) mapping is not already backed by the buffer — newly
    resident, or evicted-and-reinserted at a different way within the step
    (possible when picks exceed the ways). An expert inserted then evicted
    within the same step is correctly skipped. Output `y` never reads
    these writes."""
    new_res, new_way = cache_lib.lookup(new_state, layer, rep_e)
    fetch = new_res & ~(resident & (new_way == res_way))
    dst = cache_lib.slot_id(layer, new_way, ccfg.num_ways)
    # out-of-range destination + mode="drop" suppresses non-fetched rows
    dst = jnp.where(fetch, dst, tiers.slot_w1.shape[0])
    host_w1, host_w3, host_w2 = host_w
    s_w1 = tiers.slot_w1.at[dst].set(host_w1, mode="drop")
    s_w3 = tiers.slot_w3.at[dst].set(host_w3, mode="drop")
    s_w2 = tiers.slot_w2.at[dst].set(host_w2, mode="drop")
    return s_w1, s_w3, s_w2, fetch


def _combine(ybuf, gid, pos, tok, top_w, valid, T, x_dtype):
    ya = ybuf[gid, pos]
    scale = top_w.reshape(-1) * valid.astype(jnp.float32)
    ya = ya * scale[:, None].astype(ya.dtype)
    return jnp.zeros((T, ybuf.shape[-1]), x_dtype).at[tok].add(ya) \
        .astype(x_dtype)


def _stats(hits, valid, fetch):
    return {
        "hits": hits.sum(),
        "accesses": valid.sum().astype(jnp.int32),
        "host_flops_assignments": (valid & ~hits).sum(),
        "fetched_experts": fetch.sum(),
    }


def collaborative_moe(tiers: ExpertTiers, layer: jax.Array, x: jax.Array,
                      top_i: jax.Array, top_w: jax.Array, ccfg: CacheConfig,
                      active: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, ExpertTiers, Dict[str, jax.Array]]:
    """Execute one MoE layer for a decode micro-batch through the tiers.

    x: [T, D]; top_i/top_w: [T, K]. layer: traced scalar (the scan
    counter). active: optional [T] bool — rows of padded scheduler slots
    are masked out of the cache, the stats and the output when False.
    Returns (y [T, D], updated tiers, stats).
    """
    T, K = top_i.shape
    flat_e = top_i.reshape(-1).astype(jnp.int32)
    if active is not None:
        flat_e = jnp.where(jnp.repeat(active, K), flat_e, -1)
    valid = flat_e >= 0

    # (1) cache check + bookkeeping update (tags/age; sequential semantics)
    new_state, hits, _ = cache_lib.access(tiers.state, layer, flat_e,
                                          ccfg.policy)

    # (2) grouped execution through the gmm kernels
    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]                                            # [A, D]
    gid, pos, rep_e = _group_by_expert(flat_e, tiers.host_w1.shape[1])
    resident, res_way, w, host_w = _grouped_weights(tiers, layer, rep_e, ccfg)
    A, G = flat_e.shape[0], rep_e.shape[0]
    xbuf = jnp.zeros((G, A, x.shape[-1]), x.dtype).at[gid, pos].set(xa)
    ybuf = moe_ffn(xbuf, *w)                               # [G, A, D]

    # (3) post-fetch: reuse the execution path's host gather (one gather
    # per unique expert per step). Async-schedulable: y ignores the writes.
    s_w1, s_w3, s_w2, fetch = _post_fetch(tiers, layer, rep_e, resident,
                                          res_way, new_state, host_w, ccfg)

    y = _combine(ybuf, gid, pos, tok, top_w, valid, T, x.dtype)
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return y, tiers, _stats(hits, valid, fetch)


def collaborative_moe_offloaded(tiers: ExpertTiers, layer: jax.Array,
                                x: jax.Array, top_i: jax.Array,
                                top_w: jax.Array, ccfg: CacheConfig,
                                active: Optional[jax.Array] = None
                                ) -> Tuple[jax.Array, ExpertTiers,
                                           Dict[str, jax.Array]]:
    """The paper's workflow with *literal* memory-space semantics.

    Requires ``offload_host_tier(tiers)`` first (host weights in the host
    memory space). Then, inside one jitted step:
      * non-resident experts' grouped FFNs execute under
        ``compute_on("device_host")`` reading host-space weights — the
        paper's CPU compute;
      * the dispatch buffer crosses to host and the results cross back —
        the paper's 0.11 ms activation round-trip;
      * post-fetch gathers newly inserted experts' weights host-side (once
        per unique expert) and device_puts them into the cache slot
        buffers — the paper's asynchronous PCIe weight copy (XLA schedules
        it off the output's critical path exactly as in the default
        implementation).

    Same numerics as :func:`collaborative_moe` (tested); use this variant
    on hardware where the host tier genuinely does not fit HBM. Resident
    groups run through the same grouped gmm kernels as the default path;
    host groups use the jnp oracle (Pallas does not lower to the host
    compute stream).
    """
    from jax.experimental.compute_on import compute_on
    from jax.sharding import SingleDeviceSharding

    # single-device serving path (the paper's setting); must run under
    # jit — memory-space transfers are compile-time placements
    host_kind, dev_kind = memory_kinds()
    if host_kind is None:
        raise RuntimeError("backend exposes no host memory space")
    dev = jax.devices()[0]
    host_s = SingleDeviceSharding(dev, memory_kind=host_kind)
    dev_s = SingleDeviceSharding(dev, memory_kind=dev_kind)

    T, K = top_i.shape
    flat_e = top_i.reshape(-1).astype(jnp.int32)
    if active is not None:
        flat_e = jnp.where(jnp.repeat(active, K), flat_e, -1)
    valid = flat_e >= 0
    new_state, hits, _ = cache_lib.access(tiers.state, layer, flat_e,
                                          ccfg.policy)

    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]
    gid, pos, rep_e = _group_by_expert(flat_e, tiers.host_w1.shape[1])
    resident, way = cache_lib.lookup(tiers.state, layer, rep_e)
    slots = jnp.where(resident,
                      cache_lib.slot_id(layer, jnp.maximum(way, 0),
                                        ccfg.num_ways), 0)
    e_ix = jnp.maximum(rep_e, 0)
    A = flat_e.shape[0]
    xbuf = jnp.zeros((rep_e.shape[0], A, x.shape[-1]), x.dtype) \
        .at[gid, pos].set(xa)

    # device path (resident groups): reads only the HBM slot buffers
    ybuf_dev = moe_ffn(xbuf, tiers.slot_w1[slots], tiers.slot_w3[slots],
                       tiers.slot_w2[slots])

    # host path (non-resident groups): dispatch buffer crosses to host,
    # the grouped FFN runs there against host-space weights
    @compute_on("device_host")
    @jax.jit
    def host_groups(hw1, hw3, hw2, xh, eh, lh):
        # two-step indexing: mixed-space index broadcasting inside
        # compute_on trips XLA; dynamic layer slice + row gather doesn't
        w1 = jax.lax.dynamic_index_in_dim(hw1, lh, 0, keepdims=False)[eh]
        w3 = jax.lax.dynamic_index_in_dim(hw3, lh, 0, keepdims=False)[eh]
        w2 = jax.lax.dynamic_index_in_dim(hw2, lh, 0, keepdims=False)[eh]
        return moe_ffn_ref(xh, w1, w3, w2)

    xb_h = jax.device_put(xbuf, host_s)
    e_h = jax.device_put(e_ix, host_s)
    l_h = jax.device_put(layer, host_s)
    ybuf_host = jax.device_put(
        host_groups(tiers.host_w1, tiers.host_w3, tiers.host_w2,
                    xb_h, e_h, l_h), dev_s)
    ybuf = jnp.where(resident[:, None, None], ybuf_dev, ybuf_host)
    y = _combine(ybuf, gid, pos, tok, top_w, valid, T, x.dtype)

    # post-fetch: host-side gather of the newly inserted experts (once per
    # unique expert), then the explicit host->device copy into the slots
    @compute_on("device_host")
    @jax.jit
    def host_gather(hw, eh, lh):
        return jax.lax.dynamic_index_in_dim(hw, lh, 0, keepdims=False)[eh]

    src1 = jax.device_put(host_gather(tiers.host_w1, e_h, l_h), dev_s)
    src3 = jax.device_put(host_gather(tiers.host_w3, e_h, l_h), dev_s)
    src2 = jax.device_put(host_gather(tiers.host_w2, e_h, l_h), dev_s)
    s_w1, s_w3, s_w2, fetch = _post_fetch(
        tiers, layer, rep_e, resident, way, new_state, (src1, src3, src2),
        ccfg)

    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return y, tiers, _stats(hits, valid, fetch)


def collaborative_moe_reference(tiers: ExpertTiers, layer: jax.Array,
                                x: jax.Array, top_i: jax.Array,
                                top_w: jax.Array, ccfg: CacheConfig
                                ) -> Tuple[jax.Array, ExpertTiers,
                                           Dict[str, jax.Array]]:
    """The seed per-assignment path: dense dual gathers + vmapped
    single-row FFNs + a sequential post-fetch scan. Kept as the parity
    oracle and benchmark baseline for :func:`collaborative_moe` — do not
    use in serving code. (Known limitation, inherited: duplicate picks of
    a non-resident expert across concurrent tokens read the stale slot
    buffer — the grouped path fixes this.)
    """
    T, K = top_i.shape
    A = T * K
    flat_e = top_i.reshape(-1)

    new_state, hits, ways = cache_lib.access_scan_reference(
        tiers.state, layer, flat_e, ccfg.policy)
    slots = cache_lib.slot_id(layer, jnp.maximum(ways, 0), ccfg.num_ways)
    slots = jnp.where(ways >= 0, slots, 0)

    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]                                            # [A, D]
    w1_dev = tiers.slot_w1[slots]
    w3_dev = tiers.slot_w3[slots]
    w2_dev = tiers.slot_w2[slots]
    w1_host = tiers.host_w1[layer, flat_e]
    w3_host = tiers.host_w3[layer, flat_e]
    w2_host = tiers.host_w2[layer, flat_e]

    y_dev = jax.vmap(_ffn_one)(w1_dev, w3_dev, w2_dev, xa)      # GPU path
    y_host = jax.vmap(_ffn_one)(w1_host, w3_host, w2_host, xa)  # CPU path
    ya = jnp.where(hits[:, None], y_dev, y_host)
    ya = ya * top_w.reshape(-1)[:, None].astype(ya.dtype)
    y = jnp.zeros_like(x).at[tok].add(ya)

    do_fetch = (~hits) & (ways >= 0)

    def fetch(carry, inp):
        s_w1, s_w3, s_w2 = carry
        slot, e, do = inp
        src1 = tiers.host_w1[layer, e]
        src3 = tiers.host_w3[layer, e]
        src2 = tiers.host_w2[layer, e]
        upd = lambda buf, src: jax.lax.dynamic_update_index_in_dim(
            buf, jnp.where(do, src, buf[slot]), slot, 0)
        return (upd(s_w1, src1), upd(s_w3, src3), upd(s_w2, src2)), None

    (s_w1, s_w3, s_w2), _ = jax.lax.scan(
        fetch, (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2),
        (slots, flat_e, do_fetch))

    stats = {
        "hits": hits.sum(),
        "accesses": jnp.asarray(A, jnp.int32),
        "host_flops_assignments": (~hits).sum(),
        "fetched_experts": do_fetch.sum(),
    }
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return y, tiers, stats
