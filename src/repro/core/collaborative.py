"""Two-tier collaborative MoE execution — the paper's workflow (Fig. 4).

Per MoE layer of a decode step:

  (1) cache check    — probe the set-associative cache for the router's
                       top-k experts (repro.core.cache, inside the jit).
  (2) execute        — hit experts compute from the *device tier* (the
                       [N*M, ...] cache slot buffer in fast memory); missed
                       experts compute from the *host tier* (full expert
                       table, host memory space on real hardware).
  (3) post-fetch     — missed experts' weights are written into their
                       assigned cache slots. The write feeds only *future*
                       steps (no data path to this layer's output), so XLA
                       overlaps the copy with downstream compute — the TPU
                       analogue of the paper's second copy engine / dual
                       CUDA streams.

All state (CacheState + slot buffer) threads functionally through the
serving step; donate both so the updates are in-place on device.

TPU note: on real hardware ``host`` lives in pinned host memory
(``jax.device_put(..., TransferToMemoryKind("pinned_host"))``); on this CPU
container both tiers are ordinary buffers and the *cost model*
(repro.core.costmodel) carries the performance semantics.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig, ModelConfig
from . import cache as cache_lib

Params = Dict[str, jax.Array]


class ExpertTiers(NamedTuple):
    """The two memory tiers for one model's MoE expert weights.

    host_*: [L_moe, E, ...] — the full expert table (slow tier).
    slot_*: [N*M, ...]      — the device cache slot buffer (fast tier).
    state : CacheState      — tags/age/clock.
    """
    host_w1: jax.Array     # [L, E, D, F]
    host_w3: jax.Array
    host_w2: jax.Array     # [L, E, F, D]
    slot_w1: jax.Array     # [N*M, D, F]
    slot_w3: jax.Array
    slot_w2: jax.Array     # [N*M, F, D]
    state: cache_lib.CacheState


def offload_host_tier(tiers: ExpertTiers, device=None) -> ExpertTiers:
    """Place the host-tier expert table in the `pinned_host` memory space.

    This is the literal JAX expression of the paper's slow tier: the full
    expert table leaves accelerator HBM; hit-path reads touch only the
    HBM-resident slot buffers, miss-path reads stream over the host link.
    (Works on CPU and TPU backends; on TPU this is host DRAM over PCIe.)
    """
    import jax
    from jax.sharding import SingleDeviceSharding
    dev = device or jax.devices()[0]
    s = SingleDeviceSharding(dev, memory_kind="pinned_host")
    return tiers._replace(
        host_w1=jax.device_put(tiers.host_w1, s),
        host_w3=jax.device_put(tiers.host_w3, s),
        host_w2=jax.device_put(tiers.host_w2, s),
    )


def init_tiers(host_w1, host_w3, host_w2, ccfg: CacheConfig,
               num_experts: int = 0, key=None) -> ExpertTiers:
    S = ccfg.num_slots
    D, F = host_w1.shape[-2], host_w1.shape[-1]
    state = cache_lib.init_cache_state(ccfg, num_experts, key)
    tiers = ExpertTiers(
        host_w1=host_w1, host_w3=host_w3, host_w2=host_w2,
        slot_w1=jnp.zeros((S, D, F), host_w1.dtype),
        slot_w3=jnp.zeros((S, D, F), host_w3.dtype),
        slot_w2=jnp.zeros((S, F, D), host_w2.dtype),
        state=state,
    )
    if ccfg.policy == "random":
        # static placement: preload the pinned experts once
        tiers = _preload_static(tiers, ccfg)
    return tiers


def _preload_static(tiers: ExpertTiers, ccfg: CacheConfig) -> ExpertTiers:
    n, m = ccfg.num_indexes, ccfg.num_ways
    layers = jnp.repeat(jnp.arange(n), m)
    experts = tiers.state.tags.reshape(-1)
    w1 = tiers.host_w1[layers, experts]
    w3 = tiers.host_w3[layers, experts]
    w2 = tiers.host_w2[layers, experts]
    return tiers._replace(slot_w1=w1, slot_w3=w3, slot_w2=w2)


def _ffn_one(w1, w3, w2, x):
    """SwiGLU expert FFN for one token row x: [D]."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def collaborative_moe(tiers: ExpertTiers, layer: jax.Array, x: jax.Array,
                      top_i: jax.Array, top_w: jax.Array, ccfg: CacheConfig
                      ) -> Tuple[jax.Array, ExpertTiers, Dict[str, jax.Array]]:
    """Execute one MoE layer for a decode micro-batch through the tiers.

    x: [T, D]; top_i/top_w: [T, K]. layer: traced scalar (the scan
    counter). Returns (y [T, D], updated tiers, stats).
    """
    T, K = top_i.shape
    A = T * K
    flat_e = top_i.reshape(-1)

    # (1) cache check + bookkeeping update (tags/age; sequential semantics)
    new_state, hits, ways = cache_lib.access(tiers.state, layer, flat_e,
                                             ccfg.policy)
    slots = cache_lib.slot_id(layer, jnp.maximum(ways, 0), ccfg.num_ways)
    slots = jnp.where(ways >= 0, slots, 0)

    # (2) execute: hit experts read the device slot buffer, missed experts
    # read the host tier. Both paths are dense gathers so the program stays
    # branchless; `hits` selects per assignment.
    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]                                            # [A, D]
    w1_dev = tiers.slot_w1[slots]
    w3_dev = tiers.slot_w3[slots]
    w2_dev = tiers.slot_w2[slots]
    w1_host = tiers.host_w1[layer, flat_e]
    w3_host = tiers.host_w3[layer, flat_e]
    w2_host = tiers.host_w2[layer, flat_e]

    y_dev = jax.vmap(_ffn_one)(w1_dev, w3_dev, w2_dev, xa)      # GPU path
    y_host = jax.vmap(_ffn_one)(w1_host, w3_host, w2_host, xa)  # CPU path
    ya = jnp.where(hits[:, None], y_dev, y_host)
    ya = ya * top_w.reshape(-1)[:, None].astype(ya.dtype)
    y = jnp.zeros_like(x).at[tok].add(ya)

    # (3) post-fetch: write missed experts' weights into their slots.
    # Output `y` does not depend on these writes -> async-schedulable.
    do_fetch = (~hits) & (ways >= 0)

    def fetch(carry, inp):
        s_w1, s_w3, s_w2 = carry
        slot, e, do = inp
        src1 = tiers.host_w1[layer, e]
        src3 = tiers.host_w3[layer, e]
        src2 = tiers.host_w2[layer, e]
        upd = lambda buf, src: jax.lax.dynamic_update_index_in_dim(
            buf, jnp.where(do, src, buf[slot]), slot, 0)
        return (upd(s_w1, src1), upd(s_w3, src3), upd(s_w2, src2)), None

    (s_w1, s_w3, s_w2), _ = jax.lax.scan(
        fetch, (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2),
        (slots, flat_e, do_fetch))

    stats = {
        "hits": hits.sum(),
        "accesses": jnp.asarray(A, jnp.int32),
        "host_flops_assignments": (~hits).sum(),
        "fetched_experts": do_fetch.sum(),
    }
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return y, tiers, stats


def collaborative_moe_offloaded(tiers: ExpertTiers, layer: jax.Array,
                                x: jax.Array, top_i: jax.Array,
                                top_w: jax.Array, ccfg: CacheConfig
                                ) -> Tuple[jax.Array, ExpertTiers,
                                           Dict[str, jax.Array]]:
    """The paper's workflow with *literal* memory-space semantics.

    Requires ``offload_host_tier(tiers)`` first (host weights in the
    ``pinned_host`` space). Then, inside one jitted step:
      * miss-path expert FFNs execute under ``compute_on("device_host")``
        reading host-space weights — the paper's CPU compute;
      * the activation rows cross to host and the results cross back —
        the paper's 0.11 ms activation round-trip;
      * post-fetch gathers missed experts' weights host-side and
        device_puts them into the cache slot buffers — the paper's
        asynchronous PCIe weight copy (XLA schedules it off the output's
        critical path exactly as in the default implementation).

    Same numerics as :func:`collaborative_moe` (tested); use this variant
    on hardware where the host tier genuinely does not fit HBM.
    """
    from jax.experimental.compute_on import compute_on
    from jax.sharding import SingleDeviceSharding

    # single-device serving path (the paper's setting); must run under
    # jit — memory-space transfers are compile-time placements
    dev = jax.devices()[0]
    host_s = SingleDeviceSharding(dev, memory_kind="pinned_host")
    dev_s = SingleDeviceSharding(dev, memory_kind="device")

    T, K = top_i.shape
    A = T * K
    flat_e = top_i.reshape(-1)
    new_state, hits, ways = cache_lib.access(tiers.state, layer, flat_e,
                                             ccfg.policy)
    slots = cache_lib.slot_id(layer, jnp.maximum(ways, 0), ccfg.num_ways)
    slots = jnp.where(ways >= 0, slots, 0)
    tok = jnp.repeat(jnp.arange(T), K)
    xa = x[tok]

    # device path (cache hits): reads only the HBM slot buffers
    y_dev = jax.vmap(_ffn_one)(tiers.slot_w1[slots], tiers.slot_w3[slots],
                               tiers.slot_w2[slots], xa)

    # host path (misses): activations cross to host, FFN runs there
    @compute_on("device_host")
    @jax.jit
    def host_path(hw1, hw3, hw2, xh, eh, lh):
        # two-step indexing: mixed-space index broadcasting inside
        # compute_on trips XLA; dynamic layer slice + row gather doesn't
        w1 = jax.lax.dynamic_index_in_dim(hw1, lh, 0, keepdims=False)[eh]
        w3 = jax.lax.dynamic_index_in_dim(hw3, lh, 0, keepdims=False)[eh]
        w2 = jax.lax.dynamic_index_in_dim(hw2, lh, 0, keepdims=False)[eh]
        return jax.vmap(_ffn_one)(w1, w3, w2, xh)

    xa_h = jax.device_put(xa, host_s)
    e_h = jax.device_put(flat_e, host_s)
    l_h = jax.device_put(layer, host_s)
    y_host = jax.device_put(
        host_path(tiers.host_w1, tiers.host_w3, tiers.host_w2,
                  xa_h, e_h, l_h), dev_s)

    ya = jnp.where(hits[:, None], y_dev, y_host)
    ya = ya * top_w.reshape(-1)[:, None].astype(ya.dtype)
    y = jnp.zeros_like(x).at[tok].add(ya)

    # post-fetch: host-side gather of the missed experts, then the
    # explicit host->device copy into the cache slots
    do_fetch = (~hits) & (ways >= 0)

    @compute_on("device_host")
    @jax.jit
    def host_gather(hw, eh, lh):
        return jax.lax.dynamic_index_in_dim(hw, lh, 0, keepdims=False)[eh]

    src1 = jax.device_put(host_gather(tiers.host_w1, e_h, l_h), dev_s)
    src3 = jax.device_put(host_gather(tiers.host_w3, e_h, l_h), dev_s)
    src2 = jax.device_put(host_gather(tiers.host_w2, e_h, l_h), dev_s)

    def fetch(carry, inp):
        s_w1, s_w3, s_w2 = carry
        slot, do, a1, a3, a2 = inp
        upd = lambda buf, src: jax.lax.dynamic_update_index_in_dim(
            buf, jnp.where(do, src, buf[slot]), slot, 0)
        return (upd(s_w1, a1), upd(s_w3, a3), upd(s_w2, a2)), None

    (s_w1, s_w3, s_w2), _ = jax.lax.scan(
        fetch, (tiers.slot_w1, tiers.slot_w3, tiers.slot_w2),
        (slots, do_fetch, src1, src3, src2))

    stats = {
        "hits": hits.sum(),
        "accesses": jnp.asarray(A, jnp.int32),
        "host_flops_assignments": (~hits).sum(),
        "fetched_experts": do_fetch.sum(),
    }
    tiers = tiers._replace(slot_w1=s_w1, slot_w3=s_w3, slot_w2=s_w2,
                           state=new_state)
    return y, tiers, stats
