"""Cache replacement policies: the shared PolicySpec + the numpy twin.

``PolicySpec`` is the single source of truth for what each eviction policy
does — both the JAX cache (repro.core.cache) and the numpy twin below
consume it, so the two implementations cannot drift on policy constants.

The numpy twin's semantics are bit-identical to repro.core.cache
(property tests replay random traces through both). Used by the
discrete-event simulator, which feeds it millions of router decisions —
far cheaper here than under jit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.config import CacheConfig


@dataclass(frozen=True)
class PolicySpec:
    """What one eviction policy does (paper §IV-D).

    name            — registry key ("lru" | "fifo" | "random").
    inserts_on_miss — False for the static-random baseline: its expert set
                      is pinned at init and never replaced.
    refresh_on_hit  — LRU touch-refresh; FIFO stamps on insert only.
    needs_key       — static placement draws its pinned experts at init.
    """
    name: str
    inserts_on_miss: bool
    refresh_on_hit: bool
    needs_key: bool

    @property
    def is_static(self) -> bool:
        return not self.inserts_on_miss


POLICY_SPECS: Dict[str, PolicySpec] = {
    "lru": PolicySpec("lru", inserts_on_miss=True, refresh_on_hit=True,
                      needs_key=False),
    "fifo": PolicySpec("fifo", inserts_on_miss=True, refresh_on_hit=False,
                       needs_key=False),
    "random": PolicySpec("random", inserts_on_miss=False,
                         refresh_on_hit=False, needs_key=True),
}


def policy_spec(name: str) -> PolicySpec:
    try:
        return POLICY_SPECS[name]
    except KeyError:
        raise ValueError(f"unknown cache policy {name!r}; "
                         f"have {sorted(POLICY_SPECS)}") from None


# Slot provenance/transfer flags — the single source of truth, consumed
# by both the JAX cache (repro.core.cache) and the numpy twin below, like
# PolicySpec, so the two implementations cannot drift on flag semantics.
FLAG_DEMAND = 0      # demand-resident (or empty slot)
FLAG_SPEC = 1        # speculative insert, transfer landed
FLAG_PENDING = 2     # speculative insert, transfer in flight


@dataclass
class NumpyCache:
    ccfg: CacheConfig
    num_experts: int = 0
    seed: int = 0
    tags: np.ndarray = field(init=False)
    age: np.ndarray = field(init=False)
    flags: np.ndarray = field(init=False)
    clock: int = field(init=False, default=0)
    hits: int = field(init=False, default=0)
    accesses: int = field(init=False, default=0)
    spec_hits: int = field(init=False, default=0)
    reserved: int = field(init=False, default=0)

    def __post_init__(self):
        n, m = self.ccfg.num_indexes, self.ccfg.num_ways
        self.spec = policy_spec(self.ccfg.policy)
        self.tags = np.full((n, m), -1, np.int64)
        self.age = np.zeros((n, m), np.int64)
        self.flags = np.zeros((n, m), np.int64)
        if self.spec.is_static:
            rng = np.random.default_rng(self.seed)
            assert self.num_experts >= m
            for i in range(n):
                self.tags[i] = rng.permutation(self.num_experts)[:m]

    def access(self, layer: int, experts) -> List[bool]:
        """Sequentially service one layer's expert picks; returns hit flags.

        Mirrors repro.core.cache.access_ex: a tag hit on a PENDING
        reservation reports a miss without re-inserting; the first demand
        hit on a landed SPEC entry counts toward ``spec_hits`` and
        promotes it to demand provenance."""
        out = []
        n, m = self.tags.shape
        covered = layer < n
        for e in experts:
            self.accesses += 1
            if not covered or e < 0:
                out.append(False)
                continue
            row_t, row_a = self.tags[layer], self.age[layer]
            row_f = self.flags[layer]
            ways = np.nonzero(row_t == e)[0]
            tag_hit = ways.size > 0
            pending = tag_hit and row_f[ways[0]] == FLAG_PENDING
            hit = tag_hit and not pending
            out.append(bool(hit))
            self.hits += int(hit)
            if self.spec.is_static:
                continue
            if tag_hit:
                way = ways[0]
                if self.spec.refresh_on_hit:
                    row_a[way] = self.clock
                if row_f[way] == FLAG_SPEC:
                    self.spec_hits += 1
                if not pending:
                    row_f[way] = FLAG_DEMAND
            else:
                empty = np.nonzero(row_t < 0)[0]
                way = empty[0] if empty.size else int(np.argmin(row_a))
                row_t[way] = e
                row_a[way] = self.clock
                row_f[way] = FLAG_DEMAND
            self.clock += 1
        return out

    def reserve(self, layer: int, experts, protect=None,
                priority=None) -> List[bool]:
        """Speculatively insert predicted experts (no demand accounting).

        Mirrors repro.core.cache.reserve: policy-correct victim selection
        with *batch protection* — a way holding any expert of the
        protected set (``protect``, defaulting to the insert batch) is
        never the victim, so reserving pick B cannot evict predicted pick
        A out from under the very probe the batch is staged for (fatal at
        low associativity); callers issuing picks one at a time under a
        transfer budget pass the full prediction batch as ``protect``.
        ``priority`` (per-pick int, default 0) adds to the inserted
        entry's age stamp so later min-age evictions take low-priority
        reservations first. Already-present experts are untouched, fresh
        inserts stay PENDING until :meth:`land`. Returns the issued flags
        (True = fetch enqueued)."""
        out = []
        n, m = self.tags.shape
        covered = layer < n
        if protect is None:
            protect = experts
        if priority is None:
            priority = [0] * len(experts)
        batch = np.asarray([e for e in protect if e >= 0], np.int64)
        for e, pr in zip(experts, priority):
            if not covered or e < 0 or self.spec.is_static:
                out.append(False)
                continue
            row_t, row_a, row_f = (self.tags[layer], self.age[layer],
                                   self.flags[layer])
            if (row_t == e).any():
                out.append(False)
                self.clock += 1
                continue
            empty = np.nonzero(row_t < 0)[0]
            if empty.size:
                way = int(empty[0])
            else:
                prot = np.isin(row_t, batch)
                if prot.all():
                    out.append(False)
                    self.clock += 1
                    continue
                way = int(np.argmin(np.where(prot, np.iinfo(np.int64).max,
                                             row_a)))
            row_t[way] = e
            row_a[way] = self.clock + int(pr)
            row_f[way] = FLAG_PENDING
            self.clock += 1
            self.reserved += 1
            out.append(True)
        return out

    def land(self) -> None:
        """Mark every PENDING reservation as arrived (PENDING -> SPEC)."""
        self.flags[self.flags == FLAG_PENDING] = FLAG_SPEC

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.accesses, 1)


def random_policy_hit_probs(num_experts: int, num_ways: int,
                            top_k: int = 2) -> Tuple[float, float]:
    """Paper §IV-D closed forms for the static-random cache (top-2):

    P(>=1 of 2 experts hit) = 1 - (n-M)/n * (n-M-1)/(n-1)
    P(both hit)             = M/n * (M-1)/(n-1)
    """
    n, M = num_experts, num_ways
    p_any = 1.0 - ((n - M) / n) * ((n - M - 1) / (n - 1))
    p_both = (M / n) * ((M - 1) / (n - 1))
    return p_any, p_both
