"""Router traces: synthetic generation calibrated to the paper's measured
expert-selection patterns, plus capture from live repro models.

The paper's Fig. 2 statistics for Mixtral 8x7B on MMLU:
  * Consecutive Tokens Pattern: P(>=1 of top-2 experts repeats from the
    previous token) ~= 0.4-0.6 per layer; among repeating tokens ~23%
    also share an expert with t-2 and ~18% with t-3+.
  * Consecutive Layers Pattern: ~44% of routers pick at least one expert
    id equal to the previous layer's pick.

The synthetic generator is a per-layer sticky-categorical process:
each of the K slots keeps its previous expert with prob `stickiness`,
otherwise resamples from a Zipf-skewed popularity distribution (dup-free
within a token). `layer_corr` biases the resample toward the previous
layer's picks, reproducing the layer pattern. Defaults are calibrated so
measured statistics fall in the paper's bands (tests assert this).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class TraceConfig:
    num_tokens: int
    num_layers: int
    num_experts: int
    top_k: int = 2
    # For E=8, K=2 the *random* consecutive-token overlap is already
    # 1 - C(6,2)/C(8,2) = 0.46; the paper's 40-60% band therefore implies
    # mild stickiness on top of chance overlap.
    stickiness: float = 0.10
    zipf_s: float = 0.30
    layer_corr: float = 0.15
    seed: int = 0


def synthetic_trace(tc: TraceConfig) -> np.ndarray:
    """Returns expert selections [num_tokens, num_layers, top_k] int64."""
    rng = np.random.default_rng(tc.seed)
    E, K, L, T = tc.num_experts, tc.top_k, tc.num_layers, tc.num_tokens
    # per-layer popularity (mild Zipf, randomly permuted per layer)
    base = (1.0 / np.arange(1, E + 1) ** tc.zipf_s)
    pops = np.stack([rng.permutation(base) for _ in range(L)])
    pops /= pops.sum(axis=1, keepdims=True)

    trace = np.zeros((T, L, K), np.int64)
    prev_tok = np.zeros((L, K), np.int64)
    for l in range(L):
        prev_tok[l] = rng.choice(E, size=K, replace=False, p=pops[l])
    trace[0] = prev_tok

    for t in range(1, T):
        prev_layer_pick: Optional[np.ndarray] = None
        for l in range(L):
            picked = []
            for k in range(K):
                keep = rng.random() < tc.stickiness
                e = prev_tok[l, k]
                if not keep or e in picked:
                    p = pops[l].copy()
                    if prev_layer_pick is not None and rng.random() < tc.layer_corr:
                        p[prev_layer_pick] += 2.0 / E
                    if picked:
                        p[np.array(picked)] = 0.0
                    p /= p.sum()
                    e = rng.choice(E, p=p)
                picked.append(int(e))
            prev_tok[l] = picked
            prev_layer_pick = np.array(picked)
            trace[t, l] = picked
    return trace


def trace_stats(trace: np.ndarray) -> dict:
    """Measured pattern statistics (compare to paper Fig. 2 bands)."""
    T, L, K = trace.shape
    tok_repeat = np.zeros(L)
    layer_repeat = 0.0
    for l in range(L):
        a, b = trace[:-1, l, :], trace[1:, l, :]
        share = (a[:, :, None] == b[:, None, :]).any(axis=(1, 2))
        tok_repeat[l] = share.mean()
        if l > 0:
            c, d = trace[:, l - 1, :], trace[:, l, :]
            layer_repeat += (c[:, :, None] == d[:, None, :]).any(axis=(1, 2)).mean()
    # persistence among repeating tokens (paper: "share at least one expert
    # with the previous two/three tokens" = a common expert across the run)
    def common(*offsets):
        # exists e present in trace[t - o] for every offset o (t from max(o))
        base = max(offsets)
        sets = [trace[base - o: T - o] for o in offsets]   # aligned [T', L, K]
        out = np.zeros(sets[0].shape[:2], bool)
        for k in range(K):
            e = sets[0][:, :, k:k + 1]                      # [T', L, 1]
            ok = np.ones_like(out)
            for s in sets[1:]:
                ok &= (s == e).any(axis=2)
            out |= ok
        return out
    rep = common(0, 1)
    run3 = common(0, 1, 2)
    run4 = common(0, 1, 2, 3)
    n = min(len(rep), len(run3), len(run4))
    rep, run3, run4 = rep[-n:], run3[-n:], run4[-n:]
    p2 = (rep & run3).sum() / max(rep.sum(), 1)
    p3 = (rep & run4).sum() / max(rep.sum(), 1)
    return {
        "consec_token_repeat_mean": float(tok_repeat.mean()),
        "consec_token_repeat_min": float(tok_repeat.min()),
        "consec_token_repeat_max": float(tok_repeat.max()),
        "consec_layer_repeat": float(layer_repeat / (L - 1)),
        "persist_t2_given_repeat": float(p2),
        "persist_t3_given_repeat": float(p3),
    }


def capture_trace(cfg, params, tokens, top_k: Optional[int] = None) -> np.ndarray:
    """Capture real router decisions from a repro model (greedy decode).

    Runs the model teacher-forced over `tokens` [B, S] and records each MoE
    layer's top-k picks for batch row 0. Used by the hit-rate benchmark's
    "live model" mode; synthetic traces are the calibrated default.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import transformer
    from repro.models.moe import route

    slots, G, R = transformer.build_slots(cfg)
    K = top_k or cfg.moe.top_k

    # Forward hooks are not a JAX idiom: recompute router decisions from
    # the residual stream by re-running the backbone and capturing router
    # inputs via transformer internals would require threading state.
    # Instead we run layer-by-layer manually here (small models only).
    x = transformer._embed_inputs(params, {"tokens": tokens}, cfg)
    picks = []
    positions = jnp.arange(tokens.shape[1])[None]
    for g in range(G):
        lp_group = jax.tree.map(lambda a: a[g], params["scan"])
        for j, slot in enumerate(slots):
            lp = lp_group[f"s{j}"]
            if slot.is_moe:
                h = transformer.rmsnorm(lp["ln2"], x, cfg.norm_eps)
                _, top_i, _ = route(lp["moe"]["router"],
                                    h[0].astype(jnp.float32), K)
                picks.append(np.asarray(top_i))
            x, _, _, _ = transformer._apply_layer(lp, x, slot, cfg, positions,
                                                  "train", None, None)
    # [L_moe, S, K] -> [S, L_moe, K]
    return np.stack(picks).transpose(1, 0, 2)
