"""Hardware timing/power model, calibrated to the paper's measurements.

Two roles:
 1. Reproduce the paper's consumer-grade testbed (AMD 7960X + RTX 4090 +
    PCIe 4.0 x16) so the simulator regenerates Tables I/III/IV/V and
    Fig. 5 — constants below are the paper's own measured numbers.
 2. Provide the TPU v5e constants used by the roofline analysis.

Paper Table III semantics: "expert comp/comm time" rows are per MoE layer
*pair* (top-2 experts); per-expert values are half. The 0.11 ms row is the
activation round-trip (attention output to host and back).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (per chip)
# ---------------------------------------------------------------------------
TPU_PEAK_FLOPS_BF16 = 197e12          # FLOP/s
TPU_HBM_BW = 819e9                    # B/s
TPU_ICI_BW_PER_LINK = 50e9            # B/s per link
TPU_HBM_BYTES = 16 * 2 ** 30          # v5e HBM capacity
TPU_PCIE_HOST_BW = 32e9               # B/s host link (offload tier)

# ---------------------------------------------------------------------------
# Paper testbed (per-model measured milliseconds)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PaperModelTimings:
    name: str
    num_layers: int
    num_experts: int
    top_k: int
    expert_mb: float                   # per-expert weight size
    gpu_pair_ms: float                 # top-2 expert FFN on GPU (cached)
    comm_pair_ms: float                # PCIe fetch of the top-2 pair
    cpu_pair_ms: Dict[int, float]      # threads -> top-2 expert FFN on CPU
    act_transfer_ms: float = 0.11      # attention output D2H + result H2D
    other_layer_ms: float = 0.70       # attention/router/norм etc. per layer
    # Table IV power (W) per OMP_NUM_THREADS
    cpu_power_w: Dict[int, float] = None
    gpu_power_w: Dict[int, float] = None


MIXTRAL_TIMINGS = PaperModelTimings(
    name="mixtral-8x7b", num_layers=32, num_experts=8, top_k=2,
    expert_mb=340.0,
    gpu_pair_ms=0.25, comm_pair_ms=28.02,
    cpu_pair_ms={1: 44.12, 2: 25.53, 4: 18.34, 8: 15.76, 16: 10.96, 24: 7.34},
    cpu_power_w={1: 86.1, 2: 91.7, 4: 100.3, 8: 111.0, 16: 133.4, 24: 147.5},
    gpu_power_w={1: 91.6, 2: 92.8, 4: 101.0, 8: 103.4, 16: 99.6, 24: 97.9},
)

PHI35_TIMINGS = PaperModelTimings(
    name="phi35-moe", num_layers=32, num_experts=16, top_k=2,
    expert_mb=152.0,
    gpu_pair_ms=0.11, comm_pair_ms=12.26,
    cpu_pair_ms={1: 22.73, 2: 12.80, 4: 8.58, 8: 6.39, 16: 3.92, 24: 3.36},
    cpu_power_w={1: 84.4, 2: 88.4, 4: 92.0, 8: 98.4, 16: 110.1, 24: 118.3},
    gpu_power_w={1: 97.4, 2: 100.7, 4: 105.9, 8: 109.2, 16: 106.0, 24: 109.2},
    other_layer_ms=0.70,
)

PAPER_TIMINGS = {"mixtral-8x7b": MIXTRAL_TIMINGS, "phi35-moe": PHI35_TIMINGS}

# Pre-gated MoE power draw for the energy comparison (paper Table IV).
PREGATED_POWER_W = {
    "mixtral-8x7b": {"cpu": 92.1, "gpu": 96.3},
    "phi35-moe": {"cpu": 88.2, "gpu": 100.7},
}

PCIE_BW_GBPS = 64.0                    # PCIe 4.0 x16, bidirectional

# Cross-layer speculative prefetch (the `ours_prefetch` strategy and the
# live engine's EngineConfig.prefetch): running layer l+1's router on
# layer l's hidden state predicts the next layer's top-k with high
# accuracy — DAOP reports ~90% and Pre-gated MoE trains for the same
# one-layer lookahead. The simulator's default predictor accuracy; the
# live engine measures its own (`predicted_correct / predicted`).
PREFETCH_PREDICTOR_ACCURACY = 0.9


def cpu_pair_ms(t: PaperModelTimings, threads: int) -> float:
    """Interpolate the measured thread scaling (1/T-ish between samples)."""
    pts = sorted(t.cpu_pair_ms)
    if threads in t.cpu_pair_ms:
        return t.cpu_pair_ms[threads]
    if threads <= pts[0]:
        return t.cpu_pair_ms[pts[0]] * pts[0] / threads
    if threads >= pts[-1]:
        return t.cpu_pair_ms[pts[-1]] * pts[-1] / threads
    import bisect
    i = bisect.bisect_left(pts, threads)
    lo, hi = pts[i - 1], pts[i]
    # interpolate in 1/threads space (parallel-efficiency preserving)
    w = (1 / threads - 1 / lo) / (1 / hi - 1 / lo)
    return t.cpu_pair_ms[lo] * (1 - w) + t.cpu_pair_ms[hi] * w


def gpu_expert_ms(t: PaperModelTimings) -> float:
    return t.gpu_pair_ms / t.top_k


def fetch_expert_ms(t: PaperModelTimings) -> float:
    return t.comm_pair_ms / t.top_k


def cpu_expert_ms(t: PaperModelTimings, threads: int) -> float:
    return cpu_pair_ms(t, threads) / t.top_k
