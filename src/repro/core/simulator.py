"""Discrete-event simulator of single-request MoE token generation.

Replays a router trace through the paper's five execution strategies and
the calibrated cost model, producing tokens/s, hit rates, and J/token —
the quantities behind paper Fig. 5, Fig. 6, and Tables IV/V.

Modeled mechanics for `ours` (the paper's framework):
  * set-associative cache bookkeeping via the NumpyCache twin;
  * a single async fetch engine (the second copy engine): a miss enqueues
    a post-fetch; the expert only serves future hits once its transfer
    completes — a tag-hit on an in-flight expert is serviced as a miss
    (compute proceeds on CPU; no duplicate fetch is enqueued);
  * per-layer latency = other + max(GPU hit-expert time,
    activation round-trip + CPU missed-expert time); GPU and CPU overlap;
  * layers beyond cache coverage run entirely on CPU.

Baselines:
  cpu_only   — every expert on CPU (paper's lower bound, 100% miss).
  on_demand  — DeepSpeed/Accelerate-style fetch-then-compute on GPU.
  pregated   — Pre-gated MoE idealized as *perfect* overlap (paper §IV-A
               grants it max(compute, transfer)).
  fiddler    — static popularity placement profiled on a *different* trace
               + per-model orchestration overhead calibrated to Fig. 5
               (documented: Fiddler internals are not first-principles
               modeled; its O(E·2^E) placement cost motivates the Phi gap).

`ours_prefetch` extends `ours` with the serving engine's cross-layer
speculative prefetch: while layer l executes, the next layer's picks are
predicted (per-expert accuracy `prefetch_accuracy`, imperfect predictions
substitute a random expert) and reserved via NumpyCache.reserve — the
same policy-correct speculative insert as the live cache, no demand
accounting. Issued reservations ride the SAME single fetch engine as
demand post-fetches, so wasted speculative transfers genuinely delay
demand fetches; a reservation serves real hits only once its transfer
lands (`ready_at`), from the next layer's probe at the earliest.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.config import CacheConfig
from .costmodel import (PAPER_TIMINGS, PREFETCH_PREDICTOR_ACCURACY,
                        PREGATED_POWER_W, PaperModelTimings, cpu_expert_ms,
                        fetch_expert_ms, gpu_expert_ms)
from .policies import NumpyCache

FIDDLER_OVERHEAD_MS = {"mixtral-8x7b": 3.7, "phi35-moe": 9.8}


@dataclass
class SimResult:
    tokens_per_s: float
    ms_per_token: float
    hit_rate: float
    both_hit_rate: float
    cpu_power_w: float = 0.0
    gpu_power_w: float = 0.0
    joules_per_token: float = 0.0
    extra: Dict[str, float] = field(default_factory=dict)


def _nearest_key(d: Dict[int, float], k: int) -> float:
    return d[min(d, key=lambda x: abs(x - k))]


def simulate(trace: np.ndarray, timings: PaperModelTimings, threads: int,
             method: str = "ours", ccfg: Optional[CacheConfig] = None,
             seed: int = 0,
             prefetch_accuracy: float = PREFETCH_PREDICTOR_ACCURACY
             ) -> SimResult:
    """trace: [T, L, K] expert ids. Returns aggregate timing/energy."""
    T, L, K = trace.shape
    t_gpu = gpu_expert_ms(timings)
    t_cpu = cpu_expert_ms(timings, threads)
    t_fetch = fetch_expert_ms(timings)
    t_act = timings.act_transfer_ms
    t_other = timings.other_layer_ms

    cache = None
    ready_at: Dict[tuple, float] = {}
    fetch_free_at = 0.0
    pf_rng = np.random.default_rng(seed + 17)
    pf_issued = pf_wasted = pf_predicted = pf_correct = 0
    if method in ("ours", "ours_prefetch"):
        assert ccfg is not None
        cache = NumpyCache(ccfg, num_experts=timings.num_experts, seed=seed)
    if method == "fiddler":
        # static global-popularity placement, profiled on a shuffled trace
        rng = np.random.default_rng(seed + 1)
        slots = (ccfg.num_indexes * ccfg.num_ways) if ccfg else \
            L * 2  # same memory budget as ours
        profile = np.zeros((L, timings.num_experts))
        fake = trace[rng.permutation(T)][: max(T // 10, 1)]
        for l in range(L):
            np.add.at(profile[l], fake[:, l, :].reshape(-1), 1.0)
        placed = set()
        order = np.dstack(np.unravel_index(
            np.argsort(-profile, axis=None), profile.shape))[0]
        for l, e in order[:slots]:
            placed.add((int(l), int(e)))

    now = 0.0
    hits = accesses = both = 0
    for t in range(T):
        for l in range(L):
            experts = trace[t, l]
            accesses += K
            if method == "cpu_only":
                now += t_other + t_act + K * t_cpu
            elif method == "on_demand":
                now += t_other + K * t_fetch + K * t_gpu
            elif method == "pregated":
                now += max(K * t_fetch, t_other + K * t_gpu)
            elif method == "fiddler":
                h = [(l, int(e)) in placed for e in experts]
                nh = sum(h)
                hits += nh
                both += nh == K
                gpu_t = nh * t_gpu
                cpu_t = (t_act + (K - nh) * t_cpu) if nh < K else 0.0
                now += t_other + max(gpu_t, cpu_t) + \
                    FIDDLER_OVERHEAD_MS.get(timings.name, 3.7)
            elif method in ("ours", "ours_prefetch"):
                tag_hits = cache.access(l, experts)
                # a tag hit whose post-fetch hasn't landed is still a miss
                real = [h and ready_at.get((l, int(e)), 0.0) <= now
                        for h, e in zip(tag_hits, experts)]
                nh = sum(real)
                hits += nh
                both += nh == K
                gpu_t = nh * t_gpu
                cpu_t = (t_act + (K - nh) * t_cpu) if nh < K else 0.0
                # post-fetch misses on the async engine (covered sets only)
                if l < cache.tags.shape[0]:
                    for h, e in zip(tag_hits, experts):
                        if not h:
                            fetch_free_at = max(fetch_free_at, now) + t_fetch
                            ready_at[(l, int(e))] = fetch_free_at
                layer_ms = t_other + max(gpu_t, cpu_t)
                if method == "ours_prefetch" and l + 1 < L:
                    # predict layer l+1's picks (the live engine runs
                    # router[l+1] on layer l's output residual): each
                    # actual pick survives with p=prefetch_accuracy, else
                    # a random expert is (wastefully) predicted. The
                    # prediction is modeled available at this layer's
                    # dispatch, so the transfer may overlap the layer's
                    # expert compute — the window a predictor placed at
                    # the dispatch point (DAOP) gets; a post-FFN
                    # predictor's window is one attention block, which at
                    # these PCIe timings never fits an expert (the live
                    # path's probe-boundary landing is optimistic there)
                    nxt = trace[t, l + 1]
                    pred = [int(e) if pf_rng.random() < prefetch_accuracy
                            else int(pf_rng.integers(timings.num_experts))
                            for e in nxt]
                    pf_predicted += len(pred)
                    pf_correct += sum(p in nxt for p in pred)
                    # best-effort window gate, enforced PER TRANSFER: a
                    # speculative fetch rides the SAME engine as demand
                    # post-fetches (queued behind this layer's
                    # just-enqueued misses) and is issued only if it
                    # lands inside this layer's compute window — so
                    # prefetch fills pipeline bubbles (the CPU miss path)
                    # and a speculative transfer never occupies the
                    # engine past the next probe, where new demand
                    # fetches enqueue. The full prediction batch stays
                    # protected even when only a prefix fits the budget.
                    for p in pred:
                        if max(fetch_free_at, now) + t_fetch \
                                > now + layer_ms:
                            break
                        iss = cache.reserve(l + 1, [p], protect=pred)
                        if iss[0]:
                            fetch_free_at = max(fetch_free_at, now) \
                                + t_fetch
                            ready_at[(l + 1, p)] = fetch_free_at
                            pf_issued += 1
                            pf_wasted += int(p not in nxt)
                    cache.land()
                now += layer_ms
            else:
                raise ValueError(method)

    ms_tok = now / T
    res = SimResult(
        tokens_per_s=1000.0 / ms_tok, ms_per_token=ms_tok,
        hit_rate=hits / max(accesses, 1),
        both_hit_rate=both / (T * L),
    )
    if method == "ours_prefetch":
        res.extra.update(
            prefetch_issued=pf_issued, prefetch_wasted=pf_wasted,
            prediction_accuracy=pf_correct / max(pf_predicted, 1),
            spec_hits=cache.spec_hits)
    if timings.cpu_power_w:
        if method == "pregated":
            res.cpu_power_w = PREGATED_POWER_W[timings.name]["cpu"]
            res.gpu_power_w = PREGATED_POWER_W[timings.name]["gpu"]
        else:
            res.cpu_power_w = _nearest_key(timings.cpu_power_w, threads)
            res.gpu_power_w = _nearest_key(timings.gpu_power_w, threads)
        res.joules_per_token = (res.cpu_power_w + res.gpu_power_w) * ms_tok / 1000.0
    return res


def best_cache_config(timings: PaperModelTimings, mem_gb: float = 19.0,
                      ways_options=(2, 4, 8)) -> Dict[int, CacheConfig]:
    """Paper §III-B slot math + §IV-C guidance: candidate (N, M) configs
    for a memory budget, keyed by ways."""
    out = {}
    slots = int(mem_gb * 1024 / timings.expert_mb)
    for m in ways_options:
        if m > timings.num_experts:
            continue
        n = min(slots // m, timings.num_layers)
        if n >= 1:
            out[m] = CacheConfig(num_indexes=n, num_ways=m)
    return out
