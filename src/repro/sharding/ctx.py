"""Mesh context for sharding constraints.

Model code calls :func:`constrain` on activations with *logical* axis
tuples. When no mesh is active (unit tests, smoke tests on one device) the
call is a no-op, so model code never branches on distribution.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT_MESH: Optional[Mesh] = None

Axis = Union[str, Tuple[str, ...], None]


def set_current_mesh(mesh: Optional[Mesh]):
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def current_mesh() -> Optional[Mesh]:
    return _CURRENT_MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    global _CURRENT_MESH
    prev = _CURRENT_MESH
    _CURRENT_MESH = mesh
    try:
        with jax.set_mesh(mesh):
            yield mesh
    finally:
        _CURRENT_MESH = prev


def _filter_spec(spec: Tuple[Axis, ...], mesh: Mesh) -> P:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
    names = set(mesh.axis_names)
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            kept = tuple(a for a in ax if a in names)
            out.append(kept if kept else None)
        else:
            out.append(ax if ax in names else None)
    return P(*out)


def batch_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    mesh = mesh or _CURRENT_MESH
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, *spec: Axis):
    """with_sharding_constraint(x, P(*spec)) under the active mesh; no-op otherwise."""
    mesh = _CURRENT_MESH
    if mesh is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _filter_spec(spec, mesh)))


def constrain_sp(x):
    """Sequence-parallel residual stream: [B, S, D] sharded (batch, model).

    The per-layer activation saved by the remat'd layer scan is otherwise
    replicated across the model axis — 16x the checkpoint memory. Megatron
    SP semantics: norms/residual adds run sequence-sharded; GSPMD inserts
    the all-gather before attention/FFN matmuls and the reduce-scatter
    after (same wire bytes as the TP all-reduces they replace). Applied
    only when S divides the model axis (decode steps with S=1 skip it).
    """
    mesh = _CURRENT_MESH
    if mesh is None or mesh.size == 1:
        return x
    n = mesh.shape.get("model", 1)
    if x.ndim < 3 or n <= 1 or x.shape[1] % n != 0:
        return constrain(x, ("pod", "data"), None, None)
    return constrain(x, ("pod", "data"), "model", None)


def named(mesh: Mesh, *spec: Axis) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(spec, mesh))
