"""Parameter partitioning rules: param pytree -> PartitionSpec pytree.

Rules are keyed on the leaf's path name + rank, MaxText-style logical
rules compressed into one dispatch table:

  wq/w1/w3/in_proj      [.., D, F]   -> shard F on "model"   (column)
  wo/w2/out_proj        [.., F, D]   -> shard F on "model"   (row)
  wk/wv (+bk/bv)        [.., D, Hk*hd] -> "model" iff divisible else replicate
  moe w1/w3/w2          [.., E, *, *] -> shard E on "model"  (EP)
  embed/lm_head         [V, D]       -> shard V on "model"
  conv_w                [ci, K]      -> shard ci on "model" iff divisible
  norms/scalars                      -> replicated

Every dim sharded only if divisible by the axis size. Leading scan-stack
dims are skipped (rules address dims from the right). ``opt_state_spec``
additionally shards the largest replicated dim over "data" (ZeRO-1), so
fp32 optimizer moments of 72B-param models fit per-device HBM.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

COL = {"wq", "w1", "w3", "in_proj", "frontend_proj", "bq"}
ROW = {"wo", "w2", "out_proj"}
KV = {"wk", "wv", "bk", "bv"}
VOCAB = {"embed", "lm_head"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(str(k.idx))
    return tuple(names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1

def _divides(dim: int, mesh: Mesh, axis: str) -> bool:
    n = _axis_size(mesh, axis)
    return n > 1 and dim % n == 0


def spec_for(path, shape: Tuple[int, ...], mesh: Mesh) -> P:
    names = _path_names(path)
    leaf = names[-1]
    in_moe = "moe" in names
    nd = len(shape)
    spec: list = [None] * nd

    def set_if(dim_from_right: int, axis: str):
        i = nd - dim_from_right
        if 0 <= i < nd and _divides(shape[i], mesh, axis):
            spec[i] = axis

    if in_moe and leaf in ("w1", "w2", "w3"):
        set_if(3, "model")                      # expert dim (EP)
    elif leaf in COL:
        set_if(1, "model")
    elif leaf in ROW:
        set_if(2, "model")
    elif leaf in KV:
        set_if(1, "model")
    elif leaf in VOCAB:
        set_if(2, "model")
    elif leaf == "conv_w":
        set_if(2, "model")
    elif leaf == "router":
        pass                                    # replicated (small, fp32)
    if all(s is None for s in spec):            # canonical replicated form
        return P()
    return P(*spec)


def param_specs_for(params_shape: Any, mesh: Mesh) -> Any:
    """params pytree (arrays or ShapeDtypeStructs) -> PartitionSpec pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path, leaf.shape, mesh), params_shape)


def opt_state_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: add "data" sharding on the largest free divisible dim."""
    n = _axis_size(mesh, "data")
    if n <= 1:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, -1
    for i, (s, d) in enumerate(zip(parts, shape)):
        if s is None and d % n == 0 and d > best_dim:
            best, best_dim = i, d
    if best >= 0:
        parts[best] = "data"
    return P(*parts)


def abstractify(tree: Any, mesh: Mesh, specs: Any) -> Any:
    """Array/ShapeDtypeStruct pytree -> sharded ShapeDtypeStructs."""
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)
