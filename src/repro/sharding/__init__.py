from .ctx import constrain, current_mesh, set_current_mesh, batch_axes
from .partition import param_specs_for, opt_state_spec, abstractify

__all__ = ["constrain", "current_mesh", "set_current_mesh", "batch_axes",
           "param_specs_for", "opt_state_spec", "abstractify"]
