"""Cost-model-driven CPU/GPU split decision for cache-miss experts.

The paper's central trade-off (Table III): on a cache miss the engine can
either *fetch* the expert's weights over the host link and compute on the
accelerator, or ship the *activations* to the CPU and compute the expert
FFN there with multithreading. Which side wins is a pure cost-model
question — :class:`HostDispatchPolicy` answers it per miss *group* (one
unique expert, ``tokens`` assigned rows this step) from the calibrated
:class:`~repro.core.costmodel.PaperModelTimings`:

  CPU  lane: act_transfer_ms + tokens * cpu_expert_ms(threads)
  GPU  lane: fetch_expert_ms  + tokens * gpu_expert_ms

The activation round-trip (0.11 ms measured) rides the CPU lane; the
weight transfer (14 ms/expert on Mixtral) rides the GPU lane — which is
why host execution wins at decode batch sizes even at modest thread
counts. Both costs are linear in the group's token count, so the whole
decision collapses to a small boolean table indexed by tokens-per-group
that the jitted dispatcher can gather from (`decision_table`).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from repro.core.costmodel import MIXTRAL_TIMINGS, PAPER_TIMINGS, \
    PaperModelTimings, cpu_expert_ms, fetch_expert_ms, gpu_expert_ms

__all__ = ["HostDispatchPolicy", "timings_for"]


def timings_for(name: str) -> PaperModelTimings:
    """Resolve a model config name to its calibrated paper timings.

    Reduced configs keep the arch name (``reduced()`` only shrinks the
    geometry), so the live engine maps straight onto the paper's measured
    testbed numbers. Unknown archs fall back to the Mixtral timings (the
    paper's primary target) with a ``UserWarning`` — the fallback
    mis-costs host-dispatch decisions for non-paper models, and that must
    never happen silently."""
    for key, tm in PAPER_TIMINGS.items():
        if name == key or name.startswith(tm.name):
            return tm
    warnings.warn(
        f"no calibrated paper timings for arch {name!r}: falling back to "
        f"the Mixtral 8x7B timings ({MIXTRAL_TIMINGS.name}) — host-dispatch "
        f"cost decisions for this model are uncalibrated",
        UserWarning, stacklevel=2)
    return MIXTRAL_TIMINGS


@dataclass(frozen=True)
class HostDispatchPolicy:
    """Per-miss CPU-vs-fetch decision from the calibrated cost model."""
    timings: PaperModelTimings
    threads: int

    def cpu_ms(self, tokens: int) -> float:
        """Host lane: activation round-trip + multithreaded expert FFN."""
        return self.timings.act_transfer_ms \
            + tokens * cpu_expert_ms(self.timings, self.threads)

    def fetch_ms(self, tokens: int) -> float:
        """Device lane: weight fetch over the host link + GPU expert FFN."""
        return fetch_expert_ms(self.timings) \
            + tokens * gpu_expert_ms(self.timings)

    def prefers_cpu(self, tokens: int) -> bool:
        """True when host execution beats fetch+compute for a miss group
        of ``tokens`` assignments (empty groups never dispatch)."""
        if tokens < 1:
            return False
        return self.cpu_ms(tokens) < self.fetch_ms(tokens)

    def decision_table(self, max_tokens: int) -> np.ndarray:
        """[max_tokens + 1] bool — ``table[c]`` = run a c-token miss group
        on the CPU. Gathered inside the jitted dispatcher (the costs are
        step-invariant constants, so the split compiles to one lookup)."""
        return np.asarray([self.prefers_cpu(c)
                           for c in range(max_tokens + 1)], bool)
