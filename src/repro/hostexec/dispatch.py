"""The hybrid dispatcher stage: partition a step's expert groups into
GPU-hit and CPU-miss sets and merge their outputs.

Drop-in twin of :func:`repro.core.collaborative.execute` (stage 2 of the
probe → execute → commit pipeline) that the serving engine slots in when
``EngineConfig.host_compute`` is on:

  * **GPU-hit set** — groups resident in the fast-tier slot buffer run
    through the grouped Pallas gmm kernels exactly as before.
  * **CPU-miss set** — non-resident groups whose cost-model decision
    (:class:`repro.hostexec.policy.HostDispatchPolicy`) favors host
    execution ship their rows of the ``[G, A, D]`` activation dispatch
    buffer to the host executor and get the ``[tokens, D]`` outputs
    scatter-added back into the residual by the shared combine —
    *activations move, weights never do*.
  * **fetch set** — the remaining misses (cost model favors the weight
    transfer) keep the old path: gather from the host tier, compute on
    device.

Cache semantics are IDENTICAL in all three sets: the probe's bookkeeping
and the commit's post-fetch are untouched, so misses the policy admits
still warm the cache (the async weight copy is off the critical path —
the cost model charges the *critical-path* choice, the warming copy rides
the commit's overlap slot exactly as before). Host execution therefore
changes where FLOPs run and the stats channel — never residency, never
tokens.

Two backends:
  * ``"callback"`` — the real multithreaded numpy executor, bridged via
    ``jax.pure_callback``. float32 host math: numerically close, not
    bitwise-identical to the device lane.
  * ``"jax"`` — pure-JAX fallback: the CPU-miss groups run the same
    grouped kernel against the host-tier weight gather, entirely
    in-graph. On single-device CI both lanes are literally the same
    computation, so tokens stay BIT-identical to the all-GPU path while
    the dispatcher's partition/counters exercise for real. This is the
    default and the parity contract the tests pin.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CacheConfig
from repro.core import collaborative as collab
from repro.kernels.moe_gmm.ops import moe_ffn

from .executor import HostExpertExecutor

__all__ = ["dispatch_execute", "dispatch_plan"]


def dispatch_plan(pr: collab.ProbeResult, cpu_table: jax.Array,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Partition the probe's groups: (to_cpu [G] bool, counts [G] int32).

    counts — valid assignments per group; to_cpu — non-resident groups the
    cost model sends to the host (``cpu_table[c]`` = run a c-token miss
    group on the CPU; index 0 is False so padded/empty groups never
    dispatch). Resident groups always stay on the device — a hit costs
    ``gpu_expert_ms`` with no transfer on either lane, so the CPU can
    never win one."""
    G = pr.rep_e.shape[0]
    counts = jnp.zeros((G,), jnp.int32).at[pr.gid].add(
        pr.valid.astype(jnp.int32))
    miss = (~pr.resident) & (pr.rep_e >= 0)
    to_cpu = miss & cpu_table[jnp.minimum(counts, cpu_table.shape[0] - 1)]
    return to_cpu, counts


def dispatch_execute(tiers: collab.ExpertTiers, layer: jax.Array,
                     x: jax.Array, top_w: jax.Array,
                     pr: collab.ProbeResult, ccfg: CacheConfig,
                     cpu_table: jax.Array,
                     executor: Optional[HostExpertExecutor] = None,
                     fuse_small: int = 0,
                     ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array,
                                                 jax.Array],
                                Dict[str, jax.Array]]:
    """Stage 2' — hybrid grouped execution with host-computed misses.

    Same signature contract as :func:`repro.core.collaborative.execute`
    plus the split table and (for the callback backend) the executor;
    ``fuse_small`` is the executor's small-group fusion threshold (the
    stat mirrors it for both backends); returns (y [T, D], host-tier
    gathers for commit()'s post-fetch, dispatch stats
    {cpu_expert_calls, cpu_tokens, miss_expert_groups, fused_groups})."""
    T, K = top_w.shape
    tok, xbuf = collab._stage_dispatch(x, K, pr)
    w, host_w = collab._gather_group_weights(tiers, layer, pr, ccfg)
    to_cpu, counts = dispatch_plan(pr, cpu_table)

    # device lane: grouped gmm over the tiered gather (hit groups read the
    # slot buffer, fetch-set misses the host tier — unchanged)
    ybuf_dev = moe_ffn(xbuf, *w)                           # [G, A, D]

    if executor is not None:
        # host lane: the activation buffer crosses to the CPU executor
        # (thread-pool numpy FFN over the host expert table) and the
        # outputs cross back — the paper's activation round-trip
        ybuf_host = jax.pure_callback(
            executor.compute_groups,
            jax.ShapeDtypeStruct(xbuf.shape, xbuf.dtype),
            layer, pr.rep_e, to_cpu, xbuf, counts)
        ybuf = jnp.where(to_cpu[:, None, None], ybuf_host, ybuf_dev)
    else:
        # pure-JAX fallback: the CPU-miss groups' rows of ybuf_dev were
        # already computed from the host-tier gather (non-resident groups
        # never read the slot buffer), which is exactly what the host
        # lane would produce — so the device buffer IS the merged result,
        # bit for bit, and only the partition/counters differ from the
        # all-GPU path. No second FFN.
        ybuf = ybuf_dev

    y = collab._combine(ybuf, pr.gid, pr.pos, tok, top_w, pr.valid, T,
                        x.dtype)
    executed_miss = (~pr.resident) & (pr.rep_e >= 0) & (counts > 0)
    dstats = {
        "cpu_expert_calls": to_cpu.sum().astype(jnp.int32),
        "cpu_tokens": jnp.where(to_cpu, counts, 0).sum().astype(jnp.int32),
        # every executed non-resident group reads the host tier whatever
        # lane it takes — the denominator of the miss-handling cost model
        # (fetched_experts undercounts it: an expert evicted within the
        # step still paid its read)
        "miss_expert_groups": executed_miss.sum().astype(jnp.int32),
        # groups the executor's fusion lane batches (to_cpu already
        # excludes empty groups via cpu_table[0]=False); mirrored for
        # the jax backend so the stat channel is backend-invariant
        "fused_groups": (
            (to_cpu & (counts <= fuse_small)).sum().astype(jnp.int32)
            if fuse_small > 0 else jnp.int32(0)),
    }
    return y, host_w, dstats
