"""Live host-execution subsystem: compute cache-miss experts on the CPU.

The live-path twin of the calibrated simulator's CPU lane
(``core/costmodel.cpu_expert_ms`` / ``core/simulator``): on a cache miss
the engine can ship the *activations* to a multithreaded host executor
instead of paying the expert weight transfer. Three pieces:

  * :mod:`executor`  — thread-pool SwiGLU FFN over the numpy expert
    table, bridged into the jitted step via ``jax.pure_callback``.
  * :mod:`policy`    — the cost-model split (CPU compute vs
    fetch+cache-insert) from :class:`repro.core.costmodel
    .PaperModelTimings`, compiled to a per-group-size decision table.
  * :mod:`dispatch`  — the dispatcher stage slotted into the
    probe/execute/commit pipeline: partitions each step's unique-expert
    groups into GPU-hit / CPU-miss / fetch sets and merges the outputs.

Enabled via ``EngineConfig(host_compute=True, host_threads=...,
host_backend="jax"|"callback")``; counted in the
``EngineStats.cpu_expert_calls`` / ``cpu_tokens`` channel.
"""
from .dispatch import dispatch_execute, dispatch_plan
from .executor import HostExpertExecutor, host_expert_ffn
from .policy import HostDispatchPolicy, timings_for

__all__ = ["dispatch_execute", "dispatch_plan", "HostExpertExecutor",
           "host_expert_ffn", "HostDispatchPolicy", "timings_for"]
