"""Multithreaded host-side expert FFN executor.

The live twin of the cost model's CPU lane: the full expert table lives as
numpy arrays in host memory and cache-miss experts' SwiGLU FFNs run here,
on a thread pool, while the accelerator keeps the hit experts. Only the
``[G, A, D]`` activation dispatch buffer crosses the boundary (the paper's
0.11 ms round-trip) — weights never move.

The executor is bridged into the jitted decode step with
``jax.pure_callback`` (see repro.hostexec.dispatch): the callback receives
the step's unique-expert groups plus a run mask and returns the host
outputs, which the dispatcher scatter-selects against the device outputs.
Group tasks are independent row-blocks of the output buffer, so the pool
workers write disjoint slices; numpy matmul releases the GIL, giving real
CPU parallelism — the live analogue of the paper's OMP_NUM_THREADS knob
that :func:`repro.core.costmodel.cpu_expert_ms` calibrates.

Math runs in float32 (numpy has no native bfloat16 arithmetic) and casts
back to the activation dtype, so the callback lane is numerically close
to — but not bitwise-identical with — the device lane. Callers that need
bit-exactness use the in-graph fallback (``host_backend="jax"``).
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

__all__ = ["HostExpertExecutor", "host_expert_ffn"]


def host_expert_ffn(x: np.ndarray, w1: np.ndarray, w3: np.ndarray,
                    w2: np.ndarray) -> np.ndarray:
    """SwiGLU expert FFN for one group: x [C, D] -> [C, D], float32."""
    h1 = x @ w1
    h = (h1 / (1.0 + np.exp(-h1))) * (x @ w3)     # silu(x@w1) * (x@w3)
    return h @ w2


class HostExpertExecutor:
    """Thread-pool expert FFN over the numpy host expert table.

    w1/w3: [L, E, D, F]; w2: [L, E, F, D] — converted to float32 once at
    construction (the compute dtype of the CPU lane). ``threads`` sizes
    the pool; 1 runs inline (no pool, no handoff overhead).

    ``fuse_small`` batches the step's small miss groups (valid token
    count <= fuse_small) into ONE stacked ``np.matmul`` per FFN stage
    instead of one pool task each: a 1-2 token group's matmul is too
    thin to amortize the thread handoff, but a ``[Gs, A, D] @ [Gs, D,
    F]`` batched GEMM over the stacked small groups runs them in a
    single BLAS call. 0 disables fusion.

    Worker fan-out is census-driven per step rather than fixed: the
    effective thread count follows the step's miss-group census —
    one worker per group up to 8, then sublinearly (HybriMoE's Table
    III scaling: past ~8 threads the expert FFN is memory-bandwidth
    bound, so extra workers mostly contend), capped by ``threads``.
    Groups are bucketed one bucket per effective worker, and a repeat
    expert is pinned to the bucket that ran it last (its weight rows
    are warm in that worker's core-local cache). All of it is schedule
    only — every group still computes the same rows into disjoint
    output slices, so numerics never move.
    """

    def __init__(self, w1, w3, w2, threads: int = 8, fuse_small: int = 0):
        self.w1 = np.asarray(w1, np.float32)
        self.w3 = np.asarray(w3, np.float32)
        self.w2 = np.asarray(w2, np.float32)
        self.threads = max(1, int(threads))
        self.fuse_small = max(0, int(fuse_small))
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=self.threads,
                               thread_name_prefix="hostexec")
            if self.threads > 1 else None)
        # sticky expert -> bucket assignments for worker affinity
        self._affinity: dict = {}
        # host-side telemetry: a floor, not a ledger — pure_callback may
        # legally re-invoke, so the exact count lives in the traced
        # EngineStats channel; these confirm the pool really ran
        self.calls = 0
        self.groups = 0
        self.fused = 0
        # census-threading telemetry: steps that picked a worker count,
        # the summed effective workers (mean = census_threads /
        # census_calls), and groups that landed on their pinned bucket
        self.census_calls = 0
        self.census_threads = 0
        self.affinity_hits = 0
        # pool-utilization telemetry (same floor caveat, and busy_ns is a
        # racy += across workers — a floor by construction): summed
        # per-worker nanoseconds spent inside expert FFN compute, and the
        # high-water mark of bucket tasks one dispatch submitted
        self.busy_ns = 0
        self.queue_peak = 0

    def _effective_threads(self, census: int) -> int:
        """Workers for this step's miss-group census: linear to 8, then
        sublinear (sqrt growth past the bandwidth knee), capped by the
        pool size."""
        if census <= 0:
            return 1
        eff = census if census <= 8 else 8 + int(np.sqrt(census - 8))
        return max(1, min(self.threads, eff))

    def compute_groups(self, layer, rep_e, run, xbuf,
                       counts=None) -> np.ndarray:
        """One step's host lane: compute the masked groups' FFNs.

        layer — scalar int; rep_e [G] unique expert per group (-1 pad);
        run [G] bool — groups dispatched to the CPU; xbuf [G, A, D]
        activation dispatch buffer; counts [G] int32 valid tokens per
        group (optional — enables the small-group fusion lane). Returns
        [G, A, D] in xbuf's dtype, zeros for groups the mask skips (the
        dispatcher never reads those rows)."""
        layer = int(layer)
        rep_e = np.asarray(rep_e)
        todo = np.nonzero(np.asarray(run))[0]
        out = np.zeros(xbuf.shape, np.float32)
        if todo.size:
            x32 = np.asarray(xbuf, np.float32)
            if counts is not None and self.fuse_small > 0:
                cnt = np.asarray(counts)
                small = todo[cnt[todo] <= self.fuse_small]
                big = todo[cnt[todo] > self.fuse_small]
            else:
                small = np.zeros((0,), np.int64)
                big = todo
            if small.size:
                t0 = time.perf_counter_ns()
                es = rep_e[small].astype(np.int64)
                xs = x32[small]                              # [Gs, A, D]
                h1 = np.matmul(xs, self.w1[layer, es])       # [Gs, A, F]
                h = (h1 / (1.0 + np.exp(-h1))) * np.matmul(
                    xs, self.w3[layer, es])
                out[small] = np.matmul(h, self.w2[layer, es])
                self.fused += int(small.size)
                self.busy_ns += time.perf_counter_ns() - t0  # reprolint: shared[atomic] telemetry floor — a torn add undercounts one lane's ns, never corrupts dispatch

            def one(g: int) -> None:
                e = int(rep_e[g])
                out[g] = host_expert_ffn(x32[g], self.w1[layer, e],
                                         self.w3[layer, e],
                                         self.w2[layer, e])

            if self._pool is not None and big.size > 1:
                # census-driven fan-out: one bucket per effective worker,
                # repeat experts pinned to the bucket that ran them last
                eff = self._effective_threads(int(big.size))
                self.census_calls += 1
                self.census_threads += eff
                buckets: list = [[] for _ in range(eff)]
                for g in big:
                    e = int(rep_e[g])
                    b = self._affinity.get(e, -1)
                    if 0 <= b < eff:
                        self.affinity_hits += 1
                    else:
                        b = min(range(eff), key=lambda i: len(buckets[i]))
                        self._affinity[e] = b
                    buckets[b].append(int(g))

                def run_bucket(groups) -> None:
                    t0 = time.perf_counter_ns()
                    for g in groups:
                        one(g)
                    self.busy_ns += time.perf_counter_ns() - t0  # reprolint: shared[atomic] telemetry floor — workers race this add; GIL keeps it a lost-update, not corruption

                if eff > 1:
                    live = [bk for bk in buckets if bk]
                    if len(live) > self.queue_peak:
                        self.queue_peak = len(live)
                    list(self._pool.map(run_bucket, live))
                else:
                    run_bucket(buckets[0])
            else:
                t0 = time.perf_counter_ns()
                for g in big:
                    one(g)
                self.busy_ns += time.perf_counter_ns() - t0  # reprolint: shared[atomic] telemetry floor — submitting-thread write racing the worker lane's adds
        self.calls += 1
        self.groups += int(todo.size)
        return out.astype(xbuf.dtype)
