"""Top-k MoE FFN with sort-based capacity dispatch (EP-shardable).

The dispatch is the standard TPU formulation (cf. MaxText / Switch):
tokens' (expert, slot) coordinates are derived from a stable argsort of the
flat expert assignments; tokens beyond per-expert capacity are dropped
(train) — capacity is generous for decode. The [E, C, D] dispatch buffer is
sharded over the ``model`` mesh axis = expert parallelism; GSPMD inserts
the all-to-alls at the resharding boundaries.

Gradients flow through gather/scatter values and the combine weights, so
the router trains; indices are integer (non-differentiable) as usual. The
auxiliary load-balance loss is the Switch-style E * sum(f_e * P_e).

``moe_apply`` is the faithful dense-framework path. The *serving* path with
the paper's two-tier expert cache lives in repro.core.collaborative.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.sharding import constrain
from .layers import _dense_init, ffn_apply, ffn_params

Params = Dict[str, jax.Array]


def moe_params(key, d_model: int, m: MoEConfig) -> Params:
    ks = jax.random.split(key, 5)
    E, F = m.num_experts, m.d_ff
    p = {
        "router": _dense_init(ks[0], (d_model, E), dtype=jnp.float32),
        "w1": _dense_init(ks[1], (E, d_model, F)),
        "w3": _dense_init(ks[2], (E, d_model, F)),
        "w2": _dense_init(ks[3], (E, F, d_model)),
    }
    if m.num_shared_experts:
        p["shared"] = ffn_params(ks[4], d_model, F * m.num_shared_experts)
    return p


def route(router_w: jax.Array, x: jax.Array, top_k: int
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [T, D] -> (probs [T, E] fp32, top-k ids [T, K], top-k weights [T, K])."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)  # renormalize
    return probs, top_i, top_w


def load_balance_loss(probs: jax.Array, top_i: jax.Array, num_experts: int) -> jax.Array:
    """Switch aux loss: E * sum_e f_e * P_e (fp32 scalar)."""
    T = probs.shape[0]
    f = jnp.zeros((num_experts,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    P = probs.mean(axis=0)
    return num_experts * jnp.sum(f * P)


def sort_dispatch(top_i: jax.Array, capacity: int, num_experts: int):
    """Flat top-k expert ids -> dispatch coordinates.

    Returns (flat token index per assignment [A], buffer slot per assignment
    [A], keep mask [A]) with A = T*K, buffer slot in [0, E*C).
    """
    A = top_i.size
    flat_e = top_i.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within each expert's run of the sorted list
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    pos_in_e = jnp.arange(A) - starts[sorted_e]
    keep = pos_in_e < capacity
    slot = sorted_e * capacity + jnp.minimum(pos_in_e, capacity - 1)
    token = order // top_i.shape[-1]
    return token, slot, keep, order


def moe_apply(p: Params, x: jax.Array, m: MoEConfig,
              capacity_factor: Optional[float] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y [B, S, D], aux loss scalar).

    Dispatch is *per example* (vmapped over B) when S > 1: a global
    argsort over B*S*K assignments cannot be sharded, so GSPMD would
    replicate the whole dispatch path on every device (measured: 64 GiB
    replicated gathers on qwen3-moe train cells). Per-example sort keeps
    everything sharded over the batch/data axis; the [B, E, C, D] buffer's
    expert axis carries the EP (model-axis) sharding. For S == 1 (decode)
    the assignment count is tiny and a single flat group is cheaper.
    """
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    cf = m.capacity_factor if capacity_factor is None else capacity_factor
    # Serve-mode slack (capacity_factor given) only matters where drops
    # are probable: few assignments per dispatch group. At scale the law
    # of large numbers makes the train-style factor effectively dropless,
    # and an 8x buffer would be pure wasted expert compute.
    if capacity_factor is not None and (S if S > 1 else B * S) * K > 256:
        cf = m.capacity_factor

    xf = x.reshape(B * S, D)
    probs, top_i, top_w = route(p["router"], xf, K)
    aux = load_balance_loss(probs, top_i, E)

    if S == 1:
        y = _moe_one_group(p, xf, top_i, top_w, m, cf)
    else:
        C = max(int(S * K / E * cf), 1)
        C = (C + 7) // 8 * 8

        buf, token, slot, keep, order = jax.vmap(
            lambda xb, tib, twb: _dispatch(xb, tib, C, E))(
                x, top_i.reshape(B, S, K), top_w.reshape(B, S, K))
        buf = constrain(buf, ("pod", "data"), "model", None, None)  # EP
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w1"])) * \
            jnp.einsum("becd,edf->becf", buf, p["w3"])
        h = constrain(h, ("pod", "data"), "model", None, None)
        out = jnp.einsum("becf,efd->becd", h, p["w2"])
        out = constrain(out, ("pod", "data"), "model", None, None)

        def combine(outb, tokenb, slotb, keepb, orderb, twb):
            contrib = outb.reshape(E * C, D)[slotb] * \
                (twb.reshape(-1)[orderb] * keepb)[:, None].astype(x.dtype)
            return jnp.zeros((S, D), x.dtype).at[tokenb].add(contrib)

        y = jax.vmap(combine)(out, token, slot, keep, order,
                              top_w.reshape(B, S, K))
        y = y.reshape(B * S, D)

    if "shared" in p:
        y = y + ffn_apply(p["shared"], xf)
    return y.reshape(B, S, D), aux


def _dispatch(xb: jax.Array, tib: jax.Array, C: int, E: int):
    """One example's dispatch: xb [S, D], tib [S, K] -> buffer + coords."""
    token, slot, keep, order = sort_dispatch(tib, C, E)
    gathered = xb[token] * keep[:, None].astype(xb.dtype)
    # .add, not .set: dropped assignments are zeroed and clamped onto slot
    # C-1, which must not clobber the kept token living there.
    buf = jnp.zeros((E * C, xb.shape[-1]), xb.dtype).at[slot].add(gathered)
    return buf.reshape(E, C, xb.shape[-1]), token, slot, keep, order


def _moe_one_group(p: Params, xf: jax.Array, top_i: jax.Array,
                   top_w: jax.Array, m: MoEConfig, cf: float) -> jax.Array:
    """Flat single-group dispatch (decode: T = B tokens, tiny sort)."""
    T, D = xf.shape
    E, K = m.num_experts, m.top_k
    C = max(int(T * K / E * cf), 1)
    C = (C + 7) // 8 * 8
    buf, token, slot, keep, order = _dispatch(xf, top_i, C, E)
    buf = constrain(buf, "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    out = constrain(out, "model", None, None)
    flat_w = top_w.reshape(-1)
    contrib = out.reshape(E * C, D)[slot] * \
        (flat_w[order] * keep)[:, None].astype(xf.dtype)
    return jnp.zeros((T, D), xf.dtype).at[token].add(contrib)
