"""Encoder-decoder assembly (seamless-m4t family).

Encoder: bidirectional self-attention over precomputed modality-frontend
frame embeddings (the speech frontend is a stub per DESIGN.md — inputs are
``frames [B, S_enc, frontend_embed_dim]``). Decoder: causal self-attention
+ cross-attention over encoder memory + dense FFN. Both stacks are scanned.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding import constrain
from repro.sharding.ctx import constrain_sp
from . import attention as attn
from .layers import embed_lookup, embed_params, ffn_apply, ffn_params, \
    logits_from_embed, rmsnorm, rmsnorm_params, _dense_init

Params = Dict[str, Any]


def _enc_layer_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_params(cfg.d_model),
        "attn": attn.attn_params(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.head_dim),
        "ln2": rmsnorm_params(cfg.d_model),
        "ffn": ffn_params(k2, cfg.d_model, cfg.d_ff),
    }


def _dec_layer_params(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_layer_params(k1, cfg)
    p["ln_x"] = rmsnorm_params(cfg.d_model)
    p["cross"] = attn.attn_params(k2, cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kd, k0, k1, k2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    enc_stack = [_enc_layer_params(k, cfg) for k in enc_keys]
    dec_stack = [_dec_layer_params(k, cfg) for k in dec_keys]
    return {
        "frontend_proj": _dense_init(k0, (cfg.frontend_embed_dim, cfg.d_model)),
        "embed": embed_params(k1, cfg.vocab_size, cfg.d_model),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_stack),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_stack),
        "enc_norm": rmsnorm_params(cfg.d_model),
        "final_norm": rmsnorm_params(cfg.d_model),
    }


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           remat: bool = True) -> jax.Array:
    """frames: [B, S_enc, F] -> encoder memory [B, S_enc, D]."""
    x = (frames @ params["frontend_proj"]).astype(jnp.bfloat16)
    x = constrain(x, ("pod", "data"), None, None)
    S = x.shape[1]
    positions = jnp.arange(S)[None]

    def body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        x = x + attn.self_attention(lp["attn"], h, positions, cfg, causal=False)
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn_apply(lp["ffn"], h)
        return constrain_sp(x), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(lp: Params, x, positions, cfg, mode, state, pos, memory_kv):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    new_state = None
    if mode == "decode":
        o, new_state = attn.decode_attention(lp["attn"], h, state, pos, cfg)
    else:
        o = attn.self_attention(lp["attn"], h, positions, cfg)
        if mode == "prefill":
            q, k, v = attn._project_qkv(lp["attn"], h, cfg)
            _, k = attn._rope_qk(q, k, positions, cfg)
            new_state = {"k": k, "v": v}
    x = x + o
    h = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
    x = x + attn.cross_attention(lp["cross"], h, memory_kv)
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    x = x + ffn_apply(lp["ffn"], h)
    if mode == "train":
        x = constrain_sp(x)
    else:
        x = constrain(x, ("pod", "data"), None, None)
    return x, new_state


def decode_stack(params: Params, tokens: jax.Array, memory: jax.Array,
                 cfg: ModelConfig, mode: str, state: Optional[Params] = None,
                 remat: bool = True) -> Tuple[jax.Array, Optional[Params]]:
    """Decoder over (possibly cached) self-attn + cross-attn on memory."""
    x = embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = constrain(x, ("pod", "data"), None, None)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None] if mode != "decode" else None
    pos = state["pos"] if mode == "decode" else None

    # Cross-attention K/V from encoder memory, per layer (scanned).
    if mode == "decode" and "memory_kv" in state:
        mem_kv = state["memory_kv"]
    else:
        def mk(lp):
            return attn.encode_memory_kv(lp["cross"], memory,
                                         cfg.num_kv_heads, cfg.head_dim)
        mem_kv = jax.vmap(mk)(params["dec"])

    def body(carry, xs):
        x = carry
        lp, mkv = xs["params"], xs["mem_kv"]
        st = xs.get("state")
        x, new_st = _dec_layer(lp, x, positions, cfg, mode, st, pos, mkv)
        return x, (new_st if new_st is not None else 0)

    if remat and mode == "train":
        body = jax.checkpoint(body)
    xs: Dict[str, Any] = {"params": params["dec"], "mem_kv": mem_kv}
    if mode == "decode":
        xs["state"] = state["kv"]
    x, new_kv = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    new_state = None
    if mode in ("prefill", "decode"):
        new_state = {"kv": new_kv, "memory_kv": mem_kv,
                     "pos": (state["pos"] + 1) if mode == "decode"
                     else jnp.asarray(S, jnp.int32)}
    return x, new_state


def init_state(cfg: ModelConfig, batch: int, capacity: int,
               mem_len: int) -> Params:
    L = cfg.num_layers
    kv = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(),
        attn.init_kv_cache(batch, capacity, cfg.num_kv_heads, cfg.head_dim))
    mem_kv = {
        "k": jnp.zeros((L, batch, mem_len, cfg.num_kv_heads, cfg.head_dim),
                       jnp.bfloat16),
        "v": jnp.zeros((L, batch, mem_len, cfg.num_kv_heads, cfg.head_dim),
                       jnp.bfloat16),
    }
    return {"kv": kv, "memory_kv": mem_kv, "pos": jnp.zeros((), jnp.int32)}


def lm_logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    return logits_from_embed(params["embed"], x, cfg.logit_softcap)
