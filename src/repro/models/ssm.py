"""Mamba2 SSD (state-space duality) mixing layer.

Implements the chunked dual form of arXiv:2405.21060 §6 in pure JAX:
intra-chunk quadratic (attention-like) term + inter-chunk linear recurrence
scanned over chunk states. ``ssd_decode_step`` is the O(1) recurrent form
used by the serve path (this is why SSM/hybrid archs run the long_500k
cell). The intra-chunk einsum block is the Pallas kernel target
(kernels/ssd_scan).

Recurrence (per head h, state n, channel p):
    h_t = exp(a_t) * h_{t-1} + B_t ⊗ (x_t * dt_t)
    y_t = C_t · h_t + D * x_t,         a_t = -exp(A_log) * dt_t
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.sharding import constrain
from .layers import _dense_init, rmsnorm

Params = Dict[str, jax.Array]


def mamba_params(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.num_heads(D)
    ci = di + 2 * s.d_state                    # conv runs over (x, B, C)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], (D, 2 * di + 2 * s.d_state + nh)),
        "conv_w": (jax.random.normal(ks[1], (ci, s.d_conv), jnp.float32) * 0.1
                   ).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((ci,), jnp.bfloat16),
        "A_log": jnp.zeros((nh,), jnp.float32),           # A = -exp(0) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[2], (di, D)),
    }


def _split_proj(zxbcdt: jax.Array, s: SSMConfig, di: int, nh: int):
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * s.d_state], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time. xbc: [B, S, ci]; w: [ci, K].

    Returns (activated output [B, S, ci], new state [B, K-1, ci]).
    """
    Bb, S, ci = xbc.shape
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((Bb, K - 1, ci), xbc.dtype)
    ext = jnp.concatenate([state, xbc], axis=1)          # [B, S+K-1, ci]
    out = jnp.zeros((Bb, S, ci), jnp.float32)
    for k in range(K):                                    # K is 4: unrolled taps
        out = out + ext[:, k:k + S, :].astype(jnp.float32) * w[:, k].astype(jnp.float32)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)
    return out, ext[:, S:, :]


def ssd_chunked(x: jax.Array, dt: jax.Array, A_log: jax.Array,
                Bmat: jax.Array, Cmat: jax.Array,
                init_state: Optional[jax.Array] = None,
                chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B, S, nh, hp]; dt: [B, S, nh] (post-softplus); Bmat/Cmat: [B, S, ds];
    A_log: [nh]. Returns (y [B, S, nh, hp], final state [B, nh, ds, hp]).
    """
    Bb, S, nh, hp = x.shape
    ds = Bmat.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # ragged tail: pad with dt=0 steps — decay exp(0)=1 and zero input
        # leave the state untouched; padded outputs are sliced off.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        S_orig, S = S, S + pad
    else:
        S_orig = S
    Nc = S // Q

    a = (-jnp.exp(A_log.astype(jnp.float32)) * dt)        # [B, S, nh], negative
    xd = (x.astype(jnp.float32) * dt[..., None])          # discretized input

    # chunk views, chunk axis leading for the scan: [Nc, B, Q, ...]
    ac = jnp.moveaxis(a.reshape(Bb, Nc, Q, nh), 1, 0)
    xc = jnp.moveaxis(xd.reshape(Bb, Nc, Q, nh, hp), 1, 0)
    Bc = jnp.moveaxis(Bmat.astype(jnp.float32).reshape(Bb, Nc, Q, ds), 1, 0)
    Cc = jnp.moveaxis(Cmat.astype(jnp.float32).reshape(Bb, Nc, Q, ds), 1, 0)

    h0 = (jnp.zeros((Bb, nh, ds, hp), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    # One chunk at a time: the quadratic L tensor is [B, Q, Q, nh] for a
    # single chunk (sharded over nh), never [B, Nc, Q, Q, nh] — the full
    # materialization was a ~100x per-device memory blowup at 32k tokens.
    def body(h, inp):
        a_c, x_c, B_c, C_c = inp
        acs = jnp.cumsum(a_c, axis=1)                      # [B, Q, nh]
        scores = jnp.einsum("bqn,bkn->bqk", C_c, B_c)      # [B, Q, Q]
        diff = acs[:, :, None, :] - acs[:, None, :, :]     # [B, Q, Q, nh]
        L = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        L = constrain(L, ("pod", "data"), None, None, "model")
        y = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, L, x_c)
        y = y + jnp.einsum("bqn,bqh,bhnp->bqhp", C_c, jnp.exp(acs), h)
        decay_end = jnp.exp(acs[:, -1:, :] - acs)          # [B, Q, nh]
        s_c = jnp.einsum("bkn,bkh,bkhp->bhnp", B_c, decay_end, x_c)
        h = jnp.exp(acs[:, -1, :])[..., None, None] * h + s_c
        return h, y

    h_final, ys = jax.lax.scan(body, h0, (ac, xc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, nh, hp)[:, :S_orig]
    return y.astype(x.dtype), h_final


def ssd_decode_step(x: jax.Array, dt: jax.Array, A_log: jax.Array,
                    Bmat: jax.Array, Cmat: jax.Array, state: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. x: [B, nh, hp]; dt: [B, nh]; B/C: [B, ds];
    state: [B, nh, ds, hp]."""
    a = jnp.exp(-jnp.exp(A_log.astype(jnp.float32)) * dt)          # [B, nh]
    xd = x.astype(jnp.float32) * dt[..., None]
    state = a[..., None, None] * state.astype(jnp.float32) + \
        jnp.einsum("bn,bhp->bhnp", Bmat.astype(jnp.float32), xd)
    y = jnp.einsum("bn,bhnp->bhp", Cmat.astype(jnp.float32), state)
    return y.astype(x.dtype), state


def init_ssm_state(cfg: ModelConfig, batch: int) -> Params:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    ci = di + 2 * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, ci), jnp.bfloat16),
        "ssd": jnp.zeros((batch, nh, s.d_state, cfg.ssm.head_dim), jnp.float32),
    }


def mamba_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[Params] = None, decode: bool = False
                ) -> Tuple[jax.Array, Optional[Params]]:
    """Full Mamba2 block. x: [B, S, D] -> (y [B, S, D], new state)."""
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.num_heads(D)
    Bb, S, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(zxbcdt, s, di, nh)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = constrain(dt, ("pod", "data"), None, "model")

    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, Bmat, Cmat = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    xh = xs.reshape(Bb, S, nh, s.head_dim)
    xh = constrain(xh, ("pod", "data"), None, "model", None)

    if decode:
        assert S == 1
        y, new_ssd = ssd_decode_step(
            xh[:, 0], dt[:, 0], p["A_log"], Bmat[:, 0], Cmat[:, 0], state["ssd"])
        y = y[:, None]
    else:
        init = None if state is None else state["ssd"]
        y, new_ssd = ssd_chunked(xh, dt, p["A_log"], Bmat, Cmat, init,
                                 chunk=s.chunk_size)

    y = y + (p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(Bb, S, di)
    # gated RMSNorm (mamba2: norm(y * silu(z)))
    y = rmsnorm(p["norm_w"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                cfg.norm_eps)
    out = y @ p["out_proj"]
    new_state = {"conv": new_conv, "ssd": new_ssd} if (state is not None or decode) else None
    return out, new_state
