from .model import decode_step, init_params, init_state, loss_fn, prefill

__all__ = ["decode_step", "init_params", "init_state", "loss_fn", "prefill"]
