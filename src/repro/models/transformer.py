"""Decoder-only LM assembly with period-scanned heterogeneous layers.

Architectures repeat a *period* of P layer slots (P = lcm of the attention/
mamba interleave, the MoE interleave and the sliding-window pattern, e.g.
P=1 for llama-likes, 6 for gemma3, 8 for jamba, 2 for llama4). We scan over
``G = L // P`` groups — HLO size is O(P), independent of depth — and unroll
the ``L % P`` remainder. Slot descriptors (kind / window / moe) are static
Python, so each slot body specializes fully.

Modes:
  train   — logits for the full sequence (+ MoE aux loss), no caches.
  prefill — logits of the last position + populated decode state.
  decode  — one token in, logits + in-place-updated state (donate it).
  segment — a [C]-token prompt segment at offset ``state["pos"]`` against
            the request's existing KV (offset causal mask): appends the
            segment's KV in place (dense slot or paged pool via ``pages``)
            and emits the segment's routing trace, so a prompt forward can
            stream across scheduler ticks (repro.serving.engine).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding import constrain
from repro.sharding.ctx import constrain_sp
from . import attention as attn
from . import ssm
from .layers import embed_lookup, embed_params, ffn_apply, ffn_params, \
    logits_from_embed, rmsnorm, rmsnorm_params, _dense_init
from .moe import moe_apply, moe_params, route

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Slot:
    kind: str        # "attn" | "mamba"
    is_moe: bool
    window: int      # -1 = global


def build_slots(cfg: ModelConfig) -> Tuple[List[Slot], int, int]:
    """Returns (period slots, num scanned groups, num remainder layers)."""
    p = len(cfg.layer_pattern)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe_every)
    if cfg.window_pattern:
        p = math.lcm(p, len(cfg.window_pattern))
    p = min(p, cfg.num_layers)
    slots = [Slot(cfg.layer_kind(i), cfg.is_moe_layer(i), cfg.window_for_layer(i))
             for i in range(p)]
    return slots, cfg.num_layers // p, cfg.num_layers % p


def _slot_has_ffn(cfg: ModelConfig, slot: Slot) -> bool:
    return slot.is_moe or cfg.d_ff > 0


# --------------------------------------------------------------------------
# Parameter construction
# --------------------------------------------------------------------------

def _layer_params(key, cfg: ModelConfig, slot: Slot) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_params(cfg.d_model)}
    if slot.kind == "attn":
        p["attn"] = attn.attn_params(ks[0], cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim, cfg.qkv_bias)
    else:
        p["mamba"] = ssm.mamba_params(ks[0], cfg)
    if _slot_has_ffn(cfg, slot):
        p["ln2"] = rmsnorm_params(cfg.d_model)
        if slot.is_moe:
            p["moe"] = moe_params(ks[1], cfg.d_model, cfg.moe)
        else:
            p["ffn"] = ffn_params(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    slots, G, R = build_slots(cfg)
    keys = jax.random.split(key, 4 + len(slots) * (G + 1))
    params: Params = {"embed": embed_params(keys[0], cfg.vocab_size, cfg.d_model),
                      "final_norm": rmsnorm_params(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_params(keys[1], cfg.vocab_size, cfg.d_model)
    if cfg.frontend_embed_dim:
        params["frontend_proj"] = _dense_init(
            keys[2], (cfg.frontend_embed_dim, cfg.d_model))
    ki = 3
    scan: Params = {}
    for j, slot in enumerate(slots):
        stacked = [ _layer_params(keys[ki + g], cfg, slot) for g in range(G) ]
        ki += G
        scan[f"s{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked) \
            if G > 1 else jax.tree.map(lambda x: x[None], stacked[0])
    params["scan"] = scan
    rem: Params = {}
    for j in range(R):
        rem[f"r{j}"] = _layer_params(keys[ki], cfg, slots[j % len(slots)])
        ki += 1
    if rem:
        params["rem"] = rem
    return params


# --------------------------------------------------------------------------
# Per-layer state (KV cache / SSM state)
# --------------------------------------------------------------------------

def _layer_state(cfg: ModelConfig, slot: Slot, batch: int, capacity: int) -> Params:
    if slot.kind == "attn":
        return attn.init_kv_cache(batch, capacity, cfg.num_kv_heads, cfg.head_dim)
    return ssm.init_ssm_state(cfg, batch)


def init_state(cfg: ModelConfig, batch: int, capacity: int) -> Params:
    """Decode state pytree, mirroring the scan/rem param structure."""
    slots, G, R = build_slots(cfg)
    state: Params = {"scan": {}, "pos": jnp.zeros((), jnp.int32)}
    for j, slot in enumerate(slots):
        st = _layer_state(cfg, slot, batch, capacity)
        state["scan"][f"s{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape).copy(), st)
    if R:
        state["rem"] = {f"r{j}": _layer_state(cfg, slots[j % len(slots)], batch, capacity)
                        for j in range(R)}
    return state


# --------------------------------------------------------------------------
# Layer application
# --------------------------------------------------------------------------

def _apply_layer(lp: Params, x: jax.Array, slot: Slot, cfg: ModelConfig,
                 positions, mode: str, state: Optional[Params], pos,
                 want_trace: bool = False, pages=None,
                 kv_write_min=None, kv_write_max=None
                 ) -> Tuple[jax.Array, Optional[Params], jax.Array,
                            Optional[Params]]:
    """Returns (x, new_state, aux_loss, routing trace).

    ``want_trace`` (prefill/segment-mode MoE slots only) additionally emits
    the per-layer routing trace — ``top_i``/``top_w`` [B, S, K] and the
    post-ln2 hidden states ``h2`` [B, S, D] — that the serving engine's
    cache-warming replay consumes (repro.serving.engine). The trace is
    derived from the SAME router weights and the SAME h2 that moe_apply
    consults, so replaying it reproduces the prompt's expert demand
    exactly; emitting it never changes x / new_state / aux. Trace is None
    everywhere else (the default skips the O(L*S*D) materialization).

    Segment mode appends a [C]-token prompt segment at offset ``pos`` to
    the layer's KV (dense cache, or the paged pool when ``pages`` is a
    [B, max_pages] table; ``kv_write_min``/``kv_write_max`` bound which
    absolute positions may land — shared prefix pages stay immutable)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    new_state = None
    if slot.kind == "attn":
        if mode == "decode":
            o, new_state = attn.decode_attention(lp["attn"], h, state, pos, cfg,
                                                 slot.window)
        elif mode == "segment":
            if pages is not None:
                o, new_state = attn.segment_attention_paged(
                    lp["attn"], h, state, pos, positions, pages, cfg,
                    slot.window, kv_write_min, kv_write_max)
            else:
                o, new_state = attn.segment_attention(
                    lp["attn"], h, state, pos, positions, cfg, slot.window)
        else:
            o = attn.self_attention(lp["attn"], h, positions, cfg, slot.window)
            if mode == "prefill":
                # rebuild k/v for the cache (cheap projections; avoids
                # threading internals out of the flash path)
                q, k, v = attn._project_qkv(lp["attn"], h, cfg)
                _, k = attn._rope_qk(q, k, positions, cfg)
                new_state = {"k": k, "v": v}
    else:
        if mode == "decode":
            o, new_state = ssm.mamba_apply(lp["mamba"], h, cfg, state, decode=True)
        elif mode == "segment":
            raise NotImplementedError(
                "segment-streamed prefill supports attention layers only")
        elif mode == "prefill":
            o, new_state = ssm.mamba_apply(
                lp["mamba"], h, cfg, ssm.init_ssm_state(cfg, x.shape[0]))
        else:
            o, _ = ssm.mamba_apply(lp["mamba"], h, cfg)
    x = x + o
    trace = None
    if _slot_has_ffn(cfg, slot):
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if slot.is_moe:
            # train drops at capacity (standard); serving must not — a
            # prefill-dropped token would diverge from the decode path
            cf = None if mode == "train" else cfg.moe.serve_capacity_factor
            f, aux = moe_apply(lp["moe"], h2, cfg.moe, capacity_factor=cf)
            if want_trace and mode in ("prefill", "segment"):
                B, S, _ = h2.shape
                K = cfg.moe.top_k
                _, top_i, top_w = route(lp["moe"]["router"],
                                        h2.reshape(B * S, -1), K)
                trace = {"top_i": top_i.reshape(B, S, K),
                         "top_w": top_w.reshape(B, S, K), "h2": h2}
        else:
            f = ffn_apply(lp["ffn"], h2)
        x = x + f
    x = constrain_sp(x) if mode == "train" else \
        constrain(x, ("pod", "data"), None, None)
    return x, new_state, aux, trace


# --------------------------------------------------------------------------
# Backbone
# --------------------------------------------------------------------------

def _embed_inputs(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    x = embed_lookup(params["embed"], batch["tokens"]).astype(jnp.bfloat16)
    if cfg.family == "vlm" and "patches" in batch \
            and x.shape[1] > batch["patches"].shape[1]:
        # multimodal stub: precomputed patch embeddings replace the prefix
        # (train/prefill only — decode steps are pure text continuation)
        pe = (batch["patches"] @ params["frontend_proj"]).astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:, :]], axis=1)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, ("pod", "data"), None, None)


def _positions(batch: Dict[str, jax.Array], cfg: ModelConfig, S: int, B: int):
    if cfg.mrope:
        if "positions" in batch:
            return batch["positions"]
        p = jnp.arange(S)[None].repeat(B, 0)
        return jnp.stack([p, p, p])            # text-only: t = h = w
    return jnp.arange(S)[None]


def backbone(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
             mode: str, state: Optional[Params] = None,
             remat: bool = True, want_trace: bool = False,
             pages: Optional[jax.Array] = None,
             kv_write_min=None, kv_write_max=None
             ) -> Tuple[jax.Array, Optional[Params], jax.Array,
                        Optional[Params]]:
    """Runs embedding + all layers. Returns (hidden, new_state, aux, trace).

    ``want_trace`` (prefill/segment modes) collects every MoE layer's
    routing trace into a pytree mirroring the scan/rem param structure:
    ``trace["scan"]["s{j}"]`` holds ``top_i``/``top_w`` [G, B, S, K] and
    ``h2`` [G, B, S, D] for MoE slot j (plus ``trace["rem"]`` for
    remainder MoE layers). This is the ONE prefill implementation — the
    serving engine replays the trace to warm its expert cache; there is no
    hand-mirrored copy of the prefill branch anywhere else. Trace is None
    without the flag (and the trace materialization is skipped).

    Segment mode streams a prompt forward: ``batch["tokens"]`` holds one
    [B, C] segment, ``state["pos"]`` its first absolute position, and the
    per-layer states carry the request's KV so far (dense [B, cap] slots,
    or the paged pool with ``pages``/``kv_write_min``/``kv_write_max``
    forwarded to the paged segment attention). The forward IS the trace
    source — first-token logits emerge once the caller has streamed the
    last segment."""
    slots, G, R = build_slots(cfg)
    want_trace = want_trace and mode in ("prefill", "segment")
    x = _embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    pos = state["pos"] if mode in ("decode", "segment") else None
    if mode == "decode":
        positions = None
    elif mode == "segment":
        p = pos + jnp.arange(S)[None]                      # [1, S] absolute
        positions = jnp.stack([jnp.broadcast_to(p, (B, S))] * 3) \
            if cfg.mrope else p
    else:
        positions = _positions(batch, cfg, S, B)

    # Nested remat for multi-slot periods (jamba: 8 sub-layers/group): the
    # outer checkpoint alone would rematerialize ALL sub-layers' internals
    # simultaneously in the group's backward (~90 GB/device for jamba) —
    # checkpointing each sub-layer bounds live internals to one layer.
    nested = remat and mode == "train" and len(slots) > 1

    def group_body(carry, xs):
        x, aux = carry
        lp_group = xs["params"]
        st_group = xs.get("state")
        new_sts = {}
        traces = {}
        for j, slot in enumerate(slots):
            st = st_group[f"s{j}"] if st_group is not None else None
            layer_fn = functools.partial(_apply_layer, slot=slot, cfg=cfg,
                                         positions=positions, mode=mode,
                                         state=st, pos=pos,
                                         want_trace=want_trace, pages=pages,
                                         kv_write_min=kv_write_min,
                                         kv_write_max=kv_write_max)
            if nested:
                layer_fn = jax.checkpoint(layer_fn)
            x, new_st, a, tr = layer_fn(lp_group[f"s{j}"], x)
            if new_st is not None:
                new_sts[f"s{j}"] = new_st
            if tr is not None:
                traces[f"s{j}"] = tr
            aux = aux + a
        return (x, aux), (new_sts, traces)

    body = jax.checkpoint(group_body) if (remat and mode == "train") else group_body

    xs: Dict[str, Any] = {"params": params["scan"]}
    if mode in ("decode", "segment"):
        xs["state"] = state["scan"]
    (x, aux), (scan_states, scan_traces) = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)

    rem_states = {}
    rem_traces = {}
    for j in range(R):
        slot = slots[j % len(slots)]
        st = state["rem"][f"r{j}"] if mode in ("decode", "segment") else None
        x, new_st, a, tr = _apply_layer(params["rem"][f"r{j}"], x, slot, cfg,
                                        positions, mode, st, pos,
                                        want_trace=want_trace, pages=pages,
                                        kv_write_min=kv_write_min,
                                        kv_write_max=kv_write_max)
        if new_st is not None:
            rem_states[f"r{j}"] = new_st
        if tr is not None:
            rem_traces[f"r{j}"] = tr
        aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    new_state = None
    if mode in ("prefill", "decode", "segment"):
        new_state = {"scan": scan_states}
        if R:
            new_state["rem"] = rem_states
        if mode == "decode":
            new_state["pos"] = state["pos"] + 1
        elif mode == "segment":
            new_state["pos"] = jnp.asarray(state["pos"] + S, jnp.int32)
        else:
            new_state["pos"] = jnp.asarray(S, jnp.int32)
    trace = None
    if want_trace:
        trace = {"scan": scan_traces}
        if rem_traces:
            trace["rem"] = rem_traces
    return x, new_state, aux, trace


def lm_logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = params.get("lm_head", params["embed"])
    return logits_from_embed(table, x, cfg.logit_softcap)
