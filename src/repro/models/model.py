"""Unified model API: build/init/loss/prefill/decode for every arch family.

``step_fns(cfg)`` returns the three jittable entry points the launcher and
dry-run lower:

  train_step(params, opt_state, batch)        (via repro.optim)
  prefill_step(params, batch)      -> (last-token logits, decode state)
  serve_step(params, state, batch) -> (logits, updated state)

Batch layout (all int32 unless noted):
  tokens    [B, S]           LM tokens (decoder tokens for enc-dec)
  labels    [B, S]           train only
  frames    [B, S, F] bf16   audio family (frontend stub)
  patches   [B, 64, F] bf16  vlm family (frontend stub)
  positions [3, B, S]        mrope archs (optional; defaults to text pos)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding import constrain
from . import encdec, transformer

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key) -> Params:
    if cfg.is_encdec:
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def init_state(cfg: ModelConfig, batch: int, capacity: int) -> Params:
    if cfg.is_encdec:
        return encdec.init_state(cfg, batch, capacity, mem_len=capacity)
    return transformer.init_state(cfg, batch, capacity)


# --------------------------------------------------------------------------
# Loss (chunked over sequence to bound the logits materialization)
# --------------------------------------------------------------------------

def _xent_chunked(params: Params, x: jax.Array, labels: jax.Array,
                  cfg: ModelConfig, chunk: int = 512) -> jax.Array:
    """Mean next-token cross entropy; logits computed per seq-chunk."""
    B, S, D = x.shape
    lm = encdec.lm_logits if cfg.is_encdec else transformer.lm_logits
    chunk = min(chunk, S)
    n = S // chunk
    xc = x[:, :n * chunk].reshape(B, n, chunk, D)
    yc = labels[:, :n * chunk].reshape(B, n, chunk)

    def body(tot, inp):
        xb, yb = inp                                   # [B, chunk, D], [B, chunk]
        lg = lm(params, xb, cfg).astype(jnp.float32)
        lg = constrain(lg, ("pod", "data"), None, "model")
        lse = jax.nn.logsumexp(lg, axis=-1)
        # target logit via masked reduction — take_along_axis over the
        # model-sharded vocab axis would all-gather the full logits
        iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
        tgt = jnp.sum(jnp.where(iota == yb[..., None], lg, 0.0), axis=-1)
        return tot + (lse - tgt).sum(), None

    body = jax.checkpoint(body)
    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                          (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(yc, 1, 0)))
    return tot / (B * n * chunk)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.is_encdec:
        memory = encdec.encode(params, batch["frames"], cfg, remat)
        x, _ = encdec.decode_stack(params, batch["tokens"], memory, cfg,
                                   "train", remat=remat)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, _, aux, _ = transformer.backbone(params, batch, cfg, "train",
                                            remat=remat)
    xent = _xent_chunked(params, x, batch["labels"], cfg)
    coef = cfg.moe.aux_loss_coef if cfg.moe is not None else 0.0
    return xent + coef * aux, {"xent": xent, "aux": aux}


# --------------------------------------------------------------------------
# Serving entry points
# --------------------------------------------------------------------------

def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Params]:
    if cfg.is_encdec:
        memory = encdec.encode(params, batch["frames"], cfg, remat=False)
        x, state = encdec.decode_stack(params, batch["tokens"], memory, cfg,
                                       "prefill", remat=False)
        lm = encdec.lm_logits
    else:
        x, state, _, _ = transformer.backbone(params, batch, cfg, "prefill",
                                              remat=False)
        lm = transformer.lm_logits
    logits = lm(params, x[:, -1:, :], cfg)
    return logits, state


def decode_step(params: Params, state: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """One token for every sequence in the batch. tokens: [B, 1]."""
    if cfg.is_encdec:
        x, state = encdec.decode_stack(params, batch["tokens"], None, cfg,
                                       "decode", state=state)
        lm = encdec.lm_logits
    else:
        x, state, _, _ = transformer.backbone(params, batch, cfg, "decode",
                                              state=state)
        lm = transformer.lm_logits
    return lm(params, x, cfg), state
