"""GQA attention: chunked-flash prefill, cached decode, cross-attention.

Design notes
------------
* Prefill/train uses a pure-XLA *chunked flash* formulation: ``lax.scan``
  over KV chunks with online-softmax running statistics. Peak memory is
  O(S * chunk) instead of O(S^2), which is what makes the 32k-prefill cells
  compile within HBM. The Pallas TPU kernel (kernels/decode_attention) is a
  drop-in replacement for the decode einsum path on real hardware.
* Decode (q_len == 1) uses exact einsum attention over the cache capacity
  with a position mask; scores are [B, H, 1, S] which is small. The cache
  is updated in place at ``pos`` via dynamic_update_slice (donated buffer).
* Sliding windows are dynamic scalars so that layers with different window
  sizes can share one scanned HLO body (-1 == global).
* GQA: q heads H, kv heads Hk, group = H // Hk via reshape to
  [B, S, Hk, group, hd] — no materialized repeat of K/V.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from .layers import _dense_init, apply_mrope, apply_rope

Params = Dict[str, jax.Array]

NEG_INF = -1e30


def attn_params(key, d_model: int, num_heads: int, num_kv_heads: int,
                head_dim: int, qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, num_heads * head_dim)),
        "wk": _dense_init(ks[1], (d_model, num_kv_heads * head_dim)),
        "wv": _dense_init(ks[2], (d_model, num_kv_heads * head_dim)),
        "wo": _dense_init(ks[3], (num_heads * head_dim, d_model)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), jnp.bfloat16)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), jnp.bfloat16)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), jnp.bfloat16)
    return p


def _project_qkv(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _rope_qk(q, k, positions, cfg):
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


# --------------------------------------------------------------------------
# Chunked-flash full-sequence attention (train / prefill)
# --------------------------------------------------------------------------

def _mask_for(Sq: int, chunk: int, c_start, window, causal: bool,
              q_offset=0):
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = c_start + jnp.arange(chunk)
    dist = q_pos[:, None] - k_pos[None, :]               # [Sq, chunk]
    mask = jnp.ones((Sq, chunk), bool)
    if causal:
        mask &= dist >= 0
    win = jnp.asarray(window, jnp.int32)
    mask &= jnp.where(win > 0, dist < win, True)
    return mask


def _rep(x, group):
    x = jnp.repeat(x, group, axis=2)
    return constrain(x, ("pod", "data"), None, "model", None)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: int = -1, causal: bool = True,
                    chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks.

    q: [B, Sq, H, hd]; k, v: [B, Sk, Hk, hd]. window: scalar (-1 = global).
    Returns [B, Sq, H, hd] (bf16 as input dtype).

    Sharding: scores live on the *full* H dim (KV heads are broadcast to H
    per chunk), so the model axis shards them even when Hk < axis size —
    the [Hk, group] layout would silently replicate a 16x larger buffer.

    Memory: custom VJP (FlashAttention-2-style). Plain autodiff of the
    chunk scan stacks every chunk's f32 scores as residuals — the full
    [Sq, Sk] attention matrix — which is exactly what flash attention
    exists to avoid. The backward here saves only (q, k, v, out, lse) and
    recomputes per-chunk scores.
    """
    out, _ = _flash_fwd_scan(q, k, v, window, causal, chunk)
    return out


def _flash_fwd_scan(q, k, v, window, causal: bool, chunk: int, q_offset=0):
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    chunk = min(chunk, Sk)
    n_chunks = Sk // chunk
    assert Sk % chunk == 0, (Sk, chunk)

    qf = q.astype(jnp.float32) * hd ** -0.5
    kc = k.reshape(B, n_chunks, chunk, Hk, hd)
    vc = v.reshape(B, n_chunks, chunk, Hk, hd)

    def body(carry, inputs):
        acc, m, l = carry                      # [B,Sq,H,hd], [B,Sq,H], [B,Sq,H]
        kcb, vcb, c_start = inputs             # [B,chunk,Hk,hd] x2, scalar
        krep = _rep(kcb, group)
        vrep = _rep(vcb, group)
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, krep.astype(jnp.float32))
        s = constrain(s, ("pod", "data"), None, "model", None)
        mask = _mask_for(Sq, s.shape[-1], c_start, window, causal, q_offset)
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p, vrep.astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), starts))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)                       # [B, Sq, H]
    return out, lse


def _flash_fwd(q, k, v, window, causal, chunk):
    out, lse = _flash_fwd_scan(q, k, v, window, causal, chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(window, causal, chunk, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    chunk_ = min(chunk, Sk)
    n_chunks = Sk // chunk_

    qf = q.astype(jnp.float32) * hd ** -0.5
    do = dout.astype(jnp.float32)
    # D_i = rowsum(dO * O) — the softmax-backward diagonal term
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)     # [B, Sq, H]
    kc = k.reshape(B, n_chunks, chunk_, Hk, hd)
    vc = v.reshape(B, n_chunks, chunk_, Hk, hd)
    starts = jnp.arange(n_chunks) * chunk_

    def body(dq, inputs):
        kcb, vcb, c_start = inputs
        krep = _rep(kcb, group).astype(jnp.float32)
        vrep = _rep(vcb, group).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, krep)
        mask = _mask_for(Sq, chunk_, c_start, window, causal)
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                        # [B,Sq,H,ck]
        dv_rep = jnp.einsum("bqhk,bqhd->bkhd", p, do)
        dp = jnp.einsum("bqhd,bkhd->bqhk", do, vrep)
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bqhk,bkhd->bqhd", ds, krep) * hd ** -0.5
        dk_rep = jnp.einsum("bqhk,bqhd->bkhd", ds, qf)
        # fold the H = Hk*group broadcast back down
        dk = dk_rep.reshape(B, chunk_, Hk, group, hd).sum(axis=3)
        dv = dv_rep.reshape(B, chunk_, Hk, group, hd).sum(axis=3)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), starts))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, Hk, hd)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, Hk, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def self_attention(p: Params, x: jax.Array, positions: jax.Array, cfg,
                   window: jax.Array | int = -1, causal: bool = True) -> jax.Array:
    """Full-sequence self attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    q = constrain(q, ("pod", "data"), None, "model", None)
    k = constrain(k, ("pod", "data"), None, None, None)
    o = flash_attention(q, k, v, window=window, causal=causal)
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return o @ p["wo"]


# --------------------------------------------------------------------------
# Segment-streamed prefill (q_len == C prompt tokens at offset pos)
# --------------------------------------------------------------------------

def segment_attention(p: Params, x: jax.Array, cache: Params, pos: jax.Array,
                      positions: jax.Array, cfg,
                      window: jax.Array | int = -1) -> Tuple[jax.Array, Params]:
    """Prompt-segment attention against a request's dense KV cache.

    x: [B, C, D] — one C-token prompt segment whose first token sits at
    absolute position ``pos`` (int32 scalar); cache k/v: [B, S, Hk, hd].
    The segment's K/V is scattered into slots ``pos..pos+C-1`` (rows past
    capacity drop), then the queries run the SAME chunked-flash scan as
    the one-shot prefill over the full capacity axis with the causal mask
    offset by ``pos`` — every op from the score einsum on is shared with
    :func:`self_attention`, and flash rows are independent, so a row's
    output is bitwise identical to the one-shot forward's row.
    Returns (output [B, C, D], updated cache).
    """
    B, C, _ = x.shape
    S = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg)
    q, k_new = _rope_qk(q, k_new, positions, cfg)

    idx = jnp.asarray(pos, jnp.int32) + jnp.arange(C)      # [C] absolute
    dst = jnp.where(idx < S, idx, S)                       # overflow drops
    k_cache = cache["k"].at[:, dst].set(k_new, mode="drop")
    v_cache = cache["v"].at[:, dst].set(v_new, mode="drop")

    q = constrain(q, ("pod", "data"), None, "model", None)
    k_att = constrain(k_cache, ("pod", "data"), None, None, None)
    o, _ = _flash_fwd_scan(q, k_att, v_cache, window, True, 1024,
                           q_offset=jnp.asarray(pos, jnp.int32))
    o = o.reshape(B, C, cfg.num_heads * cfg.head_dim)
    return o @ p["wo"], {"k": k_cache, "v": v_cache}


def segment_attention_paged(p: Params, x: jax.Array, cache: Params,
                            pos: jax.Array, positions: jax.Array,
                            pages: jax.Array, cfg,
                            window: jax.Array | int = -1,
                            write_min: Optional[jax.Array] = None,
                            write_max: Optional[jax.Array] = None
                            ) -> Tuple[jax.Array, Params]:
    """Prompt-segment attention against the global paged KV pool.

    x: [B, C, D]; cache k/v: [num_pages, page_size, Hk, hd]; pages:
    [B, max_pages] page table (padded entries are causally masked); pos:
    the segment's first absolute position. K/V rows land through the page
    table only where ``write_min <= idx < write_max`` — shared prefix
    pages (other requests still reference them) and pad rows past the
    prompt are never rewritten; out-of-range rows redirect to page id
    ``num_pages`` and drop.

    Scoring streams the pool through the Pallas chunked paged-prefill
    kernel (:mod:`repro.kernels.prefill_attention`) when the sliding
    window is static and ``write_max`` bounds the valid KV length — the
    kernel's page-table indirection reads each physical page once
    instead of gathering the [B, max_pages*page_size, Hk, hd] dense view
    first. Otherwise (traced window / unbounded write) it falls back to
    the gather + offset flash scan, which is bitwise-identical to the
    dense :func:`segment_attention` path.
    Returns (output [B, C, D], updated pool).
    """
    B, C, _ = x.shape
    N, page_size = cache["k"].shape[0], cache["k"].shape[1]
    max_pages = pages.shape[1]
    S = max_pages * page_size                    # logical capacity
    Hk, hd = cfg.num_kv_heads, cfg.head_dim
    q, k_new, v_new = _project_qkv(p, x, cfg)
    q, k_new = _rope_qk(q, k_new, positions, cfg)

    idx = jnp.asarray(pos, jnp.int32) + jnp.arange(C)      # [C] absolute
    ok = idx < S
    if write_min is not None:
        ok &= idx >= write_min
    if write_max is not None:
        ok &= idx < write_max
    slot = jnp.minimum(idx, S - 1)
    page = jnp.take_along_axis(
        pages, jnp.broadcast_to((slot // page_size)[None, :], (B, C)), axis=1)
    page = jnp.where(ok[None, :], page, N)                 # [B, C]
    off = jnp.broadcast_to((slot % page_size)[None, :], (B, C))
    k_pool = cache["k"].at[page, off].set(k_new, mode="drop")
    v_pool = cache["v"].at[page, off].set(v_new, mode="drop")

    if write_max is not None and isinstance(window, int):
        # Pallas paged-prefill path: full-width CSR rows (n_pages ==
        # max_pages for every row) make the kernel's valid-length mask
        # `(n_pages-1)*page_size + lastlen - 1` come out to exactly
        # write_max - 1; pad page ids (N, out of pool bounds) redirect
        # to page 0 — their keys sit past every query's causal horizon,
        # so the kernel never unmasks them.
        from repro.kernels.prefill_attention import paged_prefill_attention
        plen = jnp.broadcast_to(jnp.asarray(write_max, jnp.int32), (B,))
        indptr = jnp.arange(B + 1, dtype=jnp.int32) * max_pages
        indices = jnp.where(pages < N, pages, 0).reshape(-1)
        lastlen = plen - (max_pages - 1) * page_size
        pos0 = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        o = paged_prefill_attention(q, k_pool, v_pool, indptr, indices,
                                    lastlen, pos0, max_pages=max_pages,
                                    window=window)
    else:
        k_cache = k_pool[pages].reshape(B, S, Hk, hd)
        v_cache = v_pool[pages].reshape(B, S, Hk, hd)
        q = constrain(q, ("pod", "data"), None, "model", None)
        k_att = constrain(k_cache, ("pod", "data"), None, None, None)
        o, _ = _flash_fwd_scan(q, k_att, v_cache, window, True, 1024,
                               q_offset=jnp.asarray(pos, jnp.int32))
    o = o.reshape(B, C, cfg.num_heads * hd)
    return o @ p["wo"], {"k": k_pool, "v": v_pool}


# --------------------------------------------------------------------------
# Cached decode (q_len == 1)
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, capacity: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
    }


def decode_attention(p: Params, x: jax.Array, cache: Params, pos: jax.Array,
                     cfg, window: jax.Array | int = -1) -> Tuple[jax.Array, Params]:
    """One-token attention against a cache of static capacity.

    x: [B, 1, D]; cache k/v: [B, S, Hk, hd]; pos: int32 scalar or [B]
    vector — number of valid cached tokens per batch row (a vector lets a
    continuous-batching scheduler serve requests at different sequence
    positions in one padded step); the new token has position ``pos`` and
    is written into slot ``pos`` (clamped to capacity-1).
    Returns (output [B, 1, D], updated cache).
    """
    B, _, _ = x.shape
    S = cache["k"].shape[1]
    Hk, hd = cfg.num_kv_heads, cfg.head_dim
    group = cfg.num_heads // Hk
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    q, k_new, v_new = _project_qkv(p, x, cfg)
    if cfg.mrope:
        posq = jnp.broadcast_to(pos_b[None, :, None], (3, B, 1))
    else:
        posq = pos_b[:, None]
    q, k_new = _rope_qk(q, k_new, posq, cfg)

    # Write each row's new kv into its slot (donated in the serving step).
    slot = jnp.minimum(pos_b, S - 1)                       # [B]
    k_cache = cache["k"].at[jnp.arange(B), slot].set(k_new[:, 0])
    v_cache = cache["v"].at[jnp.arange(B), slot].set(v_new[:, 0])
    k_cache = constrain(k_cache, ("pod", "data"), "model", None, None)
    v_cache = constrain(v_cache, ("pod", "data"), "model", None, None)

    qg = q.reshape(B, 1, Hk, group, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    j = jnp.arange(S)
    valid = j[None, :] <= slot[:, None]                    # [B, S]
    win = jnp.asarray(window, jnp.int32)
    valid &= jnp.where(win > 0, (pos_b[:, None] - j[None, :]) < win, True)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    return o @ p["wo"], {"k": k_cache, "v": v_cache}


def init_paged_kv_cache(num_pages: int, page_size: int, num_kv_heads: int,
                        head_dim: int, dtype=jnp.bfloat16) -> Params:
    """Global paged KV pool: pages replace the per-row capacity axis."""
    return {
        "k": jnp.zeros((num_pages, page_size, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((num_pages, page_size, num_kv_heads, head_dim), dtype),
    }


def decode_attention_paged(p: Params, x: jax.Array, cache: Params,
                           pos: jax.Array, pages: jax.Array, cfg,
                           window: jax.Array | int = -1,
                           active: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, Params]:
    """One-token attention against the global paged KV pool.

    x: [B, 1, D]; cache k/v: [num_pages, page_size, Hk, hd] — the pool
    shared by every request; pages: [B, max_pages] int32 — each row's
    page table padded with any value (padded entries sit past ``pos``
    and are causally masked); pos: scalar or [B] valid-token counts;
    active: [B] bool — an inactive row's write is DROPPED (its page-table
    row may alias pages owned by live requests, unlike the dense layout
    where a stale row's slot belongs to nobody else).

    Bit-identity with :func:`decode_attention`: the pool is gathered
    through the page table into the same ``[B, max_pages*page_size, Hk,
    hd]`` contiguous view the dense path scores against, and every op
    from the einsum on is shared verbatim — so for ``capacity =
    max_pages * page_size`` an active row's output (and therefore the
    generated tokens) is bitwise identical to the dense engine's.
    Returns (output [B, 1, D], updated pool).
    """
    B, _, _ = x.shape
    N, page_size = cache["k"].shape[0], cache["k"].shape[1]
    max_pages = pages.shape[1]
    S = max_pages * page_size                    # logical capacity
    Hk, hd = cfg.num_kv_heads, cfg.head_dim
    group = cfg.num_heads // Hk
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    q, k_new, v_new = _project_qkv(p, x, cfg)
    if cfg.mrope:
        posq = jnp.broadcast_to(pos_b[None, :, None], (3, B, 1))
    else:
        posq = pos_b[:, None]
    q, k_new = _rope_qk(q, k_new, posq, cfg)

    # Write each row's new kv through its page table; inactive rows write
    # out of bounds (page id N) and drop.
    slot = jnp.minimum(pos_b, S - 1)                       # [B]
    page = jnp.take_along_axis(pages, (slot // page_size)[:, None],
                               axis=1)[:, 0]               # [B] physical
    if active is not None:
        page = jnp.where(active, page, N)
    off = slot % page_size
    k_pool = cache["k"].at[page, off].set(k_new[:, 0], mode="drop")
    v_pool = cache["v"].at[page, off].set(v_new[:, 0], mode="drop")

    # Gather the row's pages into the dense path's [B, S, Hk, hd] view.
    k_cache = k_pool[pages].reshape(B, S, Hk, hd)
    v_cache = v_pool[pages].reshape(B, S, Hk, hd)
    k_cache = constrain(k_cache, ("pod", "data"), "model", None, None)
    v_cache = constrain(v_cache, ("pod", "data"), "model", None, None)

    qg = q.reshape(B, 1, Hk, group, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    j = jnp.arange(S)
    valid = j[None, :] <= slot[:, None]                    # [B, S]
    win = jnp.asarray(window, jnp.int32)
    valid &= jnp.where(win > 0, (pos_b[:, None] - j[None, :]) < win, True)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    return o @ p["wo"], {"k": k_pool, "v": v_pool}


# --------------------------------------------------------------------------
# Cross-attention (enc-dec)
# --------------------------------------------------------------------------

def cross_attention(p: Params, x: jax.Array, memory_kv: Params) -> jax.Array:
    """x: [B, Sq, D] attends over precomputed encoder memory K/V."""
    B, Sq, _ = x.shape
    k, v = memory_kv["k"], memory_kv["v"]        # [B, Sm, Hk, hd]
    hd = k.shape[3]
    H = p["wq"].shape[1] // hd
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    q = constrain(q, ("pod", "data"), None, "model", None)
    o = flash_attention(q, k, v, causal=False)   # chunked: no [Sq, Sm] blowup
    o = o.reshape(B, Sq, H * hd)
    return o @ p["wo"]


def encode_memory_kv(p: Params, memory: jax.Array, num_kv_heads: int,
                     head_dim: int) -> Params:
    """Precompute cross-attention K/V from encoder output."""
    B, Sm, _ = memory.shape
    k = (memory @ p["wk"]).reshape(B, Sm, num_kv_heads, head_dim)
    v = (memory @ p["wv"]).reshape(B, Sm, num_kv_heads, head_dim)
    return {"k": k, "v": v}
