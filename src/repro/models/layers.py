"""Shared neural-net layers: RMSNorm, RoPE/M-RoPE, SwiGLU, embeddings.

All functions are pure; parameters are plain dict pytrees created by the
matching ``*_params`` initializer. Compute dtype is bf16, normalization and
softmax statistics in fp32.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


def _dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rmsnorm_params(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE (standard + multimodal 3-D M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs      # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                            # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int):
    """Qwen2-VL uses (16, 24, 24) for hd=128, i.e. h/w get 3/8 of hd/2 each."""
    half = head_dim // 2
    hw = int(round(0.375 * half))
    return (half - 2 * hw, hw, hw)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections=None) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, hd]; positions3: [3, B, S] (temporal/height/width ids).
    Frequency channels are partitioned into three sections, each rotated by
    its own position stream. For pure text all three streams coincide.
    """
    hd = x.shape[-1]
    if sections is None:
        sections = mrope_sections(hd)
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                                   # [hd/2]
    # angles per stream: [3, B, S, hd/2]
    angles = positions3[..., None].astype(jnp.float32) * freqs
    # select stream per frequency-channel section
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections),
                     total_repeat_length=hd // 2)                   # [hd/2]
    angles = jnp.take_along_axis(
        jnp.moveaxis(angles, 0, -1), sel[None, None, :, None], axis=-1)[..., 0]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU dense FFN
# --------------------------------------------------------------------------

def ffn_params(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _dense_init(k1, (d_model, d_ff)),   # gate
        "w3": _dense_init(k2, (d_model, d_ff)),   # up
        "w2": _dense_init(k3, (d_ff, d_model)),   # down
    }


def ffn_apply(p: Params, x: jax.Array) -> jax.Array:
    from repro.sharding import constrain
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    spec = (("pod", "data"),) + (None,) * (h.ndim - 2) + ("model",)
    h = constrain(h, *spec)
    return h @ p["w2"]


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------

def embed_params(key, vocab: int, d_model: int) -> jax.Array:
    scale = d_model ** -0.5
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * scale
            ).astype(jnp.bfloat16)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def logits_from_embed(table: jax.Array, x: jax.Array,
                      softcap: float = 0.0) -> jax.Array:
    lg = jnp.einsum("bsd,vd->bsv", x, table)
    if softcap > 0:
        lg = softcap * jnp.tanh(lg.astype(jnp.float32) / softcap)
    return lg
