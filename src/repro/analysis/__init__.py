"""reprolint — repo-specific JAX-hygiene static analysis.

Seven rules over the serving stack's hard-won invariants:

=====  ==============================================================
RL001  tracer leak: Python control flow / ``bool()`` / ``float()`` /
       ``.item()`` on traced values inside jit-reachable code
RL002  host sync (``np.asarray`` / ``device_get`` /
       ``block_until_ready``) inside the computed decode/segment hot
       path, outside sanctioned stats-drain points
RL003  donated buffer read again after the donating call
RL004  ``pure_callback`` target writing non-telemetry persistent state
RL005  Pallas kernel package without a ``ref.py`` twin + bitwise parity
       test
RL006  ``EngineStats``/``RunStats``/bench ``record_run`` schema drift
       against the ``tests/test_bench_schema.py`` pins
RL007  ``repro.obs`` trace emission reachable from the jitted call
       graph or the host hot path outside an ``_obs_*`` drain helper
=====  ==============================================================

Run ``python -m repro.analysis`` (see ``--help``); the dynamic complement
is ``tools/compile_gate.py``.
"""
from .core import Finding, Project, Rule, RULES, load_project  # noqa: F401
from . import rules_conventions, rules_jax, rules_obs, \
    rules_purity                                               # noqa: F401
from .baseline import BASELINE_NAME, load_baseline, save_baseline, \
    split_findings                                             # noqa: F401
from .cli import main, run_rules                               # noqa: F401

__all__ = ["Finding", "Project", "Rule", "RULES", "load_project",
           "BASELINE_NAME", "load_baseline", "save_baseline",
           "split_findings", "main", "run_rules"]
