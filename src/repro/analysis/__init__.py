"""reprolint — repo-specific static analysis on a shared dataflow engine.

Eleven rules over the serving stack's hard-won invariants. All rules
consume one shared interprocedural engine (``dataflow.Analysis``): a
project-wide call graph (``callgraph``), per-function summaries of how
parameters escape or get released (``summaries``), and per-function CFGs
with exception edges (``cfg``) — rules query these instead of re-walking
the AST.

=====  ==============================================================
RL001  tracer leak: Python control flow / ``bool()`` / ``float()`` /
       ``.item()`` on traced values inside jit-reachable code
RL002  host sync (``np.asarray`` / ``device_get`` /
       ``block_until_ready``) inside the computed decode/segment hot
       path, outside sanctioned stats-drain points
RL003  donated buffer read again after the donating call
RL004  ``pure_callback`` target writing non-telemetry persistent state
RL005  Pallas kernel package without a ``ref.py`` twin + bitwise parity
       test
RL006  ``EngineStats``/``RunStats``/bench ``record_run`` schema drift
       against the ``tests/test_bench_schema.py`` pins
RL007  ``repro.obs`` trace emission reachable from the jitted call
       graph or the host hot path outside an ``_obs_*`` drain helper
RL008  resource-lifecycle pairing: every KV acquisition
       (``alloc_prompt``/``fork``/``prepare_append``/``claim_slot``/
       ``reserve``) released or handed off on every outgoing path,
       including exception paths
RL009  executor/pool attribute written from a worker callable and the
       submitting thread without a lock or a
       ``# reprolint: shared[atomic]`` annotation
RL010  Pallas kernel contract mismatch: BlockSpec index-map arity,
       kernel/operand counts vs specs, ``out_shape`` vs ``out_specs``
       or the ref twin's dtype, unmasked ragged tails
RL011  config/flag drift: ``EngineConfig`` field unreachable from
       ``serve.py``/README, or a CLI flag nothing consumes
=====  ==============================================================

Severity (``error``/``warning``) is reporting metadata — the SARIF
``level`` and the ``--list`` tag; every *new* finding fails CI. Run
``python -m repro.analysis`` (see ``--help``; ``--sarif`` and
``--changed-only REF`` are the CI integration points); the dynamic
complement is ``tools/compile_gate.py``.
"""
from .core import Finding, Project, Rule, RULES, load_project  # noqa: F401
from . import rules_concurrency, rules_config, rules_conventions, \
    rules_jax, rules_kernels, rules_lifecycle, rules_obs, \
    rules_purity                                               # noqa: F401
from .baseline import BASELINE_NAME, load_baseline, save_baseline, \
    split_findings                                             # noqa: F401
from .callgraph import CallGraph, CallSite, FunctionInfo       # noqa: F401
from .cfg import CFG, EXIT, RAISED, build_cfg, reaches_terminal  # noqa: F401
from .dataflow import Analysis, analysis                       # noqa: F401
from .sarif import sarif_report, write_sarif                   # noqa: F401
from .summaries import FunctionSummary, summarize              # noqa: F401
from .cli import main, run_rules                               # noqa: F401

__all__ = ["Finding", "Project", "Rule", "RULES", "load_project",
           "BASELINE_NAME", "load_baseline", "save_baseline",
           "split_findings", "CallGraph", "CallSite", "FunctionInfo",
           "CFG", "EXIT", "RAISED", "build_cfg", "reaches_terminal",
           "Analysis", "analysis", "sarif_report", "write_sarif",
           "FunctionSummary", "summarize", "main", "run_rules"]
