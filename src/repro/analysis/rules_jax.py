"""JAX-hygiene rules: tracer leaks, hot-path host syncs, donation reuse.

All three rules share the callgraph's jit-boundary index: RL001 analyzes
code *inside* the trace boundary, RL002 code *outside* it (the host
orchestration loop), RL003 the call sites that cross it with donated
buffers.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .core import Finding, Project, Source, call_name, dotted, register, \
    walk_functions
from .callgraph import CallGraph, FunctionInfo
from .dataflow import _graph                                  # noqa: F401

# Attribute reads that are static under tracing (array metadata), so they
# never carry taint out of a tracer.
UNTAINT_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
# Calls whose result is host-static even on traced arguments.
UNTAINT_CALLS = {"len", "isinstance", "type", "hasattr", "callable",
                 "issubclass", "id", "repr"}
# Calls that force a concrete value out of a tracer: each is a trace-time
# error (or a silent constant-fold hazard) inside jitted code.
LEAK_CALLS = {"bool", "float", "int"}

# RL001 findings are scoped to the files the issue names; taint still
# PROPAGATES through every analyzed file so a leak in engine.py caused by
# a call chain through models/attention.py is attributed correctly.
RL001_SCOPE = ("src/repro/serving/engine.py",
               "src/repro/core/collaborative.py",
               "src/repro/models/transformer.py")

# The shared-engine port: the project call graph (and everything layered
# on it) now lives in repro.analysis.dataflow — `_graph` above is that
# engine's call-graph accessor, re-exported here because rules_obs and
# older tests import it from this module.

# ---------------------------------------------------------------------------
# shared taint machinery
# ---------------------------------------------------------------------------

class _Taint:
    """Flow-insensitive name-level taint over one function body."""

    def __init__(self, tainted: Set[str]):
        self.tainted = set(tainted)

    def expr(self, node: ast.AST) -> bool:
        t = self.expr
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in UNTAINT_ATTRS:
                return False
            return t(node.value)
        if isinstance(node, ast.Subscript):
            return t(node.value) or t(node.slice)
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name in UNTAINT_CALLS:
                return False
            parts = [node.func] if isinstance(node.func, ast.Attribute) \
                else []
            parts += list(node.args) + [kw.value for kw in node.keywords]
            return any(t(p) for p in parts)
        if isinstance(node, ast.Compare):
            static_ops = (ast.Is, ast.IsNot, ast.In, ast.NotIn)
            if all(isinstance(op, static_ops) for op in node.ops):
                return False
            return t(node.left) or any(t(c) for c in node.comparators)
        if isinstance(node, (ast.BinOp,)):
            return t(node.left) or t(node.right)
        if isinstance(node, ast.UnaryOp):
            return t(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(t(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return t(node.test) or t(node.body) or t(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(t(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(t(v) for v in node.values if v is not None)
        if isinstance(node, ast.Starred):
            return t(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return any(t(g.iter) for g in node.generators) or t(node.elt)
        if isinstance(node, ast.DictComp):
            return any(t(g.iter) for g in node.generators) \
                or t(node.key) or t(node.value)
        if isinstance(node, ast.JoinedStr):
            return False
        if isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        if isinstance(node, ast.Slice):
            return any(t(p) for p in (node.lower, node.upper, node.step)
                       if p is not None)
        return False

    def bind(self, target: ast.AST, tainted: bool) -> None:
        """Strong update: assigning an untainted value clears the name."""
        if isinstance(target, ast.Name):
            (self.tainted.add if tainted
             else self.tainted.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self.bind(el, tainted)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, tainted)
        # Attribute / Subscript stores: name-level tracking ignores them


def _func_params(fi: FunctionInfo) -> List[str]:
    return fi.param_names()


def _map_call_taint(call: ast.Call, callee: FunctionInfo,
                    taint: _Taint, static: Sequence[str]) -> FrozenSet[str]:
    """Tainted parameter names of ``callee`` for this call site."""
    params = _func_params(callee)
    if params and params[0] in ("self", "cls") \
            and isinstance(call.func, ast.Attribute):
        params = params[1:]
    out: Set[str] = set()
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            if taint.expr(arg.value):
                out.update(params[i:])
            break
        if i < len(params) and taint.expr(arg):
            out.add(params[i])
    for kw in call.keywords:
        if kw.arg is not None and kw.arg in params and taint.expr(kw.value):
            out.add(kw.arg)
    return frozenset(n for n in out if n not in static)


# ---------------------------------------------------------------------------
# RL001 — tracer leak
# ---------------------------------------------------------------------------

@register("RL001", "Python control flow / concretization on a traced value "
                   "inside jit-reachable code")
def rl001_tracer_leak(project: Project) -> List[Finding]:
    """RL001: inside a function reachable from a ``jax.jit`` boundary, a
    value derived from traced arguments must never decide Python control
    flow (``if`` / ``while`` / ternary test) or be concretized
    (``bool()`` / ``float()`` / ``int()`` / ``.item()``) — each is a
    trace-time ``TracerBoolConversionError`` waiting for the first input
    that exercises the branch, or a silent constant-fold if the value is
    weakly typed.

    The analysis is an interprocedural taint pass: a jit root's
    non-static parameters are the sources (``static_argnames`` declared
    on the wrapper are exempt — they ARE Python values at trace time);
    taint follows assignments and call arguments through every function
    in ``src/repro``; array metadata (``.shape`` / ``.ndim`` /
    ``.dtype``), identity/membership tests (``is`` / ``in``) and
    ``len()`` / ``isinstance()`` stay static under tracing and drop the
    taint. Functions defined *inside* a jit-reachable function (scan
    bodies) are analyzed with all parameters traced — their arguments
    are carries. Findings are reported for the serving/engine,
    core/collaborative and models/transformer layers (the files the
    trace boundary actually crosses)."""
    cg = _graph(project)
    findings: List[Finding] = []
    seen: Set[Tuple[str, str, FrozenSet[str]]] = set()
    reported: Set[Tuple[str, int, str]] = set()

    # wrapper static_argnames apply to the wrapped target's params
    statics: Dict[Tuple[str, str], Set[str]] = {}
    for w in cg.jit_wrappers:
        if w.target is not None:
            statics.setdefault((w.target.file, w.target.qualname),
                               set()).update(w.static_argnames)
    for fi in cg.functions.values():
        if fi.jit_decorated:
            statics.setdefault((fi.file, fi.qualname),
                               set()).update(fi.static_argnames)

    work: List[Tuple[FunctionInfo, FrozenSet[str]]] = []
    for fi in cg.jit_targets():
        st = statics.get((fi.file, fi.qualname), set())
        params = [p for p in _func_params(fi)
                  if p not in ("self", "cls") and p not in st]
        work.append((fi, frozenset(params)))

    def emit(fi: FunctionInfo, node: ast.AST, what: str) -> None:
        if not fi.file.startswith(RL001_SCOPE):
            if fi.file not in RL001_SCOPE:
                return
        key = (fi.file, node.lineno, what)
        if key in reported:
            return
        reported.add(key)
        findings.append(Finding("RL001", fi.file, node.lineno,
                                f"{what} on a traced value inside "
                                f"jit-reachable `{fi.qualname}`",
                                symbol=fi.qualname))

    def analyze(fi: FunctionInfo, tainted_params: FrozenSet[str]) -> None:
        key = (fi.file, fi.qualname, tainted_params)
        if key in seen or not tainted_params:
            return
        seen.add(key)
        taint = _Taint(set(tainted_params))
        _walk_jit_body(fi, fi.node, taint)

    def _walk_jit_body(fi: FunctionInfo, func_node: ast.AST,
                       taint: _Taint) -> None:
        for stmt in ast.iter_child_nodes(func_node):
            _stmt(fi, stmt, taint)

    def _scan_calls(fi: FunctionInfo, node: ast.AST, taint: _Taint) -> None:
        """Leak calls + interprocedural propagation in one expression."""
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub)
            if name in LEAK_CALLS and sub.args \
                    and taint.expr(sub.args[0]):
                emit(fi, sub, f"{name}()")
            elif name == "item" and isinstance(sub.func, ast.Attribute) \
                    and taint.expr(sub.func.value):
                emit(fi, sub, ".item()")
            elif name and name not in UNTAINT_CALLS \
                    and name not in LEAK_CALLS:
                targets = []
                for w in cg.wrappers_by_name.get(name, ()):
                    if w.target is not None:
                        targets.append(
                            (w.target, set(w.static_argnames)))
                if not targets:
                    for cand in cg.resolve(name):
                        targets.append(
                            (cand, statics.get(
                                (cand.file, cand.qualname), set())))
                for cand, st in targets:
                    mapped = _map_call_taint(sub, cand, taint, sorted(st))
                    if mapped:
                        work.append((cand, mapped))

    def _stmt(fi: FunctionInfo, stmt: ast.AST, taint: _Taint) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def (scan body): params receive traced carries;
            # closure taint flows in from the enclosing frame
            inner = _Taint(taint.tainted
                           | {p.arg for p in stmt.args.args
                              + stmt.args.posonlyargs + stmt.args.kwonlyargs})
            _walk_jit_body(fi, stmt, inner)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            if taint.expr(stmt.test):
                emit(fi, stmt,
                     "`while`" if isinstance(stmt, ast.While) else "`if`")
            _scan_calls(fi, stmt.test, taint)
            for s in stmt.body + stmt.orelse:
                _stmt(fi, s, taint)
            return
        if isinstance(stmt, ast.For):
            _scan_calls(fi, stmt.iter, taint)
            taint.bind(stmt.target, taint.expr(stmt.iter))
            for s in stmt.body + stmt.orelse:
                _stmt(fi, s, taint)
            return
        if isinstance(stmt, (ast.With,)):
            for s in stmt.body:
                _stmt(fi, s, taint)
            return
        if isinstance(stmt, (ast.Try,)):
            for s in stmt.body + stmt.orelse + stmt.finalbody:
                _stmt(fi, s, taint)
            for h in stmt.handlers:
                for s in h.body:
                    _stmt(fi, s, taint)
            return
        # expression-bearing statements: find ternary tests, leak calls,
        # then apply assignments
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.IfExp) and taint.expr(sub.test):
                emit(fi, sub, "ternary `if`")
        _scan_calls(fi, stmt, taint)
        if isinstance(stmt, ast.Assign):
            val = taint.expr(stmt.value)
            for tgt in stmt.targets:
                taint.bind(tgt, val)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            taint.bind(stmt.target, taint.expr(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            if taint.expr(stmt.value):
                taint.bind(stmt.target, True)

    guard = 0
    while work:
        guard += 1
        if guard > 10000:            # name-collision blowup backstop
            break
        fi, params = work.pop()
        analyze(fi, params)
    return findings


# ---------------------------------------------------------------------------
# RL002 — host sync in the decode/segment hot path
# ---------------------------------------------------------------------------

# the steady-state loop: one scheduler tick, the batched decode step, and
# the segment-stream advance — computed reachability from these
HOT_ENTRIES = ("_tick", "decode_batch", "advance_prefill_state")
# admission / intake / retirement: per-request transitions, not the
# steady-state loop (the compile gate covers them dynamically)
HOT_STOP = ("_admit", "_retire", "submit", "cancel", "fork", "fork_slot",
            "start_prefill", "_open_ticket", "_start_segmented",
            "bind_slot", "claim_slot", "release_slot", "can_admit",
            "prefill_chunked", "prefill_request", "generate", "stats")
# sanctioned drain points: the ONLY places the hot path may synchronize —
# the stats accumulators (one host conversion per tick/chunk batch) and
# the deferred first-token sample (the token must reach the host to
# stream). Inline sites use `# reprolint: allow[RL002] <reason>` instead.
HOT_SANCTIONED = ("_accumulate", "_accumulate_prefill", "sample_first")

# calls that create device values inside a host function (their results
# must not be pulled back with np.asarray & friends in the hot path)
_DEVICE_NS = ("jnp", "lax")
_SYNC_CALLS = {"asarray", "array", "nonzero", "copy"}       # np.<these>


@register("RL002", "host synchronization inside the decode/segment hot "
                   "path outside sanctioned drain points")
def rl002_host_sync(project: Project) -> List[Finding]:
    """RL002: the steady-state serving loop — everything reachable from
    the scheduler tick, the batched decode step and the segment-stream
    advance — must not block on the device. Flagged inside that computed
    call graph:

    * ``jax.device_get(...)`` / ``.block_until_ready()`` — explicit
      syncs, flagged unconditionally;
    * ``np.asarray`` / ``np.array`` / ``np.nonzero`` / ``bool`` /
      ``int`` / ``float`` applied to a value the SAME function created
      on-device (assigned from a ``jnp.*`` / ``jax.*`` op or a jitted
      call) — an implicit transfer+sync.

    The hot path is computed, not hand-listed: reachability from
    ``_tick`` / ``decode_batch`` / ``advance_prefill_state`` by call
    name, stopping at the admission/retirement set (per-request
    transitions) and at the trace boundary (jitted functions are RL001's
    jurisdiction). The sanctioned drain points — the stats accumulators
    and the deferred first-token sample — are exempt by name; inline
    exemptions (the scheduler's once-per-tick token drain) carry a
    ``# reprolint: allow[RL002]`` comment with the reason."""
    cg = _graph(project)
    findings: List[Finding] = []
    hot = cg.reachable(HOT_ENTRIES, stop=set(HOT_STOP) | set(HOT_SANCTIONED))
    wrapper_names = set(cg.wrappers_by_name)
    reported: Set[Tuple[str, int, str]] = set()

    def report(fi: FunctionInfo, line: int, msg: str) -> None:
        # visit() rescans nested statements at every ancestor level so
        # assignments bind before deeper calls are judged — dedup keeps
        # each violation to one finding
        key = (fi.file, line, msg)
        if key not in reported:
            reported.add(key)
            findings.append(Finding("RL002", fi.file, line, msg,
                                    symbol=fi.qualname))

    for fi in hot:
        taint = _Taint(set())
        device = taint.tainted         # device-created names, same frame

        def visit(node, fi=fi, taint=taint, device=device):
            for stmt in ast.iter_child_nodes(node):
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    continue           # nested defs analyzed via callgraph
                for sub in ast.walk(stmt):
                    if not isinstance(sub, ast.Call):
                        continue
                    name = call_name(sub)
                    path = dotted(sub.func) or ""
                    if path in ("jax.device_get", "jax.block_until_ready"):
                        report(fi, sub.lineno,
                               f"`{path}` in hot-path `{fi.qualname}` — "
                               f"blocks the decode loop on the device")
                    elif name == "block_until_ready" \
                            and isinstance(sub.func, ast.Attribute):
                        report(fi, sub.lineno,
                               f"`.block_until_ready()` in hot-path "
                               f"`{fi.qualname}`")
                    elif ((path.startswith("np.") or path.startswith(
                            "numpy.")) and name in _SYNC_CALLS
                            or name in ("bool", "int", "float")) \
                            and sub.args and taint.expr(sub.args[0]):
                        report(fi, sub.lineno,
                               f"`{path or name}()` on a device value in "
                               f"hot-path `{fi.qualname}` — implicit "
                               f"device->host sync")
                if isinstance(stmt, ast.Assign):
                    tainted = _device_expr(stmt.value, taint,
                                           wrapper_names)
                    for tgt in stmt.targets:
                        taint.bind(tgt, tainted)
                elif isinstance(stmt, ast.AugAssign):
                    if _device_expr(stmt.value, taint, wrapper_names):
                        taint.bind(stmt.target, True)
                visit(stmt)

        visit(fi.node)
    return findings


def _device_expr(node: ast.AST, taint: _Taint,
                 wrapper_names: Set[str]) -> bool:
    """Does this expression produce a device value? jnp/lax namespace
    calls, calls through jit wrappers, and derivations of existing
    device-tainted names count; ``jax.device_get`` results are host."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            path = dotted(sub.func) or ""
            name = call_name(sub)
            if path in ("jax.device_get",):
                return False
            root = path.split(".", 1)[0]
            if root in _DEVICE_NS or path.startswith("jax.lax.") \
                    or path.startswith("jax.nn."):
                return True
            if name in wrapper_names:
                return True
        if isinstance(sub, ast.Name) and sub.id in taint.tainted:
            return True
    return False


# ---------------------------------------------------------------------------
# RL003 — donated buffer reused after the call
# ---------------------------------------------------------------------------

@register("RL003", "buffer passed at a donate_argnums position referenced "
                   "again after the call")
def rl003_donation_reuse(project: Project) -> List[Finding]:
    """RL003: a buffer passed at a ``donate_argnums`` position is DEAD
    after the call — XLA may have aliased its memory into the outputs —
    so any later read of the same reference observes garbage (or raises
    a deleted-buffer error on strict backends). For every call through a
    wrapper bound by ``x = jax.jit(fn, donate_argnums=(...))``, the rule
    takes each donated argument with a resolvable path (``state``,
    ``self.fast``, ``batch_state["scan"]``) and scans the remainder of
    the enclosing function for a read of that exact path (or an
    extension of it) before the path is rebound. Rebinding through the
    call's own assignment targets — the repo's threading idiom
    ``logits, state, self.fast, stats = self._decode(..., state,
    self.fast, ...)`` — clears the donation immediately. The scan is
    lexical (single forward pass), which matches the engine's straight-
    line threading style."""
    cg = _graph(project)
    findings: List[Finding] = []
    donating = {w.wrapper_name: w for w in cg.jit_wrappers
                if w.donate_argnums}

    for (file, qual), fi in cg.functions.items():
        if not file.startswith("src/repro"):
            continue
        body_stmts = list(ast.walk(fi.node))
        for stmt in body_stmts:
            if not isinstance(stmt, (ast.Assign, ast.Expr, ast.Return)):
                continue
            calls = [c for c in ast.walk(stmt) if isinstance(c, ast.Call)
                     and call_name(c) in donating]
            for call in calls:
                w = donating[call_name(call)]
                donated: List[str] = []
                for pos in w.donate_argnums:
                    if pos < len(call.args):
                        path = dotted(call.args[pos])
                        if path is not None:
                            donated.append(path)
                if not donated:
                    continue
                # targets of the same statement rebind immediately
                rebound: Set[str] = set()
                if isinstance(stmt, ast.Assign):
                    for tgt in stmt.targets:
                        rebound.update(_target_paths(tgt))
                live = [p for p in donated
                        if p not in rebound
                        and not any(p == r or p.startswith(r + ".")
                                    or p.startswith(r + "[")
                                    for r in rebound)]
                if live:
                    findings.extend(_scan_after(fi, file, stmt, call,
                                                live, set(rebound)))
    # cg.functions lists nested defs separately AND ast.walk on the
    # enclosing function covers their bodies — keep one finding per site
    uniq: Dict[Tuple[str, int, str], Finding] = {}
    for f in findings:
        uniq.setdefault((f.file, f.line, f.message), f)
    return list(uniq.values())


def _target_paths(tgt: ast.AST) -> List[str]:
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out = []
        for el in tgt.elts:
            out.extend(_target_paths(el))
        return out
    if isinstance(tgt, ast.Starred):
        return _target_paths(tgt.value)
    p = dotted(tgt)
    return [p] if p is not None else []


def _scan_after(fi: FunctionInfo, file: str, call_stmt: ast.AST,
                call: ast.Call, donated: List[str],
                rebound: Set[str]) -> List[Finding]:
    findings: List[Finding] = []
    live = {p: True for p in donated}

    def kills(path: str, rebinds: Set[str]) -> bool:
        return any(path == r or path.startswith(r + ".")
                   or path.startswith(r + "[")
                   or r.startswith(path + ".") or r.startswith(path + "[")
                   for r in rebinds)

    for stmt in ast.walk(fi.node):
        if not hasattr(stmt, "lineno") or stmt.lineno <= call_stmt.lineno:
            continue
        if not isinstance(stmt, (ast.Assign, ast.AugAssign, ast.Expr,
                                 ast.Return, ast.If, ast.While, ast.For,
                                 ast.Raise, ast.Assert, ast.AnnAssign)):
            continue
        # reads first (an AugAssign/self-referencing assign reads before
        # it writes)
        exprs: List[ast.AST] = []
        new_rebinds: Set[str] = set()
        if isinstance(stmt, ast.Assign):
            exprs = [stmt.value]
            for tgt in stmt.targets:
                new_rebinds.update(_target_paths(tgt))
        elif isinstance(stmt, ast.AnnAssign):
            exprs = [stmt.value] if stmt.value is not None else []
            new_rebinds.update(_target_paths(stmt.target))
        elif isinstance(stmt, ast.AugAssign):
            exprs = [stmt.value, stmt.target]
            new_rebinds.update(_target_paths(stmt.target))
        elif isinstance(stmt, (ast.Expr, ast.Return, ast.Raise)):
            exprs = [v for v in (getattr(stmt, "value", None),) if v]
        elif isinstance(stmt, (ast.If, ast.While)):
            exprs = [stmt.test]
        elif isinstance(stmt, ast.For):
            exprs = [stmt.iter]
        elif isinstance(stmt, ast.Assert):
            exprs = [stmt.test]
        for path in list(live):
            if not live[path]:
                continue
            for ex in exprs:
                for sub in ast.walk(ex):
                    p = dotted(sub)
                    if p is not None and (p == path
                                          or p.startswith(path + ".")
                                          or p.startswith(path + "[")):
                        findings.append(Finding(
                            "RL003", file, sub.lineno,
                            f"`{path}` was donated to `{call_name(call)}` "
                            f"on line {call.lineno} and read again — the "
                            f"buffer may already be aliased into the "
                            f"outputs", symbol=fi.qualname))
                        live[path] = False
                        break
                if not live[path]:
                    break
            if live[path] and kills(path, new_rebinds):
                live[path] = False
    return findings
