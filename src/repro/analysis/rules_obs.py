"""Observability-hygiene rule: trace emission only at drain points.

The ``repro.obs`` recorder is cheap, but it is still host work — an
emission call appends an event, formats args, and (in the jitted graph)
would force a trace-time side effect. The serving stack's contract is
therefore *event-sourced at the edges*: hot-path code reads the
monotonic clock (``now_ns()`` is just ``time.perf_counter_ns``) and
carries plain integers; the events themselves are emitted only by the
``_obs_*`` drain helpers that run once per tick / decode step / prefill
advance, next to the sanctioned RL002 stats drains. RL007 makes that
contract static.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from .core import Finding, Project, call_name, register
from .callgraph import FunctionInfo
from .dataflow import _graph
from .rules_jax import HOT_ENTRIES, HOT_SANCTIONED, HOT_STOP

# the TraceRecorder emission surface (NoopRecorder mirrors it); reading
# the clock (now_ns) and feeding histograms (LogHistogram.observe) are
# NOT emission — both are branch-free host arithmetic the hot path may do
EMISSION_CALLS = ("complete", "instant", "counter", "span")


@register("RL007", "repro.obs emission call reachable from the jitted "
                   "call graph or the host hot path outside an _obs_* "
                   "drain helper", severity="warning")
def rl007_emission_outside_drain(project: Project) -> List[Finding]:
    """RL007: a ``repro.obs`` emission call (``.complete()`` /
    ``.instant()`` / ``.counter()`` / ``.span()``) may only run at a
    sanctioned drain point. Two call graphs are checked, in every
    analyzed file that imports ``repro.obs`` (the ``src/repro/obs``
    package itself — the recorder's own implementation — is exempt):

    * the **traced graph**: functions reachable from a jit boundary
      (decorated defs, ``jax.jit(...)`` wrapper targets) followed
      *through* the trace boundary, plus ``pure_callback`` host-lane
      targets. Emission here is flagged unconditionally — even inside a
      function named ``_obs_*`` — because an emission under tracing
      fires at trace time, not at step time, and a ``pure_callback``
      body may be re-invoked or elided by XLA;
    * the **host hot path**: RL002's computed reachability from
      ``_tick`` / ``decode_batch`` / ``advance_prefill_state``, with the
      ``_obs_*`` drain helpers added to the stop set. Emission inside
      that graph is flagged unless the enclosing function itself is an
      ``_obs_*`` helper — timing is collected inline as plain
      ``now_ns()`` integers and emitted retroactively at the drain.

    Reading the clock is not emission: ``now_ns()`` calls and
    ``LogHistogram.observe()`` are allowed anywhere. An inline exemption
    (``# reprolint: allow[RL007] <reason>``) works like every other
    rule's."""
    cg = _graph(project)
    findings: List[Finding] = []
    reported: Set[Tuple[str, int]] = set()

    def in_scope(rel: str) -> bool:
        if rel.startswith("src/repro/obs/"):
            return False
        src = project.get(rel)
        return src is not None and "repro.obs" in src.text

    # --- traced graph: through the jit boundary + pure_callback lanes --
    jit_entries = {fi.name for fi in cg.jit_targets()}
    cb_targets: Set[str] = set()
    for src in project.under("src/repro"):
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) \
                    and call_name(node) == "pure_callback" and node.args:
                arg0 = node.args[0]
                name = None
                if isinstance(arg0, ast.Name):
                    name = arg0.id
                elif isinstance(arg0, ast.Attribute):
                    name = arg0.attr
                if name:
                    cb_targets.add(name)
    traced = cg.reachable(sorted(jit_entries | cb_targets),
                          through_jit=True)

    # --- host hot path: RL002's graph, with drain helpers stopped -----
    obs_helpers = {fi.name for fi in cg.functions.values()
                   if fi.name.startswith("_obs_")}
    hot = cg.reachable(
        HOT_ENTRIES,
        stop=set(HOT_STOP) | set(HOT_SANCTIONED) | obs_helpers)

    def scan(fi: FunctionInfo, where: str, allow_obs_helper: bool) -> None:
        if not in_scope(fi.file):
            return
        if allow_obs_helper and fi.name.startswith("_obs_"):
            return
        # whole-body walk (nested defs included — they execute in this
        # frame's dynamic extent); (file, line) dedup keeps one finding
        # per site when a nested def is also a reachable FunctionInfo
        for sub in ast.walk(fi.node):
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub)
            if name not in EMISSION_CALLS:
                continue
            key = (fi.file, sub.lineno)
            if key in reported:
                continue
            reported.add(key)
            findings.append(Finding(
                "RL007", fi.file, sub.lineno,
                f"`.{name}()` trace emission in {where} "
                f"`{fi.qualname}` — emit only from an `_obs_*` drain "
                f"helper (carry now_ns() integers to the drain)",
                symbol=fi.qualname))

    for fi in traced:
        scan(fi, "jit-reachable", allow_obs_helper=False)
    for fi in hot:
        scan(fi, "hot-path", allow_obs_helper=True)
    return findings
