"""RL009 — thread-shared-state discipline in executor/pool classes.

The host miss lane fans work out through ``ThreadPoolExecutor``; its
telemetry counters are the textbook place for a silent data race: a
``self.x += ...`` inside a worker callable races both other workers and
the submitting thread. The paper's cost model *reads* those counters
(``host_busy_us`` feeds the CPU-vs-fetch dispatch decision), so a torn
or lost update skews real scheduling, not just a dashboard.

The rule finds, per class:

* **worker callables** — functions passed to ``<pool>.map`` /
  ``<pool>.submit`` / ``<pool>.apply_async`` or ``Thread(target=...)``,
  resolved to nested ``def``s in the submitting method or to ``self.``
  methods of the class; writes reachable from a worker through same-
  scope helper calls count as worker writes (``run_bucket`` calling
  ``one``);
* **shared attributes** — ``self.`` attributes written inside a worker
  AND written or read elsewhere in the class outside ``__init__``
  (construction happens before the pool exists, so ``__init__`` writes
  don't race).

Every write site of a shared attribute — worker-side or submitting-side
— must be either inside a ``with self.<...lock...>:`` block or
annotated ``# reprolint: shared[atomic] <reason>`` on the writing line,
the repo's explicit "this is telemetry, a torn read is an acceptable
floor" marker (distinct from ``allow[RL009]``, which would hide the
site instead of documenting the contract).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding, Project, Source, call_name, dotted, register

_SHARED_RE = re.compile(r"#\s*reprolint:\s*shared\[atomic\]")
_SUBMITTERS = ("map", "submit", "apply_async")

RL009_PREFIX = "src/repro"


def _worker_exprs(method: ast.AST):
    """Callable expressions handed to a pool/thread inside ``method``."""
    for n in ast.walk(method):
        if not isinstance(n, ast.Call):
            continue
        name = call_name(n)
        if name in _SUBMITTERS and isinstance(n.func, ast.Attribute):
            if n.args:
                yield n.args[0]
        elif name == "Thread":
            for kw in n.keywords:
                if kw.arg == "target":
                    yield kw.value
            if n.args:
                yield n.args[0]


def _local_defs(scope: ast.AST) -> Dict[str, ast.AST]:
    """Nested function defs directly inside ``scope`` (any depth)."""
    return {d.name: d for d in ast.walk(scope)
            if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef))
            and d is not scope}


def _self_attr_writes(fn: ast.AST) -> List[Tuple[str, int]]:
    out = []
    for n in ast.walk(fn):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                base = t
                while isinstance(base, ast.Subscript):
                    base = base.value
                if isinstance(base, ast.Attribute) \
                        and isinstance(base.value, ast.Name) \
                        and base.value.id == "self":
                    out.append((base.attr, n.lineno))
    return out


def _self_attr_reads(fn: ast.AST) -> Set[str]:
    return {n.attr for n in ast.walk(fn)
            if isinstance(n, ast.Attribute)
            and isinstance(n.ctx, ast.Load)
            and isinstance(n.value, ast.Name) and n.value.id == "self"}


def _lock_ranges(method: ast.AST) -> List[Tuple[int, int]]:
    """Line ranges of ``with`` blocks whose context looks like a lock."""
    out = []
    for n in ast.walk(method):
        if isinstance(n, (ast.With, ast.AsyncWith)):
            for it in n.items:
                d = dotted(it.context_expr) or (
                    dotted(it.context_expr.func)
                    if isinstance(it.context_expr, ast.Call) else None)
                if d and "lock" in d.lower():
                    out.append((n.lineno, n.end_lineno or n.lineno))
                    break
    return out


def _worker_closure(root_fn: ast.AST, siblings: Dict[str, ast.AST],
                    methods: Dict[str, ast.AST]) -> List[ast.AST]:
    """The worker plus every same-scope helper it calls (transitively):
    writes inside ``one(g)`` called from ``run_bucket`` are worker
    writes."""
    seen: List[ast.AST] = []
    work = [root_fn]
    while work:
        fn = work.pop()
        if any(fn is s for s in seen):
            continue
        seen.append(fn)
        for n in ast.walk(fn):
            if isinstance(n, ast.Call):
                name = call_name(n)
                if name in siblings:
                    work.append(siblings[name])
                elif isinstance(n.func, ast.Attribute) \
                        and isinstance(n.func.value, ast.Name) \
                        and n.func.value.id == "self" and name in methods:
                    work.append(methods[name])
    return seen


@register("RL009", "executor/pool attribute written from a worker "
                   "callable and the submitting thread without a lock or "
                   "a shared[atomic] annotation")
def check_shared_state(project: Project) -> List[Finding]:
    """Attributes mutated across the pool boundary must declare their
    discipline.

    For each class that submits callables to a thread pool, the rule
    intersects the ``self.`` attributes written inside worker callables
    with those written or read by the rest of the class (``__init__``
    excluded — it runs before the pool). Every write site of such a
    shared attribute must sit inside a ``with self.<lock>:`` block or
    carry ``# reprolint: shared[atomic]`` on its line. The annotation is
    the repo's documented-race marker: the executor's ``busy_ns`` floor
    is the sanctioned example."""
    findings: List[Finding] = []
    for src in project.under(RL009_PREFIX):
        for cls in ast.walk(src.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            methods = {m.name: m for m in cls.body
                       if isinstance(m, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            # worker functions, resolved per submitting method
            workers: List[ast.AST] = []
            for mname, m in methods.items():
                siblings = _local_defs(m)
                for expr in _worker_exprs(m):
                    target = None
                    if isinstance(expr, ast.Name):
                        target = siblings.get(expr.id) \
                            or methods.get(expr.id)
                    elif isinstance(expr, ast.Attribute) \
                            and isinstance(expr.value, ast.Name) \
                            and expr.value.id == "self":
                        target = methods.get(expr.attr)
                    if target is not None:
                        workers.extend(_worker_closure(
                            target, siblings, methods))
            if not workers:
                continue

            def in_worker(line: int) -> bool:
                return any(w.lineno <= line <= (w.end_lineno or w.lineno)
                           for w in workers)

            worker_writes: Dict[str, List[int]] = {}
            outside_writes: Dict[str, List[int]] = {}
            outside_reads: Set[str] = set()
            for mname, m in methods.items():
                for attr, line in _self_attr_writes(m):
                    if in_worker(line):
                        worker_writes.setdefault(attr, []).append(line)
                    elif mname != "__init__":
                        outside_writes.setdefault(attr, []).append(line)
                for n in ast.walk(m):
                    if isinstance(n, ast.Attribute) \
                            and isinstance(n.ctx, ast.Load) \
                            and isinstance(n.value, ast.Name) \
                            and n.value.id == "self" \
                            and mname != "__init__" \
                            and not in_worker(n.lineno):
                        outside_reads.add(n.attr)

            shared = {a for a in worker_writes
                      if a in outside_writes or a in outside_reads}
            if not shared:
                continue
            locked = [r for m in methods.values()
                      for r in _lock_ranges(m)]

            def guarded(line: int) -> bool:
                if any(lo <= line <= hi for lo, hi in locked):
                    return True
                idx = line - 1
                return 0 <= idx < len(src.lines) \
                    and _SHARED_RE.search(src.lines[idx]) is not None

            for attr in sorted(shared):
                sites = worker_writes.get(attr, []) \
                    + outside_writes.get(attr, [])
                for line in sorted(sites):
                    if guarded(line):
                        continue
                    findings.append(Finding(
                        "RL009", src.rel, line,
                        f"'{attr}' is written from a pool worker and the "
                        f"submitting thread without a lock; guard it or "
                        f"annotate the write '# reprolint: "
                        f"shared[atomic] <reason>'", cls.name))
    return findings
