"""reprolint core: findings, parsed sources, suppressions, rule registry.

The analyzer is deliberately dependency-free (stdlib ``ast`` only) so it
can run before anything else in CI — a broken jax install must not take
the lint step down with it. Everything here is *repo-shaped*: rules know
this codebase's conventions (jitted stage functions, donated buffers,
``pure_callback`` host lanes, kernel/ref twins, pinned stats schemas)
rather than generic Python style.

Suppression: a finding on line N is suppressed by a trailing or same-line
comment ``# reprolint: allow[RL002]`` (comma-separate multiple rule ids;
bare ``# reprolint: allow`` suppresses every rule on that line). Each
suppression should carry a reason after the bracket — the sanctioned
host-sync drain points in the serving hot path are marked exactly this
way, so the *exceptions* to an invariant are greppable alongside it.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "Source", "Project", "Rule", "RULES", "register",
           "load_project"]

_ALLOW_RE = re.compile(r"#\s*reprolint:\s*allow(?:\[([A-Z0-9,\s]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One violation. ``render()`` is the CI-facing line; ``key()`` is the
    baseline identity — deliberately line-number-free so unrelated edits
    above a grandfathered finding don't churn the baseline file."""
    rule: str           # "RL001".."RL007"
    file: str           # repo-relative posix path
    line: int           # 1-based
    message: str
    symbol: str = ""    # enclosing function/class qualname ("" = module)

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.rule} {self.message}"

    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.file, self.symbol, self.message)


class Source:
    """One parsed file: text, AST, and per-line suppression sets."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=rel)
        # line -> set of suppressed rule ids; "*" suppresses all
        self.allow: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(line)
            if m:
                ids = m.group(1)
                self.allow[i] = ({"*"} if ids is None else
                                 {s.strip() for s in ids.split(",") if s.strip()})

    def suppressed(self, rule: str, line: int) -> bool:
        ids = self.allow.get(line)
        return ids is not None and ("*" in ids or rule in ids)


class Project:
    """The analyzed tree: parsed sources keyed by repo-relative path.

    ``root`` is the repo root (the directory holding ``src/`` and
    ``tests/``); rules address files as ``src/repro/...`` / ``tests/...``
    so findings and baselines are stable across checkouts."""

    def __init__(self, root: Path, sources: Dict[str, Source]):
        self.root = root
        self.sources = sources

    def get(self, rel: str) -> Optional[Source]:
        return self.sources.get(rel)

    def under(self, prefix: str) -> List[Source]:
        return [s for rel, s in sorted(self.sources.items())
                if rel.startswith(prefix)]

    def exists(self, rel: str) -> bool:
        return (self.root / rel).exists()


def load_project(root: Path,
                 subtrees: Sequence[str] = ("src/repro", "tests",
                                            "benchmarks", "tools"),
                 ) -> Project:
    sources: Dict[str, Source] = {}
    for sub in subtrees:
        base = root / sub
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            sources[rel] = Source(path, rel)
    return Project(root, sources)


@dataclass
class Rule:
    """A registered rule: id, one-line summary, and the check callable
    (``check(project) -> list[Finding]``). The docstring of the callable
    is the rule's long-form documentation (``--explain`` prints it).

    ``severity`` is reporting metadata (the SARIF ``level`` and the
    ``--list`` tag): every *new* finding fails CI regardless of severity
    — ``warning`` marks rules whose findings are contract drift rather
    than latent runtime defects."""
    rule_id: str
    summary: str
    check: callable
    findings_filter: bool = True   # apply per-line allow[] suppression
    severity: str = "error"        # "error" | "warning" | "note"

    def run(self, project: Project) -> List[Finding]:
        found = self.check(project)
        if self.findings_filter:
            found = [f for f in found
                     if not self._suppressed(project, f)]
        return sorted(found, key=lambda f: (f.file, f.line, f.message))

    @staticmethod
    def _suppressed(project: Project, f: Finding) -> bool:
        src = project.get(f.file)
        return src is not None and src.suppressed(f.rule, f.line)


RULES: Dict[str, Rule] = {}


def register(rule_id: str, summary: str, severity: str = "error"):
    """Decorator: register ``check(project) -> [Finding]`` under an id."""
    def deco(fn):
        RULES[rule_id] = Rule(rule_id, summary, fn, severity=severity)
        return fn
    return deco


# -- shared AST helpers ------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, 'a[k].b' for constant
    subscripts; None for anything dynamic."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    if isinstance(node, ast.Subscript):
        base = dotted(node.value)
        if base is None:
            return None
        sl = node.slice
        if isinstance(sl, ast.Constant):
            return f"{base}[{sl.value!r}]"
        return None
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Trailing name of the callee: ``foo`` for foo(...), x.foo(...)."""
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def walk_functions(tree: ast.AST) -> Iterable[Tuple[str, ast.AST]]:
    """Yield (qualname, def-node) for every function/method, including
    nested ones (qualname uses '.' between scopes)."""
    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from visit(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)
    yield from visit(tree, "")
