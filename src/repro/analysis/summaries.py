"""Per-function dataflow summaries: how parameters and attributes flow.

A summary answers, for one function, the questions an *interprocedural*
rule asks at a call site without re-reading the callee's body:

* does parameter ``p`` **escape** — get stored into ``self.*`` / a
  global, or appear in a returned/yielded value?
* which callees is ``p`` **passed to** (bare, as a whole object), at
  which argument position — so escape/release questions recurse through
  the call graph;
* which ``self.`` attributes does the function write and read.

Aliasing is intra-function and assignment-shaped only: ``x = p``,
``x = p.attr``, ``x = p[i]`` (and their tuple-unpack forms) make ``x``
carry ``p``'s flow; a call result is always a *fresh* value
(``x = f(p)`` does NOT alias ``x`` to ``p``) — without that cut every
token derived from a ticket would "escape" the ticket and the lifecycle
rule could never fire. The transitive closure over calls (escape through
a callee that stores its own parameter) is taken by
:class:`repro.analysis.dataflow.Analysis`, which owns the memoized
fixpoint; this module is purely syntactic.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .core import call_name, dotted

__all__ = ["PassSite", "FunctionSummary", "summarize", "alias_closure",
           "bare_names"]


@dataclass(frozen=True)
class PassSite:
    """Parameter (or one of its aliases) passed whole to another call:
    callee trailing name, dotted base of the callee expression, and the
    argument slot it landed in (position, or keyword name)."""
    callee: str
    base: Optional[str]
    pos: int                    # -1 when passed by keyword
    keyword: Optional[str]


@dataclass
class FunctionSummary:
    file: str
    qualname: str
    node: ast.AST
    params: List[str] = field(default_factory=list)
    # params whose alias is stored into self.* / a declared global
    param_stored: Set[str] = field(default_factory=set)
    # params whose alias appears in a return/yield value
    param_returned: Set[str] = field(default_factory=set)
    param_passed: Dict[str, List[PassSite]] = field(default_factory=dict)
    attr_writes: Dict[str, List[int]] = field(default_factory=dict)
    attr_reads: Set[str] = field(default_factory=set)


def bare_names(expr: ast.AST) -> Set[str]:
    """Names appearing *whole* in ``expr`` — as themselves or as the base
    of a subscript, but NOT as the base of an attribute access: in
    ``f(ticket.logits)`` the ticket's payload is read, the ticket object
    itself does not flow."""
    attr_bases = set()
    sub_bases = set()
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name):
            attr_bases.add(id(n.value))
        elif isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name):
            sub_bases.add(id(n.value))
    return {n.id for n in ast.walk(expr)
            if isinstance(n, ast.Name) and id(n) not in attr_bases}


def _alias_pairs(stmt: ast.AST):
    """(target-name, value-expr) pairs from plain/tuple assignments."""
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
        tgt, val = stmt.targets[0], stmt.value
        if isinstance(tgt, ast.Name):
            yield tgt.id, val
        elif isinstance(tgt, ast.Tuple) and isinstance(val, ast.Tuple) \
                and len(tgt.elts) == len(val.elts):
            for t, v in zip(tgt.elts, val.elts):
                if isinstance(t, ast.Name):
                    yield t.id, v
    elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
            and isinstance(stmt.target, ast.Name):
        yield stmt.target.id, stmt.value


def _is_direct_alias(value: ast.AST, of: Set[str]) -> bool:
    """True iff ``value`` is ``x`` / ``x.attr...`` / ``x[i]...`` for some
    tracked name ``x`` — calls cut the alias chain."""
    node = value
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return isinstance(node, ast.Name) and node.id in of


def alias_closure(func: ast.AST, seeds: Set[str]) -> Set[str]:
    """Flow-insensitive closure of ``seeds`` under direct-alias
    assignments anywhere in ``func`` (nested defs included — a closure
    capturing the resource still holds it)."""
    names = set(seeds)
    changed = True
    while changed:
        changed = False
        for stmt in ast.walk(func):
            for tgt, val in _alias_pairs(stmt):
                if tgt not in names and _is_direct_alias(val, names):
                    names.add(tgt)
                    changed = True
    return names


def summarize(file: str, qualname: str, func: ast.AST) -> FunctionSummary:
    s = FunctionSummary(file, qualname, func)
    a = func.args
    s.params = [p.arg for p in a.posonlyargs + a.args] \
        + [p.arg for p in a.kwonlyargs]

    own_globals: Set[str] = set()
    for stmt in ast.walk(func):
        if isinstance(stmt, (ast.Global, ast.Nonlocal)):
            own_globals.update(stmt.names)

    # self.* attribute effects (writes keep lines — RL009 anchors there)
    for n in ast.walk(func):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                base = t
                while isinstance(base, ast.Subscript):
                    base = base.value
                if isinstance(base, ast.Attribute) \
                        and isinstance(base.value, ast.Name) \
                        and base.value.id == "self":
                    s.attr_writes.setdefault(base.attr, []).append(n.lineno)
        elif isinstance(n, ast.Attribute) \
                and isinstance(n.ctx, ast.Load) \
                and isinstance(n.value, ast.Name) and n.value.id == "self":
            s.attr_reads.add(n.attr)

    for p in s.params:
        if p == "self":
            continue
        aliases = alias_closure(func, {p})
        stored = returned = False
        passed: List[PassSite] = []
        def touches(expr: Optional[ast.AST]) -> bool:
            # loose: any alias name anywhere, attr/subscript reads
            # included — storing or returning a *part* of the object
            # still hands its ownership out of this frame
            return expr is not None and any(
                isinstance(x, ast.Name) and x.id in aliases
                for x in ast.walk(expr))

        for n in ast.walk(func):
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = n.targets if isinstance(n, ast.Assign) \
                    else [n.target]
                if not touches(n.value):
                    continue
                for t in targets:
                    root = t
                    while isinstance(root, (ast.Subscript, ast.Attribute)):
                        root = root.value
                    # escape = stored reachable from outside this frame:
                    # a self attribute or a declared global — mutating an
                    # attribute OF the parameter itself is not an escape
                    if isinstance(root, ast.Name) and isinstance(
                            t, (ast.Subscript, ast.Attribute)) \
                            and (root.id == "self"
                                 or root.id in own_globals):
                        stored = True
                    elif isinstance(root, ast.Name) \
                            and root.id in own_globals:
                        stored = True
            elif isinstance(n, (ast.Return, ast.Yield)) \
                    and touches(n.value):
                returned = True
            elif isinstance(n, ast.Call):
                cname = call_name(n)
                if cname is None:
                    continue
                base = dotted(n.func.value) \
                    if isinstance(n.func, ast.Attribute) else None
                for i, arg in enumerate(n.args):
                    if isinstance(arg, ast.Name) and arg.id in aliases:
                        passed.append(PassSite(cname, base, i, None))
                for kw in n.keywords:
                    if isinstance(kw.value, ast.Name) \
                            and kw.value.id in aliases and kw.arg:
                        passed.append(PassSite(cname, base, -1, kw.arg))
        if stored:
            s.param_stored.add(p)
        if returned:
            s.param_returned.add(p)
        if passed:
            s.param_passed[p] = passed
    return s
