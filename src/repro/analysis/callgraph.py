"""Lightweight call graph + jit-boundary index over the project AST.

Two facts the rules need are *computed* here rather than hand-listed:

* which functions are **jit roots** — wrapped by ``jax.jit`` either as a
  decorator (``@jax.jit`` / ``@partial(jax.jit, ...)``) or by the repo's
  engine idiom ``self._decode = jax.jit(self._decode_step,
  donate_argnums=(1, 2), static_argnames=("warm",))`` — together with
  their donated positions and static argument names;
* which functions are **reachable** from a set of entry points through
  ordinary Python calls (RL002's "hot path"), resolved by trailing call
  name: ``self.engine.decode_batch(...)`` resolves to every function
  named ``decode_batch`` in the analyzed tree. Name collisions
  over-approximate reachability, which errs on the side of more
  scrutiny, never less.

Resolution is deliberately name-based, not type-based: the codebase's
method names are distinctive (``advance_prefill_state``, ``_warm_chunk``)
and a static analyzer that needs a type checker to boot defeats the
"runs before everything else in CI" property. Two refinements keep the
name-based graph honest where it matters:

* **import aliases** — ``from repro.util import helper as h`` makes a
  bare ``h(...)`` call record ``helper``, so renamed imports still land
  on the defining function;
* **``self.`` context** — ``self.m(...)`` inside class ``A`` resolves to
  ``A.m`` in the same file when that method exists, falling back to the
  global by-name set only for names the class doesn't define (mixins,
  monkey-patched hooks).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .core import Project, Source, call_name, dotted, walk_functions

__all__ = ["FunctionInfo", "JitWrapper", "CallSite", "CallGraph",
           "build_callgraph"]


@dataclass
class FunctionInfo:
    file: str                       # repo-relative path
    qualname: str                   # "Class.method" / "outer.inner"
    node: ast.AST                   # the FunctionDef
    jit_decorated: bool = False
    static_argnames: Tuple[str, ...] = ()
    donate_argnums: Tuple[int, ...] = ()

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    def param_names(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        if a.vararg:
            names.append(a.vararg.arg)
        names += [p.arg for p in a.kwonlyargs]
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names


@dataclass
class CallSite:
    """One call expression inside a function: the (alias-normalized)
    trailing callee name, the dotted base it was called through
    (``"self"`` for ``self.m(...)``, ``"self.kv_pool"`` for
    ``self.kv_pool.free(...)``, None for bare calls), and the Call node
    itself — enough for rules to resolve context-sensitively without
    re-walking the AST."""
    name: str
    line: int
    base: Optional[str]
    node: ast.Call


@dataclass
class JitWrapper:
    """One ``wrapper = jax.jit(target, ...)`` binding: calls through the
    wrapper name (``self._decode(...)``) enter traced code at ``target``."""
    wrapper_name: str               # trailing name the call sites use
    target: Optional[FunctionInfo]  # resolved target (None if external)
    target_name: str
    donate_argnums: Tuple[int, ...]
    static_argnames: Tuple[str, ...]
    file: str
    line: int


def _const_int_tuple(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
        return tuple(out)
    return ()


def _const_str_tuple(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(el.value for el in node.elts
                     if isinstance(el, ast.Constant)
                     and isinstance(el.value, str))
    return ()


def _is_jax_jit(node: ast.AST) -> bool:
    return dotted(node) in ("jax.jit", "jit")


def _jit_call_parts(call: ast.Call):
    """(target expr, donate, static) for a ``jax.jit(...)`` call, or
    ``@partial(jax.jit, ...)`` decorator call; None otherwise."""
    if _is_jax_jit(call.func):
        target = call.args[0] if call.args else None
    elif call_name(call) == "partial" and call.args \
            and _is_jax_jit(call.args[0]):
        target = None               # decorator form: target is the def
    else:
        return None
    donate: Tuple[int, ...] = ()
    static: Tuple[str, ...] = ()
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            donate = _const_int_tuple(kw.value)
        elif kw.arg in ("static_argnames",):
            static = _const_str_tuple(kw.value)
        elif kw.arg in ("static_argnums",):
            # keep positions as names later via param list; store ints in
            # donate-style tuple on the side is not needed by the rules —
            # the repo uses static_argnames exclusively
            pass
    return target, donate, static


class CallGraph:
    def __init__(self):
        self.functions: Dict[Tuple[str, str], FunctionInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self.jit_wrappers: List[JitWrapper] = []
        # trailing wrapper name -> wrappers (call sites enter traced code)
        self.wrappers_by_name: Dict[str, List[JitWrapper]] = {}
        # (file, qualname) -> trailing names this function calls
        self.calls: Dict[Tuple[str, str], List[Tuple[str, int]]] = {}
        # (file, qualname) -> full call sites (base + node, for dataflow)
        self.call_sites: Dict[Tuple[str, str], List[CallSite]] = {}
        # file -> {local alias -> imported trailing name} (ImportFrom asname)
        self.aliases: Dict[str, Dict[str, str]] = {}

    def add(self, fi: FunctionInfo) -> None:
        self.functions[(fi.file, fi.qualname)] = fi
        self.by_name.setdefault(fi.name, []).append(fi)

    def resolve(self, name: str) -> List[FunctionInfo]:
        return self.by_name.get(name, [])

    def resolve_site(self, file: str, caller_qualname: str,
                     site: CallSite) -> List[FunctionInfo]:
        """Context-sensitive resolution of one call site: ``self.m(...)``
        prefers the caller's own class's ``m`` in the same file; everything
        else falls back to the global trailing-name set."""
        if site.base == "self" and "." in caller_qualname:
            cls = caller_qualname.split(".", 1)[0]
            own = self.functions.get((file, f"{cls}.{site.name}"))
            if own is not None:
                return [own]
        return self.resolve(site.name)

    def jit_targets(self) -> List[FunctionInfo]:
        """Every function traced code enters: decorated defs plus the
        resolved targets of ``jax.jit(...)`` assignment wrappers."""
        out = {}
        for fi in self.functions.values():
            if fi.jit_decorated:
                out[(fi.file, fi.qualname)] = fi
        for w in self.jit_wrappers:
            if w.target is not None:
                out[(w.target.file, w.target.qualname)] = w.target
        return list(out.values())

    def reachable(self, entries: Sequence[str],
                  stop: Iterable[str] = (),
                  through_jit: bool = False) -> List[FunctionInfo]:
        """Functions reachable from the named entries via call edges.

        ``entries``/``stop`` are trailing function names. ``stop`` names
        are never traversed *into* (their bodies stay out of the result).
        With ``through_jit=False`` a call that enters traced code (a jit
        wrapper name or a jit-decorated function) is not followed — host
        rules stop at the trace boundary."""
        stop = set(stop)
        jit_names = set(self.wrappers_by_name)
        if not through_jit:
            jit_names |= {fi.name for fi in self.jit_targets()}
        seen: Dict[Tuple[str, str], FunctionInfo] = {}
        work = [fi for name in entries for fi in self.resolve(name)]
        while work:
            fi = work.pop()
            key = (fi.file, fi.qualname)
            if key in seen:
                continue
            seen[key] = fi
            for callee, _line in self.calls.get(key, ()):
                if callee in stop:
                    continue
                if not through_jit and callee in jit_names:
                    continue
                for nxt in self.resolve(callee):
                    if (nxt.file, nxt.qualname) not in seen:
                        work.append(nxt)
        return list(seen.values())


def build_callgraph(project: Project,
                    prefix: str = "src/repro") -> CallGraph:
    cg = CallGraph()
    for src in project.under(prefix):
        _index_file(cg, src)
    # resolve assignment-form wrapper targets now that every def is known
    for w in cg.jit_wrappers:
        if w.target is None and w.target_name:
            cands = cg.resolve(w.target_name)
            if len(cands) >= 1:
                # prefer a target in the same file (the engine idiom)
                same = [c for c in cands if c.file == w.file]
                w.target = (same or cands)[0]
        cg.wrappers_by_name.setdefault(w.wrapper_name, []).append(w)
    return cg


def _index_file(cg: CallGraph, src: Source) -> None:
    # import aliases: bare calls through `from m import f as g` record f,
    # so renaming an import never hides a call edge
    aliases: Dict[str, str] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.asname and a.asname != a.name:
                    aliases[a.asname] = a.name.rsplit(".", 1)[-1]
    cg.aliases[src.rel] = aliases

    for qual, node in walk_functions(src.tree):
        fi = FunctionInfo(src.rel, qual, node)
        for dec in node.decorator_list:
            if _is_jax_jit(dec):
                fi.jit_decorated = True
            elif isinstance(dec, ast.Call):
                parts = _jit_call_parts(dec)
                if parts is not None:
                    fi.jit_decorated = True
                    _, fi.donate_argnums, fi.static_argnames = parts
        cg.add(fi)
        calls = []
        sites = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = call_name(sub)
                if name:
                    base = None
                    if isinstance(sub.func, ast.Name):
                        name = aliases.get(name, name)
                    else:
                        base = dotted(sub.func.value)
                    calls.append((name, sub.lineno))
                    sites.append(CallSite(name, sub.lineno, base, sub))
        cg.calls[(src.rel, qual)] = calls
        cg.call_sites[(src.rel, qual)] = sites

    # assignment-form wrappers: self._decode = jax.jit(self._decode_step,
    # donate_argnums=(1, 2), ...) — anywhere in the file (typically
    # __init__), keyed by the wrapper's trailing attribute name
    for node in ast.walk(src.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.value, ast.Call)):
            continue
        parts = _jit_call_parts(node.value)
        if parts is None or parts[0] is None:
            continue
        target_expr, donate, static = parts
        tname = dotted(target_expr)
        if tname is None:
            continue
        wname = dotted(node.targets[0])
        if wname is None:
            continue
        cg.jit_wrappers.append(JitWrapper(
            wrapper_name=wname.rsplit(".", 1)[-1],
            target=None,
            target_name=tname.rsplit(".", 1)[-1],
            donate_argnums=donate,
            static_argnames=static,
            file=src.rel,
            line=node.lineno))
