"""``python -m repro.analysis`` — run every rule, diff against the
baseline, print ``file:line rule-id message`` lines, exit non-zero on any
new finding or stale baseline entry.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from . import rules_conventions, rules_jax, rules_obs, \
    rules_purity                                          # noqa: F401
from .baseline import BASELINE_NAME, load_baseline, save_baseline, \
    split_findings
from .core import Finding, RULES, load_project


def _find_root(start: Path) -> Path:
    """Walk up from ``start`` to the directory holding ``src/repro``."""
    for cand in (start, *start.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    # running from an installed/bare checkout: fall back to the package's
    # own location (…/src/repro/analysis -> repo root three levels up)
    return Path(__file__).resolve().parents[3]


def run_rules(project, only: Optional[List[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for rule_id in sorted(RULES):
        if only and rule_id not in only:
            continue
        findings.extend(RULES[rule_id].run(project))
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule,
                                           f.message))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: repo-specific JAX-hygiene static analysis "
                    "(RL001-RL007)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected from cwd)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current finding set "
                         "and exit 0")
    ap.add_argument("--rules", nargs="*", metavar="RLxxx",
                    help="run only these rule ids")
    ap.add_argument("--explain", metavar="RLxxx",
                    help="print a rule's full documentation and exit")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--json", type=Path, default=None,
                    help="also write a findings report (new/grandfathered/"
                         "stale) as JSON — the CI artifact")
    args = ap.parse_args(argv)

    if args.list:
        for rule_id in sorted(RULES):
            print(f"{rule_id}  {RULES[rule_id].summary}")
        return 0
    if args.explain:
        rule = RULES.get(args.explain)
        if rule is None:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(RULES))}", file=sys.stderr)
            return 2
        print(f"{rule.rule_id} — {rule.summary}\n")
        print(rule.check.__doc__ or "(no documentation)")
        return 0

    root = (args.root or _find_root(Path.cwd())).resolve()
    project = load_project(root)
    findings = run_rules(project, args.rules)

    baseline_path = args.baseline or (root / BASELINE_NAME)
    if args.update_baseline:
        save_baseline(baseline_path, findings)
        print(f"baseline updated: {len(findings)} grandfathered finding(s) "
              f"-> {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, old, stale = split_findings(findings, baseline)

    for f in new:
        print(f.render())
    for key in stale:
        print(f"{key[1]} STALE-BASELINE {key[0]} entry no longer matches "
              f"any finding (fixed? retire it): {key[3]}")

    if args.json:
        args.json.write_text(json.dumps({
            "new": [f.__dict__ for f in new],
            "grandfathered": [f.__dict__ for f in old],
            "stale_baseline": [list(k) for k in stale],
        }, indent=2) + "\n")

    if new or stale:
        print(f"\nreprolint: {len(new)} new finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'} "
              f"({len(old)} grandfathered)", file=sys.stderr)
        return 1
    print(f"reprolint: clean ({len(findings)} finding(s), all "
          f"grandfathered)" if findings else "reprolint: clean")
    return 0
