"""``python -m repro.analysis`` — run every rule, diff against the
baseline, print ``file:line rule-id message`` lines, exit non-zero on any
new finding or stale baseline entry.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import List, Optional, Set

from . import rules_concurrency, rules_config, rules_conventions, \
    rules_jax, rules_kernels, rules_lifecycle, rules_obs, \
    rules_purity                                          # noqa: F401
from .baseline import BASELINE_NAME, load_baseline, save_baseline, \
    split_findings
from .core import Finding, RULES, load_project
from .sarif import write_sarif


def _find_root(start: Path) -> Path:
    """Walk up from ``start`` to the directory holding ``src/repro``."""
    for cand in (start, *start.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    # running from an installed/bare checkout: fall back to the package's
    # own location (…/src/repro/analysis -> repo root three levels up)
    return Path(__file__).resolve().parents[3]


def run_rules(project, only: Optional[List[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for rule_id in sorted(RULES):
        if only and rule_id not in only:
            continue
        findings.extend(RULES[rule_id].run(project))
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule,
                                           f.message))


def _changed_files(root: Path, ref: str) -> Optional[Set[str]]:
    """Repo-relative paths changed vs ``ref`` plus untracked files, or
    None when git is unavailable (fail open: report everything)."""
    try:
        diff = subprocess.run(
            ["git", "diff", "--name-only", ref],
            cwd=root, capture_output=True, text=True, check=True)
        untracked = subprocess.run(
            ["git", "ls-files", "--others", "--exclude-standard"],
            cwd=root, capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError) as e:
        print(f"reprolint: --changed-only: git failed ({e}); "
              f"reporting all files", file=sys.stderr)
        return None
    return {line.strip() for line in
            (diff.stdout + untracked.stdout).splitlines() if line.strip()}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: repo-specific JAX-hygiene static analysis "
                    "(RL001-RL011)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected from cwd)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current finding set "
                         "and exit 0")
    ap.add_argument("--rules", nargs="*", metavar="RLxxx",
                    help="run only these rule ids")
    ap.add_argument("--explain", metavar="RLxxx",
                    help="print a rule's full documentation and exit")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--json", type=Path, default=None,
                    help="also write a findings report (new/grandfathered/"
                         "stale) as JSON — the CI artifact")
    ap.add_argument("--sarif", type=Path, default=None,
                    help="also write the NEW findings as SARIF 2.1.0 "
                         "(code-scanning upload)")
    ap.add_argument("--changed-only", metavar="REF", default=None,
                    help="report only findings (and stale baseline "
                         "entries) in files changed vs this git ref; "
                         "rules still analyze the whole project so "
                         "cross-file reasoning stays sound")
    args = ap.parse_args(argv)

    if args.list:
        for rule_id in sorted(RULES):
            r = RULES[rule_id]
            print(f"{rule_id}  [{r.severity}] {r.summary}")
        return 0
    if args.explain:
        rule = RULES.get(args.explain)
        if rule is None:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(RULES))}", file=sys.stderr)
            return 2
        print(f"{rule.rule_id} — {rule.summary}\n")
        print(rule.check.__doc__ or "(no documentation)")
        return 0

    root = (args.root or _find_root(Path.cwd())).resolve()
    project = load_project(root)
    findings = run_rules(project, args.rules)

    baseline_path = args.baseline or (root / BASELINE_NAME)
    if args.update_baseline:
        save_baseline(baseline_path, findings)
        print(f"baseline updated: {len(findings)} grandfathered finding(s) "
              f"-> {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, old, stale = split_findings(findings, baseline)

    if args.changed_only:
        changed = _changed_files(root, args.changed_only)
        if changed is not None:
            new = [f for f in new if f.file in changed]
            stale = [k for k in stale if k[1] in changed]

    for f in new:
        print(f.render())
    for key in stale:
        print(f"{key[1]} STALE-BASELINE {key[0]} entry no longer matches "
              f"any finding (fixed? retire it): {key[3]}")

    if args.json:
        args.json.write_text(json.dumps({
            "new": [dict(f.__dict__,
                         severity=RULES[f.rule].severity
                         if f.rule in RULES else "error")
                    for f in new],
            "grandfathered": [dict(f.__dict__,
                                   severity=RULES[f.rule].severity
                                   if f.rule in RULES else "error")
                              for f in old],
            "stale_baseline": [list(k) for k in stale],
        }, indent=2) + "\n")
    if args.sarif:
        write_sarif(args.sarif, new)

    if new or stale:
        print(f"\nreprolint: {len(new)} new finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'} "
              f"({len(old)} grandfathered)", file=sys.stderr)
        return 1
    print(f"reprolint: clean ({len(findings)} finding(s), all "
          f"grandfathered)" if findings else "reprolint: clean")
    return 0
