"""The shared interprocedural engine every rule consumes.

One :class:`Analysis` per :class:`~repro.analysis.core.Project` bundles

* the project call graph (:mod:`repro.analysis.callgraph`) — built once,
  shared by RL001/RL002/RL003/RL007/RL008/RL009;
* per-function :class:`~repro.analysis.summaries.FunctionSummary`
  objects, computed lazily and memoized;
* per-function CFGs (:mod:`repro.analysis.cfg`), likewise lazy;
* the two interprocedural fixpoints the summaries alone can't answer:
  :meth:`param_escapes` ("does this argument leave the callee's frame,
  transitively?") and :meth:`param_released_by` ("does the callee, or
  anything it forwards to, pass it to one of these release calls?").

The cache is keyed by object identity with a liveness check, exactly like
the rule-local cache it replaces: the CLI builds one project per run, and
tests that build many small projects must not cross-pollinate.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .callgraph import CallGraph, CallSite, FunctionInfo, build_callgraph
from .cfg import CFG, build_cfg
from .core import Project
from .summaries import FunctionSummary, summarize

__all__ = ["Analysis", "analysis"]


class Analysis:
    def __init__(self, project: Project):
        self.project = project
        self.graph: CallGraph = build_callgraph(project)
        self._summaries: Dict[Tuple[str, str], FunctionSummary] = {}
        self._cfgs: Dict[Tuple[str, str], CFG] = {}
        self._escape_memo: Dict[Tuple[str, str, str], bool] = {}
        self._release_memo: Dict[Tuple[str, str, str, frozenset], bool] = {}

    # -- lazy per-function artifacts --------------------------------------
    def summary(self, fi: FunctionInfo) -> FunctionSummary:
        key = (fi.file, fi.qualname)
        if key not in self._summaries:
            self._summaries[key] = summarize(fi.file, fi.qualname, fi.node)
        return self._summaries[key]

    def cfg(self, fi: FunctionInfo) -> CFG:
        key = (fi.file, fi.qualname)
        if key not in self._cfgs:
            self._cfgs[key] = build_cfg(fi.node)
        return self._cfgs[key]

    # -- interprocedural queries ------------------------------------------
    def _callee_param(self, callee: FunctionInfo, pos: int,
                      keyword: Optional[str],
                      through_attr: bool) -> Optional[str]:
        """Map a call-site argument slot to the callee's parameter name
        (shifting past ``self`` for attribute-style method calls)."""
        params = self.summary(callee).params
        if keyword is not None:
            return keyword if keyword in params else None
        off = 1 if (through_attr and params and params[0] == "self") else 0
        idx = pos + off
        return params[idx] if 0 <= idx < len(params) else None

    def param_escapes(self, fi: FunctionInfo, param: str,
                      _depth: int = 0) -> bool:
        """True iff ``param`` can leave ``fi``'s frame: stored into
        ``self.*``/a global, returned/yielded, or passed whole to a
        callee that (transitively) does either — or to a callee this
        project doesn't define, which must be assumed to keep it."""
        key = (fi.file, fi.qualname, param)
        if key in self._escape_memo:
            return self._escape_memo[key]
        if _depth > 6:
            return True                       # deep chain: assume escape
        self._escape_memo[key] = False        # optimistic on cycles
        s = self.summary(fi)
        out = param in s.param_stored or param in s.param_returned
        if not out:
            for site in s.param_passed.get(param, ()):
                cands = self._resolve_pass(fi, site)
                if not cands:
                    out = True                # unknown callee keeps it
                    break
                for c in cands:
                    cp = self._callee_param(c, site.pos, site.keyword,
                                            site.base is not None)
                    if cp is None:
                        out = True            # *args soup: assume escape
                    elif self.param_escapes(c, cp, _depth + 1):
                        out = True
                if out:
                    break
        self._escape_memo[key] = out
        return out

    def param_released_by(self, fi: FunctionInfo, param: str,
                          release_names: Iterable[str],
                          _depth: int = 0) -> bool:
        """True iff ``fi`` passes ``param`` (whole) to a call whose
        trailing name is in ``release_names`` — directly or through a
        project-defined callee. Unknown callees do NOT release."""
        rel = frozenset(release_names)
        key = (fi.file, fi.qualname, param, rel)
        if key in self._release_memo:
            return self._release_memo[key]
        if _depth > 6:
            return False
        self._release_memo[key] = False
        s = self.summary(fi)
        out = False
        for site in s.param_passed.get(param, ()):
            if site.callee in rel:
                out = True
                break
            for c in self._resolve_pass(fi, site):
                cp = self._callee_param(c, site.pos, site.keyword,
                                        site.base is not None)
                if cp is not None and self.param_released_by(
                        c, cp, rel, _depth + 1):
                    out = True
                    break
            if out:
                break
        self._release_memo[key] = out
        return out

    def _resolve_pass(self, caller: FunctionInfo,
                      site) -> List[FunctionInfo]:
        cs = CallSite(site.callee, 0, site.base, None)
        return self.graph.resolve_site(caller.file, caller.qualname, cs)


_cache: Dict[int, Tuple[Project, Analysis]] = {}


def analysis(project: Project) -> Analysis:
    """Memoized Analysis for ``project`` (one live project at a time —
    the CLI's case; tests with many small projects stay correct because
    the key is checked by identity, not reused across objects)."""
    key = id(project)
    hit = _cache.get(key)
    if hit is None or hit[0] is not project:
        _cache.clear()
        _cache[key] = (project, Analysis(project))
    return _cache[key][1]


def _graph(project: Project) -> CallGraph:
    """Back-compat shim: the v1 rules asked for the bare call graph."""
    return analysis(project).graph
