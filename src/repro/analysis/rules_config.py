"""RL011 — config/flag drift between ``EngineConfig`` and the CLI.

The serving stack is steered by two surfaces that must stay in sync by
hand: ``EngineConfig`` (the dataclass every knob lands in) and
``serve.py`` (the flags an operator can actually set). Drift is silent
in both directions — a config field nobody can reach from the CLI or
the docs is dead weight that readers will assume is tunable, and an
``add_argument`` whose ``dest`` no code ever reads is a flag that
parses, prints in ``--help``, and does nothing.

Two checks, both textual-with-AST-anchors (warning severity — drift is
a documentation bug, not a correctness bug):

* every annotated field of a class named ``EngineConfig`` must appear
  as a whole word in some ``serve.py`` under ``src/repro`` or in the
  repo-root ``README.md``;
* every ``add_argument`` dest in a ``serve.py`` must be consumed as
  ``args.<dest>`` somewhere in that file.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from .core import Finding, Project, Source, call_name, register

CONFIG_CLASS = "EngineConfig"


def _config_fields(src: Source) -> List[ast.AnnAssign]:
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef) and node.name == CONFIG_CLASS:
            return [s for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)]
    return []


def _arg_dest(call: ast.Call) -> Optional[str]:
    """The argparse dest: explicit ``dest=`` kw, else the first long
    option with dashes mapped to underscores."""
    for kw in call.keywords:
        if kw.arg == "dest" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            return kw.value.value
    for a in call.args:
        if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                and a.value.startswith("--"):
            return a.value.lstrip("-").replace("-", "_")
    # positional argument ("prompt"): consumed as args.<name> too
    for a in call.args:
        if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                and not a.value.startswith("-"):
            return a.value.replace("-", "_")
    return None


@register("RL011", "config/flag drift: EngineConfig field unreachable "
                   "from serve.py or README, or a CLI flag with no "
                   "args.<dest> consumer", severity="warning")
def check_config_drift(project: Project) -> List[Finding]:
    """Both steering surfaces must acknowledge each other.

    ``EngineConfig`` fields are checked for whole-word mentions in any
    ``serve.py`` under ``src/repro`` or in the repo-root ``README.md``
    (either counts: a field may be launch-wired or docs-only-by-design,
    but invisible-in-both means operators cannot discover it). CLI
    dests are checked for an ``args.<dest>`` read in their own file —
    an unparsed-into-anything flag is dead."""
    findings: List[Finding] = []

    serve_srcs = [s for s in project.under("src/repro")
                  if s.rel.endswith("/serve.py") or s.rel == "serve.py"]
    surfaces = [s.text for s in serve_srcs]
    readme = project.root / "README.md"
    if readme.exists():
        surfaces.append(readme.read_text())

    for src in project.under("src/repro"):
        if CONFIG_CLASS not in src.text:
            continue
        for field in _config_fields(src):
            name = field.target.id
            pat = re.compile(rf"\b{re.escape(name)}\b")
            if any(pat.search(t) for t in surfaces):
                continue
            findings.append(Finding(
                "RL011", src.rel, field.lineno,
                f"EngineConfig field '{name}' appears in no serve.py "
                f"and not in README.md: operators cannot discover or "
                f"set it", CONFIG_CLASS))

    for src in serve_srcs:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call) \
                    or call_name(node) != "add_argument":
                continue
            dest = _arg_dest(node)
            if dest is None:
                continue
            if dest == "help" or re.search(
                    rf"\bargs\.{re.escape(dest)}\b", src.text):
                continue
            findings.append(Finding(
                "RL011", src.rel, node.lineno,
                f"CLI flag dest '{dest}' is parsed but 'args.{dest}' "
                f"is never read: the flag does nothing", dest))
    return findings
