"""Baseline: grandfathered findings that don't fail CI.

The baseline file is a checked-in, line-oriented ledger of findings that
predate the analyzer (or are explicitly accepted). Each entry is the
finding's line-number-free identity — ``rule<TAB>file<TAB>symbol<TAB>
message`` — so edits elsewhere in a file don't churn the ledger. The CLI
fails on any finding NOT in the baseline, and also on any baseline entry
that no longer matches a finding (a *stale* entry: the defect was fixed,
so the grandfather must be retired — this keeps the ledger honest and is
what ``--update-baseline`` rewrites).
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Set, Tuple

from .core import Finding

__all__ = ["BASELINE_NAME", "load_baseline", "save_baseline",
           "split_findings"]

BASELINE_NAME = ".reprolint-baseline"

Key = Tuple[str, str, str, str]

_HEADER = """\
# reprolint baseline — grandfathered findings (rule\\tfile\\tsymbol\\tmessage)
# Entries here are accepted, pre-existing findings: the CLI fails on any
# NEW finding and on any STALE entry (listed here but no longer found).
# Regenerate with: python -m repro.analysis --update-baseline
"""


def load_baseline(path: Path) -> Set[Key]:
    keys: Set[Key] = set()
    if not path.exists():
        return keys
    for line in path.read_text().splitlines():
        line = line.rstrip("\n")
        if not line or line.startswith("#"):
            continue
        parts = line.split("\t")
        if len(parts) == 4:
            keys.add(tuple(parts))      # type: ignore[arg-type]
    return keys


def _clean_key(key: Key) -> Key:
    """The on-disk form of a key: the format is tab-separated and
    newline-terminated, so a tab/newline inside a message would corrupt
    the row. Applied on save AND on comparison so a finding whose
    message contains whitespace-control chars still matches its entry."""
    return tuple(part.replace("\t", " ").replace("\n", " ")
                 .replace("\r", " ") for part in key)  # type: ignore


def save_baseline(path: Path, findings: Iterable[Finding]) -> None:
    # byte-stable output (sorted, deduped, sanitized) is pinned by the
    # schema tests: the same finding set always serializes identically
    rows = sorted({_clean_key(f.key()) for f in findings})
    body = "".join("\t".join(row) + "\n" for row in rows)
    path.write_text(_HEADER + body)


def split_findings(findings: List[Finding], baseline: Set[Key]
                   ) -> Tuple[List[Finding], List[Finding], List[Key]]:
    """(new, grandfathered, stale): findings not in the baseline, findings
    the baseline accepts, and baseline entries nothing matched."""
    new: List[Finding] = []
    old: List[Finding] = []
    matched: Set[Key] = set()
    for f in findings:
        k = _clean_key(f.key())
        if k in baseline:
            old.append(f)
            matched.add(k)
        else:
            new.append(f)
    stale = sorted(baseline - matched)
    return new, old, stale
