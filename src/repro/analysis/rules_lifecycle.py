"""RL008 — resource-lifecycle pairing on every path, exception paths
included.

The serving stack's refcounted resources follow three pairing shapes,
and the rule checks each with the cheapest analysis that is sound for
it:

**Path mode** (CFG + summaries) — acquires whose result is a value the
acquirer must either release or hand off: ``kv_pool.alloc_prompt`` (a
page table), a pool ``fork`` (a child table), and any project function
that *propagates* an acquire by returning it (``start_prefill`` returns
a ticket carrying ``alloc_prompt``'s table, so its callers inherit the
obligation — computed, not hand-listed). From the acquire statement,
every CFG path — normal and exceptional — must reach a discharge before
leaving the function:

* a **release** call (``free`` / ``abort_ticket``) taking the resource:
  absorbs the path entirely;
* an **escape** — stored into ``self.*``/a global, or passed whole to a
  callee whose summary stores/returns/releases it (ownership moved to a
  longer-lived frame), or passed to a callee this project doesn't
  define (assumed to keep it);
* a ``return``/``yield`` carrying the resource — but only on the
  statement's *fall-through* edge: ``return self._open_ticket(...,
  table, ...)`` raising mid-call has not escaped the table, which is
  exactly the page-leak class PR 7 fixed by hand.

One guard's worth of path-sensitivity rides the walk: an ``if`` arm the
``None``-ness of the resource proves impossible is skipped, so the
canonical handler ``except BaseException: if table is not None:
free(table); raise`` verifies instead of flagging its own guard.

**Sequence mode** — ``prepare_append`` stages pool mutations that
``commit_append`` lands; the plan is consumed within the step, so the
contract is lexical: a function calling ``prepare_append`` must call
``commit_append`` further down the same function (the calls sit in
separate per-slot loops, which path mode would over-flag).

**Component mode** — ``claim_slot``/``release_slot`` and
``reserve``/``land`` pair across functions and ticks by design
(claim at admission, release at retire/cancel). Statically checkable:
the release side must exist *somewhere* in the project — an acquire
with no matching release call anywhere is dead pinned memory.

Provider files (``serving/kv_pool.py``, ``core/cache.py``,
``core/policies.py``) implement the lifecycle and are exempt — the rule
governs consumers.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .callgraph import CallSite, FunctionInfo
from .cfg import EXIT, RAISED, build_cfg, header_exprs, reaches_terminal
from .core import Finding, Project, call_name, dotted, register
from .dataflow import Analysis, analysis
from .summaries import alias_closure, bare_names

# acquires checked in path mode: trailing call name -> needs pool base?
PATH_ACQUIRES = {"alloc_prompt": False, "fork": True}
# discharge calls for path mode: passing the resource here releases it
RELEASES = ("free", "abort_ticket")
# lexical pairs: staged call -> the landing call later in the function
SEQ_PAIRS = {"prepare_append": "commit_append"}
# cross-function pairs: acquire call name -> release call name that must
# exist somewhere in the analyzed tree
COMPONENT_PAIRS = {"claim_slot": "release_slot", "reserve": "land"}

PROVIDER_SUFFIXES = ("serving/kv_pool.py", "core/cache.py",
                     "core/policies.py")

RL008_PREFIX = "src/repro"


def _is_provider(rel: str) -> bool:
    return rel.endswith(PROVIDER_SUFFIXES)


def _acquire_call(expr: ast.AST,
                  propagated: Set[str]) -> Optional[ast.Call]:
    """The path-mode acquire Call inside ``expr``, if any."""
    for n in ast.walk(expr):
        if not isinstance(n, ast.Call):
            continue
        name = call_name(n)
        if name is None:
            continue
        if name in propagated:
            return n
        needs_pool = PATH_ACQUIRES.get(name)
        if needs_pool is None:
            continue
        if needs_pool:
            base = dotted(n.func.value) \
                if isinstance(n.func, ast.Attribute) else None
            if base is None or "pool" not in base.lower():
                continue
        return n
    return None


def _bound_name(stmt: ast.AST, acq: ast.Call) -> Optional[str]:
    """The local name the acquire's result is bound to: ``x = acq()`` or
    ``x, y = acq()`` (first element carries the resource — the repo's
    tuple-returning acquires put the table first)."""
    if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1 \
            or stmt.value is not acq:
        return None
    tgt = stmt.targets[0]
    if isinstance(tgt, ast.Tuple) and tgt.elts:
        tgt = tgt.elts[0]
    return tgt.id if isinstance(tgt, ast.Name) else None


def _propagated_acquires(an: Analysis) -> Set[str]:
    """Names of project functions that return a fresh acquire (bare, or
    bare inside the returned call's arguments) — their callers inherit
    the release obligation. Fixpoint, so a wrapper of a wrapper
    propagates too."""
    out: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for (file, qual), fi in an.graph.functions.items():
            if not file.startswith(RL008_PREFIX) or _is_provider(file):
                continue
            if fi.name in out:
                continue
            bound: Set[str] = set()
            for stmt in ast.walk(fi.node):
                if isinstance(stmt, ast.Assign):
                    acq = _acquire_call(stmt.value, out)
                    if acq is not None:
                        b = _bound_name(stmt, acq)
                        if b:
                            bound.add(b)
            if not bound:
                continue
            for stmt in ast.walk(fi.node):
                if isinstance(stmt, ast.Return) and stmt.value is not None \
                        and bare_names(stmt.value) & bound:
                    out.add(fi.name)
                    changed = True
                    break
    return out


def _names_in(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def _classify_discharge(an: Analysis, fi: FunctionInfo, stmt: ast.AST,
                        aliases: Set[str]) -> Optional[str]:
    """How ``stmt`` discharges the tracked resource: ``"always"`` (path
    absorbed), ``"normal"`` (fall-through only; exception edge stays
    live), or None."""
    exprs = header_exprs(stmt)
    if not exprs:
        return None

    # (a) release call taking the resource — absorbs
    for e in exprs:
        for n in ast.walk(e):
            if isinstance(n, ast.Call) and call_name(n) in RELEASES:
                args = list(n.args) + [kw.value for kw in n.keywords]
                if any(_names_in(a) & aliases for a in args):
                    return "always"

    # (e) rebind of the tracked name — tracking ends
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
        tgt = stmt.targets[0]
        if isinstance(tgt, ast.Name) and tgt.id in aliases \
                and not (_names_in(stmt.value) & aliases):
            return "always"

    # (c) returned/yielded — escapes only if the statement completes
    if isinstance(stmt, ast.Return) or (isinstance(stmt, ast.Expr)
                                        and isinstance(stmt.value,
                                                       ast.Yield)):
        val = stmt.value if isinstance(stmt, ast.Return) \
            else stmt.value.value
        if val is not None and _names_in(val) & aliases:
            return "normal"

    # (b) stored into self.* / a global container
    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        if stmt.value is not None and _names_in(stmt.value) & aliases:
            for t in targets:
                root = t
                while isinstance(root, (ast.Subscript, ast.Attribute)):
                    root = root.value
                if isinstance(t, (ast.Subscript, ast.Attribute)) \
                        and isinstance(root, ast.Name) \
                        and root.id == "self":
                    return "always"

    # (d) passed whole to a callee that keeps or releases it
    for e in exprs:
        if isinstance(stmt, ast.Return):
            break       # a raising return escaped nothing — (c) covers it
        for n in ast.walk(e):
            if not isinstance(n, ast.Call):
                continue
            cname = call_name(n)
            if cname is None:
                continue
            hit = any(isinstance(a, ast.Name) and a.id in aliases
                      for a in n.args) \
                or any(isinstance(kw.value, ast.Name)
                       and kw.value.id in aliases for kw in n.keywords)
            if not hit:
                continue
            base = dotted(n.func.value) \
                if isinstance(n.func, ast.Attribute) else None
            site = CallSite(cname, n.lineno, base, n)
            cands = an.graph.resolve_site(fi.file, fi.qualname, site)
            if not cands:
                return "always"         # unknown callee keeps it
            for c in cands:
                for i, a in enumerate(n.args):
                    if isinstance(a, ast.Name) and a.id in aliases:
                        cp = an._callee_param(c, i, None, base is not None)
                        if cp is not None and (
                                an.param_escapes(c, cp)
                                or an.param_released_by(c, cp, RELEASES)):
                            return "always"
                for kw in n.keywords:
                    if isinstance(kw.value, ast.Name) \
                            and kw.value.id in aliases and kw.arg:
                        cp = an._callee_param(c, -1, kw.arg,
                                              base is not None)
                        if cp is not None and (
                                an.param_escapes(c, cp)
                                or an.param_released_by(c, cp, RELEASES)):
                            return "always"
    return None


def _none_branch_skips(cfg, aliases: Set[str]) -> Dict[int, int]:
    """For each ``if`` on the resource's None-ness, the branch entry that
    is impossible while the resource is live (it was just acquired, so it
    is not None)."""
    skips: Dict[int, int] = {}
    for i, (body, orelse) in cfg.if_branches.items():
        test = cfg.stmts[i].test
        skip_none_arm = None
        if isinstance(test, ast.Compare) and len(test.ops) == 1 \
                and isinstance(test.comparators[0], ast.Constant) \
                and test.comparators[0].value is None \
                and isinstance(test.left, ast.Name) \
                and test.left.id in aliases:
            if isinstance(test.ops[0], ast.Is):
                skip_none_arm = body        # `if r is None:` body arm
            elif isinstance(test.ops[0], ast.IsNot):
                skip_none_arm = orelse      # `if r is not None:` else arm
        elif isinstance(test, ast.Name) and test.id in aliases:
            skip_none_arm = orelse          # `if r:` else arm
        elif isinstance(test, ast.UnaryOp) \
                and isinstance(test.op, ast.Not) \
                and isinstance(test.operand, ast.Name) \
                and test.operand.id in aliases:
            skip_none_arm = body            # `if not r:` body arm
        if skip_none_arm is not None:
            skips[i] = skip_none_arm
    return skips


@register("RL008", "resource acquire (alloc_prompt/fork/prepare_append/"
                   "claim_slot/reserve) not released or handed off on "
                   "every outgoing path, exception paths included")
def check_lifecycle(project: Project) -> List[Finding]:
    """Every refcounted acquire must be *dominated by* its release.

    Path mode walks the acquiring function's CFG (exception edges
    included) and demands a discharge — a ``free``/``abort_ticket``
    release, an escape into ``self.*``/a keeping callee, or a completed
    ``return`` carrying the resource — on every route out of the
    function. Acquire-returning wrappers (``start_prefill``) propagate
    the obligation to their callers through the call graph. Sequence
    mode requires ``commit_append`` lexically after ``prepare_append``
    in the same function; component mode requires the project to contain
    the paired release (``release_slot`` for ``claim_slot``, ``land``
    for ``reserve``) somewhere. Provider files implementing the pools
    are exempt."""
    an = analysis(project)
    findings: List[Finding] = []
    propagated = _propagated_acquires(an)

    # ---- path mode -------------------------------------------------------
    for (file, qual), fi in sorted(an.graph.functions.items()):
        if not file.startswith(RL008_PREFIX) or _is_provider(file):
            continue
        cfg = an.cfg(fi)
        for i, stmt in enumerate(cfg.stmts):
            acq = None
            for e in header_exprs(stmt):
                acq = _acquire_call(e, propagated)
                if acq is not None:
                    break
            if acq is None:
                continue
            bound = _bound_name(stmt, acq)
            if bound is None:
                continue    # result escapes immediately (returned/stored)
            aliases = alias_closure(fi.node, {bound})
            blocked_always: Set[int] = {i}
            blocked_normal: Set[int] = set()
            for j, other in enumerate(cfg.stmts):
                if j == i:
                    continue
                kind = _classify_discharge(an, fi, other, aliases)
                if kind == "always":
                    blocked_always.add(j)
                elif kind == "normal":
                    blocked_normal.add(j)
            term = reaches_terminal(
                cfg, set(cfg.succ_normal.get(i, ())), blocked_always,
                blocked_normal, _none_branch_skips(cfg, aliases))
            if term is not None:
                route = "an exception path" if term == RAISED \
                    else "a fall-through path"
                findings.append(Finding(
                    "RL008", file, acq.lineno,
                    f"'{call_name(acq)}' result '{bound}' may leak on "
                    f"{route}: no release (free/abort_ticket) or "
                    f"ownership hand-off dominates every exit", qual))

    # ---- sequence mode ---------------------------------------------------
    for (file, qual), sites in sorted(an.graph.call_sites.items()):
        if not file.startswith(RL008_PREFIX) or _is_provider(file):
            continue
        for s in sites:
            landing = SEQ_PAIRS.get(s.name)
            if landing is None:
                continue
            if not any(t.name == landing and t.line > s.line
                       for t in sites):
                findings.append(Finding(
                    "RL008", file, s.line,
                    f"'{s.name}' staged with no '{landing}' later in the "
                    f"same function: staged pool mutations never land",
                    qual))

    # ---- component mode --------------------------------------------------
    released: Set[str] = set()
    acq_sites: Dict[str, List[Tuple[str, str, int]]] = {}
    for (file, qual), sites in sorted(an.graph.call_sites.items()):
        if not file.startswith(RL008_PREFIX) or _is_provider(file):
            continue
        for s in sites:
            if s.name in COMPONENT_PAIRS:
                acq_sites.setdefault(s.name, []).append(
                    (file, qual, s.line))
            if s.name in COMPONENT_PAIRS.values():
                released.add(s.name)
    for acq_name, sites_ in sorted(acq_sites.items()):
        rel_name = COMPONENT_PAIRS[acq_name]
        if rel_name in released:
            continue
        for file, qual, line in sites_:
            findings.append(Finding(
                "RL008", file, line,
                f"'{acq_name}' is called but '{rel_name}' appears nowhere "
                f"in the project: acquired resources are never returned",
                qual))
    return findings
