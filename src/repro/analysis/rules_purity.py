"""RL004 — ``pure_callback`` targets must stay effect-free.

``jax.pure_callback`` tells XLA the callback is pure: the runtime may
cache it, re-invoke it (donation replays, multi-device broadcast) or
elide it entirely when the output is dead. A target that mutates
persistent state therefore double-counts, under-counts or silently
drops its writes. The host-executor lane holds the one sanctioned
exception: best-effort pool telemetry whose docstring already declares
it "a floor, not a ledger".
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .core import Finding, Project, Source, call_name, dotted, register

# The executor's sanctioned telemetry attributes (repro/hostexec/
# executor.py documents them as best-effort floors — pure_callback may
# legally re-invoke — with the exact counts living in the traced
# EngineStats channel). Writes to anything else inside a callback target
# are a correctness bug, not telemetry.
SANCTIONED_TELEMETRY = {"calls", "groups", "fused", "census_calls",
                        "census_threads", "affinity_hits", "_affinity",
                        "busy_ns", "queue_peak"}

HOSTEXEC_PREFIX = "src/repro/hostexec/"


@register("RL004", "pure_callback target writes non-telemetry persistent "
                   "state")
def rl004_callback_purity(project: Project) -> List[Finding]:
    """RL004: every function passed to ``jax.pure_callback`` from the
    ``hostexec`` package is located (method references like
    ``executor.compute_groups`` resolve by trailing name across the
    package) and its body — including nested worker functions — is
    checked for writes to persistent state: ``self.<attr>`` stores,
    ``global`` / ``nonlocal`` rebinding, and stores to module-level
    names. Writes to the executor's sanctioned pool-telemetry attributes
    are exempt; everything else is flagged. Local buffers (including
    closure-captured locals of the callback itself, like the output
    array worker threads fill) are fine — they die with the invocation."""
    findings: List[Finding] = []
    sources = project.under(HOSTEXEC_PREFIX)

    # 1) collect callback target names at pure_callback call sites
    target_names: Set[str] = set()
    for src in sources:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) \
                    and call_name(node) == "pure_callback" and node.args:
                tname = dotted(node.args[0])
                if tname is not None:
                    target_names.add(tname.rsplit(".", 1)[-1])

    if not target_names:
        return findings

    # 2) resolve each target function in the package and audit its writes
    for src in sources:
        module_globals = _module_names(src.tree)
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in target_names:
                findings.extend(_audit(src, node, module_globals))
    return findings


def _module_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            names.add(node.target.id)
    return names


def _bound_names(tgt: ast.AST):
    """Names a store target actually binds (plain ``x = ...`` and tuple
    unpacking). ``x[k] = ...`` / ``x.a = ...`` mutate an existing object
    and bind nothing — treating their root as local would mask writes to
    module globals."""
    if isinstance(tgt, ast.Name):
        yield tgt.id
    elif isinstance(tgt, ast.Starred):
        yield from _bound_names(tgt.value)
    elif isinstance(tgt, (ast.Tuple, ast.List)):
        for el in tgt.elts:
            yield from _bound_names(el)


def _audit(src: Source, func: ast.AST,
           module_globals: Set[str]) -> List[Finding]:
    findings: List[Finding] = []
    declared_global: Set[str] = set()
    # names bound locally anywhere in the callback (params, assignments):
    # stores to these are invocation-local, not persistent
    local_names = {a.arg for a in func.args.args + func.args.posonlyargs
                   + func.args.kwonlyargs}
    if func.args.vararg:
        local_names.add(func.args.vararg.arg)
    if func.args.kwarg:
        local_names.add(func.args.kwarg.arg)
    for node in ast.walk(func):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                local_names.update(_bound_names(tgt))
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign, ast.For)) \
                and isinstance(getattr(node, "target", None), ast.Name):
            local_names.add(node.target.id)

    def root_name(node: ast.AST) -> Optional[str]:
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return node.id if isinstance(node, ast.Name) else None

    def check_store(tgt: ast.AST, line: int) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                check_store(el, line)
            return
        root = root_name(tgt)
        if root == "self":
            # self.<attr>[...] / self.<attr> — attr is the persistence unit
            node = tgt
            attr = None
            while isinstance(node, (ast.Attribute, ast.Subscript)):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id == "self":
                    attr = node.attr
                node = node.value
            if attr is not None and attr not in SANCTIONED_TELEMETRY:
                findings.append(Finding(
                    "RL004", src.rel, line,
                    f"pure_callback target `{func.name}` writes "
                    f"`self.{attr}` — not sanctioned pool telemetry; "
                    f"pure_callback may re-invoke, cache or elide the "
                    f"call", symbol=func.name))
        elif root is not None and (
                root in declared_global
                or (root in module_globals and root not in local_names)):
            # G = v (under `global G`), G[k] = v, G.attr = v — persistent
            # module state either way
            findings.append(Finding(
                "RL004", src.rel, line,
                f"pure_callback target `{func.name}` writes module "
                f"global `{root}`", symbol=func.name))

    for node in ast.walk(func):
        if isinstance(node, ast.Global):
            declared_global.update(node.names)
            findings.append(Finding(
                "RL004", src.rel, node.lineno,
                f"pure_callback target `{func.name}` declares "
                f"`global {', '.join(node.names)}`", symbol=func.name))
        elif isinstance(node, ast.Nonlocal):
            findings.append(Finding(
                "RL004", src.rel, node.lineno,
                f"pure_callback target `{func.name}` declares "
                f"`nonlocal {', '.join(node.names)}`", symbol=func.name))
    for node in ast.walk(func):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                check_store(tgt, node.lineno)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            check_store(node.target, node.lineno)
    return findings
