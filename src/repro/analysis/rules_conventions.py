"""RL005/RL006 — repo landing conventions, machine-checked.

RL005 pins the kernel/jnp-twin convention: every Pallas kernel package
ships a ``ref.py`` pure-jnp twin and a test asserting bitwise parity
against it, so interpret-mode CI runs and TPU runs are guarded by the
same oracle. RL006 pins the stats/bench schema: the counters
``EngineStats``/``RunStats`` export and the benchmark ``record_run``
payloads must stay bit-for-bit in sync with the pins in
``tests/test_bench_schema.py`` — schema drift is how CI artifacts rot.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding, Project, Source, call_name, register

KERNELS_PREFIX = "src/repro/kernels/"
STATS_FILE = "src/repro/serving/stats.py"
SCHEMA_TEST = "tests/test_bench_schema.py"

# text markers that a module contains a Pallas kernel
_PALLAS_MARKERS = ("pallas_call", "from jax.experimental import pallas")
# text markers of a bitwise-parity assertion in a test
_BITWISE_MARKERS = ("array_equal",)


@register("RL005", "Pallas kernel package missing ref.py twin or bitwise "
                   "parity test", severity="warning")
def rl005_kernel_twin(project: Project) -> List[Finding]:
    """RL005: every ``src/repro/kernels/<pkg>/`` package containing a
    Pallas module (detected by ``pallas_call`` / pallas imports) must

    1. ship a ``ref.py`` pure-jnp twin in the same package, and
    2. be exercised by at least one test under ``tests/`` that imports
       the package's ``ref`` module AND asserts bitwise parity
       (``array_equal``) in the same file.

    This is the repo's kernel landing convention (every kernel since the
    decode-attention PR pairs with a replay twin); RL005 turns it from a
    review habit into a gate. The twin is what lets interpret-mode CI
    (no TPU) and device runs share one numerical oracle."""
    findings: List[Finding] = []
    pkgs: Dict[str, Source] = {}
    for src in project.under(KERNELS_PREFIX):
        parts = src.rel[len(KERNELS_PREFIX):].split("/")
        if len(parts) != 2:
            continue
        pkg, mod = parts
        if mod != "ref.py" and any(m in src.text for m in _PALLAS_MARKERS):
            pkgs.setdefault(pkg, src)

    for pkg, kernel_src in sorted(pkgs.items()):
        ref_rel = f"{KERNELS_PREFIX}{pkg}/ref.py"
        if project.get(ref_rel) is None and not project.exists(ref_rel):
            findings.append(Finding(
                "RL005", kernel_src.rel, 1,
                f"kernel package `{pkg}` has a Pallas module but no "
                f"ref.py jnp twin", symbol=pkg))
            continue
        if not _has_parity_test(project, pkg):
            findings.append(Finding(
                "RL005", kernel_src.rel, 1,
                f"kernel package `{pkg}` has no test importing its ref "
                f"twin and asserting bitwise parity (array_equal)",
                symbol=pkg))
    return findings


def _has_parity_test(project: Project, pkg: str) -> bool:
    want_mod = f"repro.kernels.{pkg}"
    for src in project.under("tests/"):
        if not any(m in src.text for m in _BITWISE_MARKERS):
            continue
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ImportFrom) or node.module is None:
                continue
            if node.module == want_mod \
                    and any(a.name == "ref" for a in node.names):
                return True
            if node.module == f"{want_mod}.ref":
                return True
    return False


@register("RL006", "stats/bench schema keys out of sync with "
                   "test_bench_schema.py pins", severity="warning")
def rl006_schema_drift(project: Project) -> List[Finding]:
    """RL006: three schema contracts, checked two-way.

    1. Every scalar field and derived-rate property of ``EngineStats``
       must appear in the ``ENGINE_KEYS`` pin of
       ``tests/test_bench_schema.py`` — a counter added to the stats
       without a pin ships unvalidated in every CI artifact.
    2. Same for ``RunStats`` fields against ``RUN_KEYS``.
    3. Vice versa: a pinned key with no backing field/property is a
       stale pin (the export would fail ``set(d) == ENGINE_KEYS``, but
       the lint catches it before the test suite boots jax).
    4. Every benchmark module calling ``record_run`` must be exercised
       by name in ``tests/test_bench_schema.py`` so its payload shape is
       validated against the pinned schema."""
    findings: List[Finding] = []
    stats_src = project.get(STATS_FILE)
    schema_src = project.get(SCHEMA_TEST)
    if stats_src is None or schema_src is None:
        return findings

    exported = _exported_keys(stats_src)
    pinned = _pinned_keys(schema_src)
    for cls, pin_name in (("EngineStats", "ENGINE_KEYS"),
                          ("RunStats", "RUN_KEYS")):
        if cls not in exported or pin_name not in pinned:
            continue
        keys, lines = exported[cls]
        pin_keys, pin_line = pinned[pin_name]
        for key in sorted(keys - pin_keys):
            findings.append(Finding(
                "RL006", stats_src.rel, lines.get(key, 1),
                f"{cls} exports `{key}` but {SCHEMA_TEST} {pin_name} "
                f"does not pin it", symbol=cls))
        for key in sorted(pin_keys - keys):
            findings.append(Finding(
                "RL006", schema_src.rel, pin_line,
                f"{pin_name} pins `{key}` but {cls} does not export it",
                symbol=pin_name))

    # benchmark record_run coverage
    for src in project.under("benchmarks/"):
        stem = src.rel.rsplit("/", 1)[-1][:-3]
        if stem in ("common", "__init__"):
            continue
        call_line = _first_record_run(src)
        if call_line is not None and stem not in schema_src.text:
            findings.append(Finding(
                "RL006", src.rel, call_line,
                f"benchmark `{stem}` calls record_run but "
                f"{SCHEMA_TEST} never exercises it", symbol=stem))
    return findings


def _exported_keys(src: Source
                   ) -> Dict[str, Tuple[Set[str], Dict[str, int]]]:
    """Per stats class: exported key names (scalar fields + @property
    derived rates) and the line each was declared on."""
    out: Dict[str, Tuple[Set[str], Dict[str, int]]] = {}
    for node in src.tree.body:
        if not isinstance(node, ast.ClassDef) \
                or node.name not in ("EngineStats", "RunStats"):
            continue
        keys: Set[str] = set()
        lines: Dict[str, int] = {}
        for item in node.body:
            if isinstance(item, ast.AnnAssign) \
                    and isinstance(item.target, ast.Name):
                keys.add(item.target.id)
                lines[item.target.id] = item.lineno
            elif isinstance(item, ast.FunctionDef) and any(
                    isinstance(d, ast.Name) and d.id == "property"
                    for d in item.decorator_list):
                keys.add(item.name)
                lines[item.name] = item.lineno
        out[node.name] = (keys, lines)
    return out


def _pinned_keys(src: Source) -> Dict[str, Tuple[Set[str], int]]:
    out: Dict[str, Tuple[Set[str], int]] = {}
    for node in src.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id in ("ENGINE_KEYS", "RUN_KEYS") \
                and isinstance(node.value, ast.Set):
            keys = {el.value for el in node.value.elts
                    if isinstance(el, ast.Constant)
                    and isinstance(el.value, str)}
            out[node.targets[0].id] = (keys, node.lineno)
    return out


def _first_record_run(src: Source) -> Optional[int]:
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Call) and call_name(node) == "record_run":
            return node.lineno
    return None
