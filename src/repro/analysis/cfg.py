"""Per-function control-flow graphs with exception edges.

RL008's "released on every outgoing path *including exception paths*"
needs more than a lexical scan: it needs to know that the statement after
a ``try:`` body is reachable both normally and through each handler, that
a ``finally:`` runs on the exceptional route out, and that a ``raise``
inside a handler leaves the function. This module builds exactly enough
CFG for that query and nothing more:

* nodes are **statements** (plus ``ExceptHandler`` markers); expressions
  never get their own node;
* a statement *may raise* iff it contains a ``Call`` (or is ``raise`` /
  ``assert``) — attribute access, arithmetic and subscripts are assumed
  total, which deliberately under-approximates Python's real exception
  surface: the repo's lifecycle bugs live on call boundaries, and taking
  every BINARY_OP edge would drown the rule in vacuous paths;
* ``finally`` bodies are **duplicated** — one copy on the normal route to
  the continuation, one on the exceptional route to the enclosing
  handler/exit — so a release inside ``finally`` discharges both routes
  without edge labels;
* two synthetic terminals: ``EXIT`` (fell off the end / ``return``) and
  ``RAISED`` (an exception left the function).

Handler matching is over-approximated by position: an exception edge from
a protected statement enters the *first* handler node, and each handler
node chains exceptionally to the next (or out of the ``try`` when the
last handler is not a catch-all) — "which handler matches" is a dynamic
type question a name-based analyzer refuses to guess.

The one deliberate piece of path-sensitivity lives in the traversal, not
the graph: :func:`reaches_terminal` takes a ``branch_skip`` map so a rule
can declare "on this ``if``'s else-branch the resource is known None" —
the idiom ``except: if table is not None: free(table); raise`` would
otherwise flag its own guard.
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["CFG", "EXIT", "RAISED", "build_cfg", "reaches_terminal",
           "header_exprs"]

EXIT = -1      # normal function exit
RAISED = -2    # exceptional function exit


def _may_raise(stmt: ast.AST) -> bool:
    if isinstance(stmt, (ast.Raise, ast.Assert)):
        return True
    for sub in ast.walk(stmt):
        if isinstance(sub, ast.Call):
            return True
    return False


def _catch_all(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:
        return True
    names = [handler.type] if not isinstance(handler.type, ast.Tuple) \
        else list(handler.type.elts)
    for n in names:
        tail = n.attr if isinstance(n, ast.Attribute) else \
            (n.id if isinstance(n, ast.Name) else "")
        if tail in ("BaseException", "Exception"):
            return True
    return False


class CFG:
    """One function's statement graph. ``stmts[i]`` is the AST node for
    node ``i`` (a statement or an ``ExceptHandler``); ``succ_normal`` /
    ``succ_exc`` hold fall-through vs may-raise successors (``EXIT`` /
    ``RAISED`` are terminal pseudo-ids)."""

    def __init__(self, func: ast.AST):
        self.func = func
        self.stmts: List[ast.AST] = []
        self.succ_normal: Dict[int, Set[int]] = {}
        self.succ_exc: Dict[int, Set[int]] = {}
        # if-statement node -> (body entry, orelse entry): lets a rule
        # prune a branch its predicate proves impossible (None-guards)
        self.if_branches: Dict[int, Tuple[int, int]] = {}
        self.entry: int = EXIT

    def _add(self, stmt: ast.AST) -> int:
        i = len(self.stmts)
        self.stmts.append(stmt)
        self.succ_normal[i] = set()
        self.succ_exc[i] = set()
        return i

    def succ(self, i: int) -> Set[int]:
        return self.succ_normal.get(i, set()) | self.succ_exc.get(i, set())

    def nodes_of(self, pred: Callable[[ast.AST], bool]) -> List[int]:
        """Node ids whose statement satisfies ``pred`` (a statement
        duplicated by ``finally`` modeling appears once per copy)."""
        return [i for i, s in enumerate(self.stmts) if pred(s)]


def build_cfg(func: ast.AST) -> CFG:
    """CFG for one ``FunctionDef`` body (nested defs become opaque
    single statements — their bodies don't run at definition time)."""
    cfg = CFG(func)

    def seq(body: Sequence[ast.stmt], follow: int, exc: int,
            brk: Optional[int], cont: Optional[int]) -> int:
        entry = follow
        for stmt in reversed(body):
            entry = one(stmt, entry, exc, brk, cont)
        return entry

    def one(stmt: ast.stmt, follow: int, exc: int,
            brk: Optional[int], cont: Optional[int]) -> int:
        if isinstance(stmt, ast.If):
            node = cfg._add(stmt)
            body = seq(stmt.body, follow, exc, brk, cont)
            orelse = seq(stmt.orelse, follow, exc, brk, cont)
            cfg.succ_normal[node] |= {body, orelse}
            cfg.if_branches[node] = (body, orelse)
            if _may_raise(stmt.test):
                cfg.succ_exc[node].add(exc)
            return node

        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            node = cfg._add(stmt)
            body = seq(stmt.body, node, exc, follow, node)
            cfg.succ_normal[node].add(body)
            infinite = (isinstance(stmt, ast.While)
                        and isinstance(stmt.test, ast.Constant)
                        and bool(stmt.test.value))
            if not infinite:
                # the zero-iteration / loop-exhausted edge (orelse bodies
                # are folded into it — the repo doesn't use for/else)
                cfg.succ_normal[node].add(
                    seq(stmt.orelse, follow, exc, brk, cont))
            header = stmt.test if isinstance(stmt, ast.While) else stmt.iter
            if _may_raise(header):
                cfg.succ_exc[node].add(exc)
            return node

        if isinstance(stmt, ast.Try):
            # exceptional continuation: through a duplicated finally copy
            # when one exists, else straight to the enclosing target
            f_exc = seq(stmt.finalbody, exc, exc, brk, cont) \
                if stmt.finalbody else exc
            f_norm = seq(stmt.finalbody, follow, exc, brk, cont) \
                if stmt.finalbody else follow
            # handler chain: body exceptions enter the first handler;
            # each handler may decline (exceptionally) to the next
            h_entry = f_exc
            for h in reversed(stmt.handlers):
                h_node = cfg._add(h)
                h_body = seq(h.body, f_norm, f_exc, brk, cont)
                cfg.succ_normal[h_node].add(h_body)
                if not _catch_all(h):
                    cfg.succ_exc[h_node].add(h_entry)
                h_entry = h_node
            orelse = seq(stmt.orelse, f_norm, f_exc, brk, cont)
            return seq(stmt.body, orelse, h_entry, brk, cont)

        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            node = cfg._add(stmt)
            cfg.succ_normal[node].add(seq(stmt.body, follow, exc, brk, cont))
            if any(_may_raise(it.context_expr) for it in stmt.items):
                cfg.succ_exc[node].add(exc)
            return node

        if isinstance(stmt, (ast.Return, ast.Yield)):
            node = cfg._add(stmt)
            cfg.succ_normal[node].add(EXIT)
            if _may_raise(stmt):
                cfg.succ_exc[node].add(exc)
            return node

        if isinstance(stmt, ast.Raise):
            node = cfg._add(stmt)
            cfg.succ_exc[node].add(exc)
            return node

        if isinstance(stmt, ast.Break):
            node = cfg._add(stmt)
            cfg.succ_normal[node].add(follow if brk is None else brk)
            return node

        if isinstance(stmt, ast.Continue):
            node = cfg._add(stmt)
            cfg.succ_normal[node].add(follow if cont is None else cont)
            return node

        if isinstance(stmt, ast.Match):
            node = cfg._add(stmt)
            for case in stmt.cases:
                cfg.succ_normal[node].add(
                    seq(case.body, follow, exc, brk, cont))
            cfg.succ_normal[node].add(follow)   # no case matched
            if _may_raise(stmt.subject):
                cfg.succ_exc[node].add(exc)
            return node

        # simple statement (incl. nested def/class, which don't execute
        # their bodies here): fall through, may-raise edge if it calls
        node = cfg._add(stmt)
        cfg.succ_normal[node].add(follow)
        if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and _may_raise(stmt):
            cfg.succ_exc[node].add(exc)
        return node

    cfg.entry = seq(func.body, EXIT, RAISED, None, None)
    return cfg


def reaches_terminal(cfg: CFG, start: Set[int],
                     blocked_always: Set[int],
                     blocked_normal: Optional[Set[int]] = None,
                     branch_skip: Optional[Dict[int, int]] = None
                     ) -> Optional[int]:
    """First terminal (``EXIT``/``RAISED``) reachable from ``start``
    without passing through a discharge node, or None.

    ``blocked_always`` nodes absorb completely (a release call: once
    reached, every continuation is safe). ``blocked_normal`` nodes
    absorb only their fall-through — their *exception* successors stay
    live, modeling "this statement hands the resource off only if it
    completes" (``return self._open_ticket(..., table, ...)`` raising
    mid-call has NOT escaped the table: that is PR 7's leak class).
    ``branch_skip`` maps an ``If`` node id to the one branch-entry id
    that must NOT be followed from it (the branch the caller's predicate
    analysis proved impossible, e.g. the ``table is None`` arm after a
    successful allocation)."""
    blocked_normal = blocked_normal or set()
    branch_skip = branch_skip or {}
    seen: Set[int] = set()
    work = list(start)
    while work:
        i = work.pop()
        if i in seen:
            continue
        seen.add(i)
        if i in (EXIT, RAISED):
            return i
        if i in blocked_always:
            continue
        if i in blocked_normal:
            nxt = set(cfg.succ_exc.get(i, ()))
        else:
            nxt = cfg.succ(i)
        skip = branch_skip.get(i)
        if skip is not None:
            nxt = nxt - {skip}
        work.extend(j for j in nxt if j not in seen)
    return None


def header_exprs(stmt: ast.AST) -> List[ast.AST]:
    """The expressions a *compound* statement evaluates itself, excluding
    its nested body (body statements are their own CFG nodes — scanning
    the whole ``If`` node would double-attribute everything inside it).
    Simple statements return themselves."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [it.context_expr for it in stmt.items]
    if isinstance(stmt, ast.Match):
        return [stmt.subject]
    if isinstance(stmt, (ast.Try, ast.ExceptHandler, ast.FunctionDef,
                         ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    return [stmt]
