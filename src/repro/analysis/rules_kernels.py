"""RL010 — Pallas kernel contracts: the arithmetic ``pallas_call``
enforces at trace time, checked statically (and a ragged-tail mask check
trace time cannot do at all).

RL005 guarantees every kernel package has a ``ref.py`` twin and a
bitwise parity test; RL010 extends "twin exists" to "contract matches".
For every ``pl.pallas_call`` (including via an assigned
``PrefetchScalarGridSpec``), with ``P = num_scalar_prefetch``:

1. every ``BlockSpec`` **index map** must take ``len(grid) + P``
   arguments (grid coordinates plus the prefetched scalar refs; specs
   without an index map — full-array/SMEM operands — are exempt);
2. the **kernel signature** must take ``P + len(in_specs) + n_out +
   len(scratch_shapes)`` positional parameters (resolved through
   ``functools.partial``);
3. the **call site** must pass ``P + len(in_specs)`` operands;
4. ``out_shape`` and ``out_specs`` must agree on the number of outputs;
5. a scalar-prefetch kernel walks indirection lists (CSR page tables)
   whose last grid axis is a *padded upper bound* — the kernel must
   compare against the last-axis ``pl.program_id`` (a ``<``/``>``-style
   bound feeding ``pl.when``/``jnp.where``) or the ragged tail is
   read unmasked;
6. each ``out_shape`` dtype written as a dotted expression
   (``q.dtype``, ``jnp.float32``) must appear in the package's
   ``ref.py`` — the twin must produce the same output dtype or the
   bitwise parity test is comparing casts.

Everything is best-effort static: a count that isn't syntactically
evident (computed grids, ``*specs`` splats) skips the check rather than
guessing.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import Dict, List, Optional, Tuple

from .core import Finding, Project, Source, call_name, dotted, register

RL010_MARKER = "pallas_call"


def _module_defs(tree: ast.AST) -> Dict[str, ast.AST]:
    return {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _positional_count(fn: ast.AST) -> Optional[int]:
    a = fn.args
    if a.vararg is not None:
        return None                     # *args: count not evident
    return len(a.posonlyargs) + len(a.args)


def _index_map_arity(spec: ast.Call,
                     defs: Dict[str, ast.AST]) -> Optional[int]:
    """Arg count of a BlockSpec's index map (None = no map / unknown)."""
    imap = None
    if len(spec.args) >= 2:
        imap = spec.args[1]
    for kw in spec.keywords:
        if kw.arg == "index_map":
            imap = kw.value
    if imap is None:
        return None
    if isinstance(imap, ast.Lambda):
        return len(imap.args.posonlyargs) + len(imap.args.args)
    if isinstance(imap, ast.Name) and imap.id in defs:
        return _positional_count(defs[imap.id])
    return None


def _spec_list(node: Optional[ast.AST]) -> Optional[List[ast.Call]]:
    """BlockSpec call list from an in_specs/out_specs expression."""
    if node is None:
        return None
    if isinstance(node, ast.Call):
        return [node]
    if isinstance(node, (ast.List, ast.Tuple)):
        out = []
        for el in node.elts:
            if not isinstance(el, ast.Call):
                return None
            out.append(el)
        return out
    return None


def _grid_len(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return 1
    return None


def _int_const(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _out_shape_entries(node: Optional[ast.AST]) -> Optional[List[ast.AST]]:
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


class _CallPlan:
    """Everything statically evident about one pallas_call."""

    def __init__(self):
        self.kernel: Optional[ast.AST] = None       # resolved def
        self.kernel_expr: Optional[ast.AST] = None
        self.grid_len: Optional[int] = None
        self.prefetch: int = 0
        self.in_specs: Optional[List[ast.Call]] = None
        self.out_specs: Optional[List[ast.Call]] = None
        self.out_shape: Optional[List[ast.AST]] = None
        self.scratch_n: Optional[int] = 0


def _resolve_local(name: str, func: ast.AST) -> Optional[ast.AST]:
    """Value of the most recent `name = <expr>` assignment in ``func``."""
    val = None
    for n in ast.walk(func):
        if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                and isinstance(n.targets[0], ast.Name) \
                and n.targets[0].id == name:
            val = n.value
    return val


def _plan(call: ast.Call, enclosing: ast.AST,
          defs: Dict[str, ast.AST]) -> _CallPlan:
    p = _CallPlan()
    kw = {k.arg: k.value for k in call.keywords if k.arg}

    spec_kw = kw
    gs = kw.get("grid_spec")
    if gs is not None:
        if isinstance(gs, ast.Name):
            gs = _resolve_local(gs.id, enclosing)
        if isinstance(gs, ast.Call):
            spec_kw = {k.arg: k.value for k in gs.keywords if k.arg}
        else:
            spec_kw = {}
    p.grid_len = _grid_len(spec_kw.get("grid"))
    p.prefetch = _int_const(spec_kw.get("num_scalar_prefetch")) or 0
    p.in_specs = _spec_list(spec_kw.get("in_specs"))
    p.out_specs = _spec_list(spec_kw.get("out_specs"))
    scratch = spec_kw.get("scratch_shapes")
    p.scratch_n = len(scratch.elts) \
        if isinstance(scratch, (ast.List, ast.Tuple)) else \
        (0 if scratch is None else None)
    p.out_shape = _out_shape_entries(kw.get("out_shape"))

    if call.args:
        k = call.args[0]
        p.kernel_expr = k
        if isinstance(k, ast.Call) and call_name(k) == "partial" \
                and k.args:
            k = k.args[0]
        if isinstance(k, ast.Name) and k.id in defs:
            p.kernel = defs[k.id]
    return p


def _ragged_masked(kernel: ast.AST, last_axis: int) -> bool:
    """Does the kernel bound-compare the last grid axis's program id?"""
    bound_names = set()
    for n in ast.walk(kernel):
        if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call) \
                and call_name(n.value) == "program_id" \
                and n.value.args \
                and _int_const(n.value.args[0]) == last_axis:
            for t in n.targets:
                if isinstance(t, ast.Name):
                    bound_names.add(t.id)
    for n in ast.walk(kernel):
        if not isinstance(n, ast.Compare):
            continue
        if not any(isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE))
                   for op in n.ops):
            continue
        for side in [n.left] + n.comparators:
            for sub in ast.walk(side):
                if isinstance(sub, ast.Name) and sub.id in bound_names:
                    return True
                if isinstance(sub, ast.Call) \
                        and call_name(sub) == "program_id" and sub.args \
                        and _int_const(sub.args[0]) == last_axis:
                    return True
    return False


def _wrapping_call(tree: ast.AST, inner: ast.Call) -> Optional[ast.Call]:
    """The ``pl.pallas_call(...)(operands)`` outer call, if present."""
    for n in ast.walk(tree):
        if isinstance(n, ast.Call) and n.func is inner:
            return n
    return None


@register("RL010", "Pallas kernel contract mismatch: index-map arity, "
                   "kernel/operand counts, out_shape vs out_specs or ref "
                   "twin dtype, or an unmasked ragged tail")
def check_kernel_contracts(project: Project) -> List[Finding]:
    """The grid/BlockSpec/scalar-prefetch arithmetic, statically.

    With ``P = num_scalar_prefetch``: index maps take ``len(grid) + P``
    args, the kernel takes ``P + len(in_specs) + n_out + n_scratch``
    positional params, the call site passes ``P + len(in_specs)``
    operands, ``out_shape`` matches ``out_specs``, scalar-prefetch
    kernels must bound-compare the last grid axis's ``program_id``
    (ragged-tail mask), and every dotted ``out_shape`` dtype must appear
    in the package's ``ref.py`` twin. Counts that aren't syntactically
    evident skip their check."""
    findings: List[Finding] = []
    for src in project.under("src/repro"):
        if RL010_MARKER not in src.text:
            continue
        defs = _module_defs(src.tree)
        ref_text = None
        pkg = PurePosixPath(src.rel).parent
        ref_rel = (pkg / "ref.py").as_posix()
        if project.exists(ref_rel) and src.rel != ref_rel:
            ref_src = project.get(ref_rel)
            ref_text = ref_src.text if ref_src is not None else \
                (project.root / ref_rel).read_text()

        for qual, fn in [(n.name, n) for n in ast.walk(src.tree)
                         if isinstance(n, ast.FunctionDef)]:
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call) \
                        or call_name(call) != "pallas_call":
                    continue
                p = _plan(call, fn, defs)
                line = call.lineno

                # 1. index-map arity
                if p.grid_len is not None:
                    want = p.grid_len + p.prefetch
                    for spec in (p.in_specs or []) + (p.out_specs or []):
                        got = _index_map_arity(spec, defs)
                        if got is not None and got != want:
                            findings.append(Finding(
                                "RL010", src.rel, spec.lineno,
                                f"BlockSpec index map takes {got} args "
                                f"but the grid has {p.grid_len} dims + "
                                f"{p.prefetch} scalar-prefetch refs "
                                f"(want {want})", qual))

                # 4. out_shape vs out_specs count
                n_out = None
                if p.out_specs is not None:
                    n_out = len(p.out_specs)
                    if p.out_shape is not None \
                            and len(p.out_shape) != n_out:
                        findings.append(Finding(
                            "RL010", src.rel, line,
                            f"out_shape declares {len(p.out_shape)} "
                            f"output(s) but out_specs declares {n_out}",
                            qual))
                elif p.out_shape is not None:
                    n_out = len(p.out_shape)

                # 2. kernel positional-parameter count
                if p.kernel is not None and n_out is not None \
                        and p.in_specs is not None \
                        and p.scratch_n is not None:
                    want = p.prefetch + len(p.in_specs) + n_out \
                        + p.scratch_n
                    got = _positional_count(p.kernel)
                    if got is not None and got != want:
                        findings.append(Finding(
                            "RL010", src.rel, line,
                            f"kernel '{p.kernel.name}' takes {got} "
                            f"positional refs but the specs provide "
                            f"{want} ({p.prefetch} prefetch + "
                            f"{len(p.in_specs)} in + {n_out} out + "
                            f"{p.scratch_n} scratch)", qual))

                # 3. call-site operand count
                outer = _wrapping_call(fn, call)
                if outer is not None and p.in_specs is not None \
                        and not any(isinstance(a, ast.Starred)
                                    for a in outer.args):
                    want = p.prefetch + len(p.in_specs)
                    if len(outer.args) != want:
                        findings.append(Finding(
                            "RL010", src.rel, outer.lineno,
                            f"pallas_call invoked with "
                            f"{len(outer.args)} operand(s) but the "
                            f"specs expect {want} ({p.prefetch} "
                            f"prefetch + {len(p.in_specs)} inputs)",
                            qual))

                # 5. ragged-tail mask for scalar-prefetch kernels
                if p.prefetch > 0 and p.grid_len is not None \
                        and p.kernel is not None \
                        and not _ragged_masked(p.kernel, p.grid_len - 1):
                    findings.append(Finding(
                        "RL010", src.rel, line,
                        f"scalar-prefetch kernel "
                        f"'{p.kernel.name}' never bound-compares "
                        f"program_id({p.grid_len - 1}): the padded last "
                        f"axis's ragged tail is read unmasked", qual))

                # 6. out_shape dtype vs the ref twin
                if ref_text is not None and p.out_shape is not None:
                    for entry in p.out_shape:
                        if not isinstance(entry, ast.Call):
                            continue
                        dt = entry.args[1] if len(entry.args) >= 2 \
                            else next((k.value for k in entry.keywords
                                       if k.arg == "dtype"), None)
                        d = dotted(dt) if dt is not None else None
                        if d is not None and d not in ref_text:
                            findings.append(Finding(
                                "RL010", src.rel, entry.lineno,
                                f"out_shape dtype '{d}' never appears "
                                f"in the package's ref.py twin: the "
                                f"bitwise parity test is comparing "
                                f"casts", qual))
    return findings
