"""Minimal SARIF 2.1.0 writer for reprolint findings.

SARIF is the interchange format code-scanning UIs ingest; emitting it
lets CI annotate PR diffs with findings instead of burying them in a log.
Only *new* findings are emitted (grandfathered ones are accepted debt,
not review feedback). The shape is the minimal valid subset:

* ``tool.driver.rules`` — one descriptor per registered rule, in id
  order; ``results[].ruleIndex`` points into it;
* ``results[].level`` — the rule's severity (``error``/``warning``/
  ``note``), reporting metadata only: CI fails on any new finding;
* ``partialFingerprints`` — the baseline's line-number-free identity,
  so scanning UIs track a finding across unrelated edits exactly like
  the baseline does.
"""
from __future__ import annotations

import json
from typing import Dict, List

from .core import Finding, RULES

__all__ = ["sarif_report"]

SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")
SARIF_VERSION = "2.1.0"


def sarif_report(findings: List[Finding]) -> Dict:
    """A SARIF 2.1.0 ``log`` dict for the given (new) findings."""
    rule_ids = sorted(RULES)
    index = {rid: i for i, rid in enumerate(rule_ids)}
    rules = [{
        "id": rid,
        "shortDescription": {"text": RULES[rid].summary},
        "defaultConfiguration": {"level": RULES[rid].severity},
    } for rid in rule_ids]

    results = []
    for f in sorted(findings, key=lambda f: (f.file, f.line, f.rule,
                                             f.message)):
        rule = RULES.get(f.rule)
        results.append({
            "ruleId": f.rule,
            "ruleIndex": index.get(f.rule, -1),
            "level": rule.severity if rule is not None else "error",
            "message": {"text": f.message},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {"uri": f.file},
                    "region": {"startLine": f.line},
                },
                "logicalLocations": ([{"name": f.symbol}]
                                     if f.symbol else []),
            }],
            "partialFingerprints": {
                "reprolintKey/v1": "\t".join(f.key()),
            },
        })

    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": "reprolint",
                "informationUri":
                    "src/repro/analysis/README.md",
                "rules": rules,
            }},
            "results": results,
        }],
    }


def write_sarif(path, findings: List[Finding]) -> None:
    path.write_text(json.dumps(sarif_report(findings), indent=2) + "\n")
