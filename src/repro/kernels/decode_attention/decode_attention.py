"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Decode attention at 32k-500k context is purely HBM-bandwidth-bound on the
KV cache stream. The kernel tiles the sequence axis; grid is

  (B, Hk, S/bs)   with the S axis innermost (sequential),

keeping per-(batch, kv-head) online-softmax state (m, l, acc) in VMEM
scratch across S steps — the classic flash-decode single-pass scheme. The
q block [group, hd] stays resident; each step streams one [bs, hd] K tile
and V tile through VMEM. Position/window masking is computed from the
grid coordinate with an iota, so arbitrary cache fill levels work.

Block choice: bs=512 rows of (hd=128) bf16 = 128 KiB per K/V tile; with
double buffering ~512 KiB VMEM — far under budget, and wide enough that
the HBM stream hits peak bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bs: int, n_s: int, window: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[pl.program_id(0)]
    q = q_ref[0, 0].astype(jnp.float32)              # [group, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [bs, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scale = q.shape[-1] ** -0.5
    s = jnp.dot(q * scale, k.T,
                preferred_element_type=jnp.float32)   # [group, bs]
    j = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = j <= pos
    if window > 0:
        valid &= j > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # [group, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
                 *, window: int = -1, bs: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q: [B, H, hd]; k/v: [B, S, Hk, hd]; pos: scalar or [B] int32 (a
    vector carries per-row cache fill levels — the serving engine's
    continuous batch decodes every slot at its own position) ->
    [B, H, hd]."""
    B, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    n_s = S // bs
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    qg = q.reshape(B, Hk, group, hd)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, bs=bs, n_s=n_s, window=window),
        grid=(B, Hk, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # pos
            pl.BlockSpec((1, 1, group, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos, qg, k, v)
    return out.reshape(B, H, hd)
