"""Jitted wrappers for the flash-decode kernels (dense and paged)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .decode_attention import flash_decode
from .paged import paged_flash_decode

INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window",))
def _decode_attention(q, k, v, pos, window):
    return flash_decode(q, k, v, pos, window=window, interpret=INTERPRET)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, window: int = -1) -> jax.Array:
    """q: [B, H, hd]; k/v: [B, S, Hk, hd]; pos: scalar int32 (one shared
    fill level) or [B] vector (per-row fill levels — what the serving
    engine's continuous batch passes). Any other rank is rejected here,
    at the op boundary, instead of surfacing as a reshape error inside
    the kernel."""
    pos = jnp.asarray(pos, jnp.int32)
    B = q.shape[0]
    if pos.ndim > 1:
        raise ValueError(
            f"pos must be a scalar or a [B] vector, got shape {pos.shape}")
    if pos.ndim == 1 and pos.shape[0] != B:
        raise ValueError(
            f"per-row pos length {pos.shape[0]} != batch {B}")
    return _decode_attention(q, k, v, jnp.broadcast_to(pos, (B,)), window)


@partial(jax.jit, static_argnames=("max_pages", "window"))
def _paged_decode_attention(q, k_pages, v_pages, page_indptr, page_indices,
                            last_page_len, max_pages, window):
    return paged_flash_decode(q, k_pages, v_pages, page_indptr,
                              page_indices, last_page_len,
                              max_pages=max_pages, window=window,
                              interpret=INTERPRET)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_indptr: jax.Array,
                           page_indices: jax.Array, last_page_len: jax.Array,
                           max_pages: int, window: int = -1) -> jax.Array:
    """q: [B, H, hd]; k_pages/v_pages: [num_pages, page_size, Hk, hd];
    page_indptr [B+1] / page_indices / last_page_len [B]: the serving
    pool's CSR page tables (every row >= 1 page); max_pages: static
    per-row page bound."""
    if page_indptr.shape[0] != q.shape[0] + 1:
        raise ValueError(
            f"page_indptr carries {page_indptr.shape[0] - 1} rows for a "
            f"batch of {q.shape[0]}")
    if last_page_len.shape[0] != q.shape[0]:
        raise ValueError(
            f"last_page_len carries {last_page_len.shape[0]} rows for a "
            f"batch of {q.shape[0]}")
    return _paged_decode_attention(q, k_pages, v_pages, page_indptr,
                                   page_indices, last_page_len,
                                   int(max_pages), window)
