"""Jitted wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .decode_attention import flash_decode

INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, window: int = -1) -> jax.Array:
    """q: [B, H, hd]; k/v: [B, S, Hk, hd]; pos: scalar int32."""
    return flash_decode(q, k, v, jnp.reshape(pos, (1,)), window=window,
                        interpret=INTERPRET)
