"""Pure-jnp oracles for flash-decode attention (dense and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array, window: int = -1) -> jax.Array:
    """One-token GQA attention over a KV cache.

    q: [B, H, hd]; k/v: [B, S, Hk, hd]; pos: scalar or [B] — row b's
    entries j <= pos_b are valid (the new token's kv is assumed already
    written at slot pos_b). window > 0 additionally masks
    j < pos - window + 1. Returns [B, H, hd].
    """
    B, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    qg = q.reshape(B, Hk, group, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    j = jnp.arange(S)
    valid = j[None, :] <= pos_b[:, None]              # [B, S]
    if window > 0:
        valid &= (pos_b[:, None] - j[None, :]) < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def paged_gather(k_pages: jax.Array, page_indptr, page_indices,
                 max_pages: int) -> jax.Array:
    """Gather each row's pages into a dense [B, max_pages*page_size, Hk,
    hd] cache (rows padded with page 0 — callers mask by length)."""
    indptr = np.asarray(page_indptr)
    indices = np.asarray(page_indices)
    B = len(indptr) - 1
    rows = []
    for b in range(B):
        ids = indices[indptr[b]:indptr[b + 1]]
        pad = np.zeros(max_pages - len(ids), ids.dtype)
        rows.append(jnp.concatenate(
            [k_pages[i] for i in np.concatenate([ids, pad])], axis=0))
    return jnp.stack(rows)


def paged_lengths(page_indptr, last_page_len, page_size: int) -> np.ndarray:
    """Valid token count per row from the CSR page table."""
    indptr = np.asarray(page_indptr)
    n_pages = indptr[1:] - indptr[:-1]
    return (n_pages - 1) * page_size + np.asarray(last_page_len)


def paged_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_indptr, page_indices, last_page_len, *,
                     max_pages: int, window: int = -1) -> jax.Array:
    """Reference twin of :func:`..paged.paged_flash_decode`.

    Replays the kernel's page-by-page online-softmax update with the
    SAME jnp ops on the SAME block shapes, in the same order, traced
    under one jit — the interpret-mode kernel's ops also execute inside
    its caller's jit, so the two compile identically and outputs match
    BITWISE (an eager per-op replay drifts in the last float32 ulp
    through different dot/transpose fusion). The page-table arrays are
    consumed as static host values; test-sized inputs only.
    """
    B, H, hd = q.shape
    page_size, Hk = k_pages.shape[1], k_pages.shape[2]
    group = H // Hk
    indptr = np.asarray(page_indptr)
    indices = np.asarray(page_indices)
    lastlen = np.asarray(last_page_len)
    scale = hd ** -0.5

    def replay(q, k_pages, v_pages):
        qg = q.reshape(B, Hk, group, hd)
        rows = []
        for b in range(B):
            n_pages = int(indptr[b + 1] - indptr[b])
            pos = (n_pages - 1) * page_size + int(lastlen[b]) - 1
            heads = []
            for h in range(Hk):
                qf = qg[b, h].astype(jnp.float32)
                m = jnp.full((group, 1), -1e30, jnp.float32)
                l = jnp.zeros((group, 1), jnp.float32)
                acc = jnp.zeros((group, hd), jnp.float32)
                for p_idx in range(max_pages):
                    i = min(indptr[b] + p_idx, indptr[b + 1] - 1)
                    k = k_pages[indices[i], :, h, :].astype(jnp.float32)
                    v = v_pages[indices[i], :, h, :].astype(jnp.float32)
                    s = jnp.dot(qf * scale, k.T,
                                preferred_element_type=jnp.float32)
                    j = p_idx * page_size + jax.lax.broadcasted_iota(
                        jnp.int32, s.shape, 1)
                    valid = (j <= pos) & (p_idx < n_pages)
                    if window > 0:
                        valid &= j > pos - window
                    s = jnp.where(valid, s, -1e30)
                    m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
                    p = jnp.exp(s - m_new)
                    alpha = jnp.exp(m - m_new)
                    l = l * alpha + p.sum(axis=-1, keepdims=True)
                    acc = acc * alpha + jnp.dot(
                        p, v, preferred_element_type=jnp.float32)
                    m = m_new
                heads.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
            rows.append(jnp.stack(heads))
        return jnp.stack(rows).reshape(B, H, hd)

    return jax.jit(replay)(q, k_pages, v_pages)
