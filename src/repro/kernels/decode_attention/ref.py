"""Pure-jnp oracle for flash-decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array, window: int = -1) -> jax.Array:
    """One-token GQA attention over a KV cache.

    q: [B, H, hd]; k/v: [B, S, Hk, hd]; pos: scalar — entries j <= pos are
    valid (the new token's kv is assumed already written at slot pos).
    window > 0 additionally masks j < pos - window + 1. Returns [B, H, hd].
    """
    B, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    qg = q.reshape(B, Hk, group, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    j = jnp.arange(S)
    valid = j <= pos
    if window > 0:
        valid &= j > pos - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
