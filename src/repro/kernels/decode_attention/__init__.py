from .decode_attention import flash_decode
from .paged import paged_flash_decode
from .ops import decode_attention, paged_decode_attention
from . import ref

__all__ = ["flash_decode", "paged_flash_decode", "decode_attention",
           "paged_decode_attention", "ref"]
