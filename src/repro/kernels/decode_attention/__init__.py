from .decode_attention import flash_decode
from .ops import decode_attention
from . import ref

__all__ = ["flash_decode", "decode_attention", "ref"]
