"""Pallas TPU paged flash-decode kernel: K/V gathered via a page table.

The dense kernel streams one contiguous ``[B, S, Hk, hd]`` cache; here
the cache is the serving engine's global paged pool ``[num_pages,
page_size, Hk, hd]`` and each batch row names its pages through the
flashinfer CSR layout (``page_indptr`` / ``page_indices`` /
``last_page_len``). The grid is

  (B, Hk, max_pages)   with the page axis innermost (sequential),

and the per-(batch, kv-head) online-softmax state (m, l, acc) lives in
VMEM scratch across page steps, exactly like the dense kernel. The page
indirection happens in the BlockSpec index maps: the CSR arrays ride the
grid as scalar-prefetch operands (``PrefetchScalarGridSpec``), so the
index map reads ``page_indices[page_indptr[b] + p]`` and the DMA engine
fetches each physical ``[page_size, hd]`` K/V tile straight from the
pool — no gathered copy of the row's KV is ever materialized. Rows
shorter than ``max_pages`` pages clamp to their last page and mask the
re-fetched tile; rows must hold at least one page.

``paged_decode_ref`` in ref.py replays the identical update order with
the same jnp ops, so interpret-mode outputs match it bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(indptr_ref, indices_ref, lastlen_ref,
                  q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, page_size: int, n_p: int, window: int):
    b = pl.program_id(0)
    p_idx = pl.program_id(2)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_pages = indptr_ref[b + 1] - indptr_ref[b]
    pos = (n_pages - 1) * page_size + lastlen_ref[b] - 1
    q = q_ref[0, 0].astype(jnp.float32)              # [group, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [page_size, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scale = q.shape[-1] ** -0.5
    s = jnp.dot(q * scale, k.T,
                preferred_element_type=jnp.float32)   # [group, page_size]
    j = p_idx * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (j <= pos) & (p_idx < n_pages)
    if window > 0:
        valid &= j > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # [group, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p_idx == n_p - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _kv_page_map(b, h, p, indptr, indices, lastlen):
    # Clamp past-the-end steps to the row's last page (masked in-kernel);
    # every row holds >= 1 page so indptr[b+1] - 1 >= indptr[b].
    i = jnp.minimum(indptr[b] + p, indptr[b + 1] - 1)
    return (indices[i], 0, h, 0)


def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       page_indptr: jax.Array, page_indices: jax.Array,
                       last_page_len: jax.Array, *, max_pages: int,
                       window: int = -1,
                       interpret: bool = False) -> jax.Array:
    """q: [B, H, hd]; k_pages/v_pages: [num_pages, page_size, Hk, hd];
    page_indptr: [B+1]; page_indices: [total_pages]; last_page_len: [B]
    (>= 1 — row b's valid length is ``(n_pages_b - 1) * page_size +
    last_page_len_b``, its final token sitting at position length-1);
    max_pages: static per-row page bound (the grid extent).
    Returns [B, H, hd]."""
    B, H, hd = q.shape
    page_size, Hk = k_pages.shape[1], k_pages.shape[2]
    group = H // Hk
    qg = q.reshape(B, Hk, group, hd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hk, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda b, h, p, ii, ix, ll: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd), _kv_page_map),
            pl.BlockSpec((1, page_size, 1, hd), _kv_page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b, h, p, ii, ix, ll: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, page_size=page_size,
                          n_p=max_pages, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, group, hd), q.dtype),
        interpret=interpret,
    )(page_indptr.astype(jnp.int32), page_indices.astype(jnp.int32),
      last_page_len.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
