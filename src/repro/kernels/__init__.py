# Pallas TPU kernels for the perf-critical compute layers, each with an
# ops.py jit wrapper and a ref.py pure-jnp oracle (validated in interpret
# mode on CPU; see tests/test_kernels_*.py):
#   moe_gmm/          grouped expert matmul + fused SwiGLU gate
#   decode_attention/ flash-decode over long KV caches
#   ssd_scan/         Mamba2 SSD chunked scan (state held in VMEM)
