"""Pallas TPU kernels: grouped expert matmul + fused SwiGLU gate.

These replace the one-hot/einsum dispatch math for the [E, C, D] capacity
buffer produced by repro.models.moe.sort_dispatch. MXU-oriented tiling:

  grid = (E, C/bm, F/bn, D/bk), K innermost so the fp32 accumulator tile
  stays resident in VMEM across K steps (revisiting the same out block).
  Tiles default to 128x128 (MXU native); the [bm,bk] + [bk,bn] + [bm,bn]
  working set is ~196 KiB ≪ 16 MiB VMEM, leaving headroom for the
  pipeline's double-buffered prefetch of the next K tile.

The fused variant reads the activation tile ONCE for both the w1 (gate)
and w3 (up) products — halving activation HBM reads for the first MoE
matmul pair (the dominant non-weight traffic in the expert FFN).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
        bk: int = 128, interpret: bool = False) -> jax.Array:
    """Grouped matmul x: [E, C, D] @ w: [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    _, _, F = w.shape
    bm, bn, bk = min(bm, C), min(bn, F), min(bk, D)
    assert C % bm == 0 and F % bn == 0 and D % bk == 0, (x.shape, w.shape)
    n_k = D // bk
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=n_k),
        grid=(E, C // bm, F // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, bk, bn), lambda e, m, n, k: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _swiglu_kernel(x_ref, w1_ref, w3_ref, o_ref, acc1_ref, acc3_ref,
                   *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc3_ref[...] = jnp.zeros_like(acc3_ref)

    xt = x_ref[0]
    acc1_ref[...] += jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    acc3_ref[...] += jnp.dot(xt, w3_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        a = acc1_ref[...]
        o_ref[0] = (a * jax.lax.logistic(a) * acc3_ref[...]).astype(o_ref.dtype)


def swiglu_gmm(x: jax.Array, w1: jax.Array, w3: jax.Array, *, bm: int = 128,
               bn: int = 128, bk: int = 128,
               interpret: bool = False) -> jax.Array:
    """Fused silu(x@w1) * (x@w3): [E, C, D] x [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    _, _, F = w1.shape
    bm, bn, bk = min(bm, C), min(bn, F), min(bk, D)
    assert C % bm == 0 and F % bn == 0 and D % bk == 0
    n_k = D // bk
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, n_k=n_k),
        grid=(E, C // bm, F // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, bk, bn), lambda e, m, n, k: (e, k, n)),
            pl.BlockSpec((1, bk, bn), lambda e, m, n, k: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3)
