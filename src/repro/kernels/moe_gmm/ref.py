"""Pure-jnp oracles for the grouped expert matmul kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped matmul: x [E, C, D] @ w [E, D, F] -> [E, C, F] (fp32 acc)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def swiglu_gmm_ref(x: jax.Array, w1: jax.Array, w3: jax.Array) -> jax.Array:
    """Fused gate: silu(x@w1) * (x@w3), grouped. [E,C,D]x[E,D,F] -> [E,C,F]."""
    a = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w1.astype(jnp.float32))
    b = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w3.astype(jnp.float32))
    return (jax.nn.silu(a) * b).astype(x.dtype)


def moe_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                w2: jax.Array) -> jax.Array:
    """Full grouped SwiGLU expert FFN. [E,C,D] -> [E,C,D]."""
    h = swiglu_gmm_ref(x, w1, w3)
    return gmm_ref(h, w2)
