"""Jitted public wrappers for the grouped-MoE kernels.

`moe_ffn` runs the full grouped SwiGLU expert FFN on the [E, C, D]
dispatch buffer: fused gate kernel + down-projection gmm. All dims are
padded to 128 multiples here (MXU tile), so callers never think about
tiling. On non-TPU backends (this container) interpret mode is used.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .moe_gmm import gmm, swiglu_gmm

INTERPRET = jax.default_backend() != "tpu"


def _pad128(x: jax.Array, *axes: int) -> jax.Array:
    widths = [(0, 0)] * x.ndim
    needed = False
    for ax in axes:
        pad = (-x.shape[ax]) % 128
        widths[ax] = (0, pad)
        needed = needed or pad
    return jnp.pad(x, widths) if needed else x


@jax.jit
def moe_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array,
            w2: jax.Array) -> jax.Array:
    """Grouped SwiGLU FFN: x [E, C, D] -> [E, C, D]."""
    E, C, D = x.shape
    xp = _pad128(x, 1, 2)
    h = swiglu_gmm(xp, _pad128(w1, 1, 2), _pad128(w3, 1, 2),
                   interpret=INTERPRET)
    y = gmm(h, _pad128(w2, 1, 2), interpret=INTERPRET)
    return y[:, :C, :D]


@jax.jit
def grouped_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Padded grouped matmul wrapper: [E, C, D] @ [E, D, F]."""
    _, C, _ = x.shape
    F = w.shape[-1]
    out = gmm(_pad128(x, 1, 2), _pad128(w, 1, 2), interpret=INTERPRET)
    return out[:, :C, :F]
