from .moe_gmm import gmm, swiglu_gmm
from .ops import grouped_matmul, moe_ffn
from . import ref

__all__ = ["gmm", "swiglu_gmm", "grouped_matmul", "moe_ffn", "ref"]
