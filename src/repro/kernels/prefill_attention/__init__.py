from .paged import paged_flash_prefill
from .ops import paged_prefill_attention
from . import ref

__all__ = ["paged_flash_prefill", "paged_prefill_attention", "ref"]
