"""Pure-jnp oracles for chunked-prefill attention over paged KV."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def prefill_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                          pos0: jax.Array, lengths: jax.Array,
                          window: int = -1) -> jax.Array:
    """Naive oracle: C-query GQA attention over a dense KV view.

    q: [B, C, H, hd]; k/v: [B, S, Hk, hd]; pos0: [B] — query row r of
    batch b sits at absolute position ``pos0[b] + r``; lengths: [B] —
    keys j < lengths[b] exist. Returns [B, C, H, hd].
    """
    B, C, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    pos0 = jnp.asarray(pos0, jnp.int32)
    qg = q.reshape(B, C, Hk, group, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bchgd,bkhd->bchgk", qg, k.astype(jnp.float32))
    j = jnp.arange(S)
    qpos = pos0[:, None] + jnp.arange(C)[None]        # [B, C]
    valid = j[None, None, :] <= qpos[:, :, None]      # [B, C, S]
    valid &= j[None, None, :] < jnp.asarray(lengths)[:, None, None]
    if window > 0:
        valid &= (qpos[:, :, None] - j[None, None, :]) < window
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bchgk,bkhd->bchgd", w, v.astype(jnp.float32))
    return o.reshape(B, C, H, hd).astype(q.dtype)


def paged_prefill_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      page_indptr, page_indices, last_page_len, pos0, *,
                      max_pages: int, window: int = -1) -> jax.Array:
    """Reference twin of :func:`..paged.paged_flash_prefill`.

    Replays the kernel's page-by-page online-softmax update with the
    SAME jnp ops on the SAME block shapes, in the same order, traced
    under one jit — so interpret-mode kernel outputs match BITWISE (an
    eager per-op replay drifts in the last float32 ulp through
    different dot/transpose fusion). The page-table arrays and pos0 are
    consumed as static host values; test-sized inputs only.
    """
    B, C, H, hd = q.shape
    page_size, Hk = k_pages.shape[1], k_pages.shape[2]
    group = H // Hk
    indptr = np.asarray(page_indptr)
    indices = np.asarray(page_indices)
    lastlen = np.asarray(last_page_len)
    pos0_np = np.asarray(pos0)
    scale = hd ** -0.5

    def replay(q, k_pages, v_pages):
        qg = q.reshape(B, C, Hk, group, hd).transpose(0, 2, 1, 3, 4)
        rows = []
        for b in range(B):
            n_pages = int(indptr[b + 1] - indptr[b])
            last = (n_pages - 1) * page_size + int(lastlen[b]) - 1
            heads = []
            for h in range(Hk):
                qf = qg[b, h].astype(jnp.float32).reshape(C * group, hd)
                m = jnp.full((C * group, 1), -1e30, jnp.float32)
                l = jnp.zeros((C * group, 1), jnp.float32)
                acc = jnp.zeros((C * group, hd), jnp.float32)
                for p_idx in range(max_pages):
                    i = min(indptr[b] + p_idx, indptr[b + 1] - 1)
                    k = k_pages[indices[i], :, h, :].astype(jnp.float32)
                    v = v_pages[indices[i], :, h, :].astype(jnp.float32)
                    s = jnp.dot(qf * scale, k.T,
                                preferred_element_type=jnp.float32)
                    j = p_idx * page_size + jax.lax.broadcasted_iota(
                        jnp.int32, s.shape, 1)
                    qpos = int(pos0_np[b]) + jax.lax.broadcasted_iota(
                        jnp.int32, s.shape, 0) // group
                    valid = (j <= qpos) & (j <= last) & (p_idx < n_pages)
                    if window > 0:
                        valid &= j > qpos - window
                    s = jnp.where(valid, s, -1e30)
                    m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
                    p = jnp.exp(s - m_new)
                    alpha = jnp.exp(m - m_new)
                    l = l * alpha + p.sum(axis=-1, keepdims=True)
                    acc = acc * alpha + jnp.dot(
                        p, v, preferred_element_type=jnp.float32)
                    m = m_new
                heads.append((acc / jnp.maximum(l, 1e-30)
                              ).reshape(C, group, hd).astype(q.dtype))
            rows.append(jnp.stack(heads))
        return jnp.stack(rows).transpose(0, 2, 1, 3, 4).reshape(B, C, H, hd)

    return jax.jit(replay)(q, k_pages, v_pages)
