"""Pallas TPU chunked-prefill attention: segment queries vs paged KV.

The serving engine streams a prompt forward as C-token *segments*
(repro.serving.engine, ``prefill_segment``): segment queries sit at
absolute positions ``pos0_b .. pos0_b + C - 1`` and attend every KV
token already in the request's pages — including the segment's own,
which the caller scatters into the pool before scoring. Like the paged
flash-decode kernel this streams the pool ``[num_pages, page_size, Hk,
hd]`` through the flashinfer CSR page table (``page_indptr`` /
``page_indices`` / ``last_page_len``) with the grid

  (B, Hk, max_pages)   page axis innermost (sequential),

scalar-prefetch page indirection in the BlockSpec index maps (the DMA
engine fetches each physical page tile straight from the pool — no
gathered per-row KV copy), and VMEM online-softmax state. The decode
kernel carries one query row; here the state is ``[C * group, ...]`` and
the causal mask is per query row: key ``j`` is visible to query row
``r`` iff ``j <= pos0_b + r // group`` and ``j`` is inside the row's
valid length. Query rows past the prompt (ragged last segment) see a
full causal window of real keys and produce well-defined junk the
caller discards.

``paged_prefill_ref`` in ref.py replays the identical update order with
the same jnp ops, so interpret-mode outputs match it bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(indptr_ref, indices_ref, lastlen_ref, pos0_ref,
                    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                    *, page_size: int, n_p: int, group: int, window: int):
    b = pl.program_id(0)
    p_idx = pl.program_id(2)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_pages = indptr_ref[b + 1] - indptr_ref[b]
    last = (n_pages - 1) * page_size + lastlen_ref[b] - 1
    pos0 = pos0_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)              # [C, group, hd]
    C, _, hd = q.shape
    qf = q.reshape(C * group, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [page_size, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scale = hd ** -0.5
    s = jnp.dot(qf * scale, k.T,
                preferred_element_type=jnp.float32)   # [C*group, page_size]
    j = p_idx * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    qpos = pos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
    valid = (j <= qpos) & (j <= last) & (p_idx < n_pages)
    if window > 0:
        valid &= j > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # [C*group, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p_idx == n_p - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).reshape(C, group, hd).astype(o_ref.dtype)


def _kv_page_map(b, h, p, indptr, indices, lastlen, pos0):
    # Clamp past-the-end steps to the row's last page (masked in-kernel);
    # every row holds >= 1 page so indptr[b+1] - 1 >= indptr[b].
    i = jnp.minimum(indptr[b] + p, indptr[b + 1] - 1)
    return (indices[i], 0, h, 0)


def paged_flash_prefill(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_indptr: jax.Array, page_indices: jax.Array,
                        last_page_len: jax.Array, pos0: jax.Array, *,
                        max_pages: int, window: int = -1,
                        interpret: bool = False) -> jax.Array:
    """q: [B, C, H, hd] — one C-token prompt segment per row, row b's
    first query at absolute position ``pos0[b]``; k_pages/v_pages:
    [num_pages, page_size, Hk, hd] (the segment's own KV already
    written); page_indptr: [B+1]; page_indices: [total_pages];
    last_page_len: [B] (>= 1); pos0: [B] int32; max_pages: static
    per-row page bound (the grid extent). Returns [B, C, H, hd]."""
    B, C, H, hd = q.shape
    page_size, Hk = k_pages.shape[1], k_pages.shape[2]
    group = H // Hk
    qg = q.reshape(B, C, Hk, group, hd).transpose(0, 2, 1, 3, 4)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, Hk, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, C, group, hd),
                         lambda b, h, p, ii, ix, ll, p0: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd), _kv_page_map),
            pl.BlockSpec((1, page_size, 1, hd), _kv_page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, C, group, hd),
                               lambda b, h, p, ii, ix, ll, p0: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * group, 1), jnp.float32),
            pltpu.VMEM((C * group, 1), jnp.float32),
            pltpu.VMEM((C * group, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, page_size=page_size,
                          n_p=max_pages, group=group, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, C, group, hd), q.dtype),
        interpret=interpret,
    )(page_indptr.astype(jnp.int32), page_indices.astype(jnp.int32),
      last_page_len.astype(jnp.int32), pos0.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, H, hd)
