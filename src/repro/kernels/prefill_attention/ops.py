"""Jitted wrapper for the chunked-prefill paged-attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .paged import paged_flash_prefill

INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("max_pages", "window"))
def _paged_prefill_attention(q, k_pages, v_pages, page_indptr, page_indices,
                             last_page_len, pos0, max_pages, window):
    return paged_flash_prefill(q, k_pages, v_pages, page_indptr,
                               page_indices, last_page_len, pos0,
                               max_pages=max_pages, window=window,
                               interpret=INTERPRET)


def paged_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, page_indptr: jax.Array,
                            page_indices: jax.Array, last_page_len: jax.Array,
                            pos0: jax.Array, max_pages: int,
                            window: int = -1) -> jax.Array:
    """q: [B, C, H, hd] — one C-token prompt segment per batch row, row
    b's first query at absolute position ``pos0[b]`` (scalar pos0
    broadcasts); k_pages/v_pages: [num_pages, page_size, Hk, hd] with
    the segment's own KV already written; page_indptr [B+1] /
    page_indices / last_page_len [B]: the serving pool's CSR page
    tables (every row >= 1 page); max_pages: static per-row page bound.
    Returns [B, C, H, hd]."""
    B = q.shape[0]
    pos0 = jnp.asarray(pos0, jnp.int32)
    if pos0.ndim > 1:
        raise ValueError(
            f"pos0 must be a scalar or a [B] vector, got shape {pos0.shape}")
    if pos0.ndim == 1 and pos0.shape[0] != B:
        raise ValueError(
            f"per-row pos0 length {pos0.shape[0]} != batch {B}")
    if page_indptr.shape[0] != B + 1:
        raise ValueError(
            f"page_indptr carries {page_indptr.shape[0] - 1} rows for a "
            f"batch of {B}")
    if last_page_len.shape[0] != B:
        raise ValueError(
            f"last_page_len carries {last_page_len.shape[0]} rows for a "
            f"batch of {B}")
    return _paged_prefill_attention(q, k_pages, v_pages, page_indptr,
                                    page_indices, last_page_len,
                                    jnp.broadcast_to(pos0, (B,)),
                                    int(max_pages), window)
