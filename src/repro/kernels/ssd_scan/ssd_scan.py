"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

Fuses, per (batch, head) and per chunk:
  * the intra-chunk quadratic term (scores ∘ decay) @ x  — MXU matmuls,
  * the inter-chunk state contribution C @ h,
  * the state update h' = exp(A_chunk) h + (B ∘ decay)^T x,

with the running state h [ds, hp] held in VMEM scratch across the chunk
grid axis — the recurrence never round-trips HBM, which is the entire
point: the XLA fallback carries h through a lax.scan whose per-chunk
state store/load dominates the layer's HBM traffic at long sequence.

Grid: (B*nh, S/Q) with the chunk axis innermost/sequential. Blocks:
a [1,Q], x [1,Q,hp], Bm/Cm [1,Q,ds] stream per chunk; scratch h is
[ds, hp] f32 (128x64 = 32 KiB — negligible VMEM).

Alignment: Q (chunk) is 128-multiple; hp=64 and ds=128 are the mamba2
defaults and MXU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
                *, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0, 0].astype(jnp.float32)              # [Q]
    x = x_ref[0, 0].astype(jnp.float32)              # [Q, hp]
    Bm = b_ref[0, 0].astype(jnp.float32)             # [Q, ds]
    Cm = c_ref[0, 0].astype(jnp.float32)             # [Q, ds]
    Q = a.shape[0]

    a_cs = jnp.cumsum(a)                             # [Q]
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    diff = a_cs[:, None] - a_cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    y = jnp.dot(scores * L, x, preferred_element_type=jnp.float32)
    h = h_ref[...]
    y = y + jnp.exp(a_cs)[:, None] * jnp.dot(
        Cm, h, preferred_element_type=jnp.float32)

    decay_end = jnp.exp(a_cs[-1] - a_cs)             # [Q]
    h_ref[...] = jnp.exp(a_cs[-1]) * h + jnp.dot(
        (Bm * decay_end[:, None]).T, x, preferred_element_type=jnp.float32)

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _flush():
        hout_ref[0] = h_ref[...]


def ssd_scan(a: jax.Array, x: jax.Array, Bm: jax.Array, Cm: jax.Array,
             *, chunk: int = 128, interpret: bool = False):
    """a: [G, S] log-decays; x: [G, S, hp] (dt-scaled); Bm/Cm: [G, S, ds]
    with G = batch*heads folded. Returns (y [G, S, hp], h [G, ds, hp])."""
    G, S = a.shape
    hp, ds = x.shape[-1], Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    n = S // Q
    ac = a.reshape(G, n, Q)
    xc = x.reshape(G, n, Q, hp)
    bc = Bm.reshape(G, n, Q, ds)
    cc = Cm.reshape(G, n, Q, ds)
    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n),
        grid=(G, n),
        in_specs=[
            pl.BlockSpec((1, 1, Q), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, 1, Q, hp), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda g, c: (g, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hp), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, ds, hp), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, n, Q, hp), x.dtype),
            jax.ShapeDtypeStruct((G, ds, hp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ds, hp), jnp.float32)],
        interpret=interpret,
    )(ac, xc, bc, cc)
    return y.reshape(G, S, hp), h
