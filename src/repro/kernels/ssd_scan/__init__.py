from .ssd_scan import ssd_scan
from .ops import ssd_chunked_kernel
from . import ref

__all__ = ["ssd_scan", "ssd_chunked_kernel", "ref"]
