"""Pure-jnp oracle for the SSD intra-chunk kernel (one chunk)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(a_cs: jax.Array, x: jax.Array, B: jax.Array, C: jax.Array,
                  h_in: jax.Array):
    """One SSD chunk, one head group.

    a_cs: [Q] cumulative log-decay; x: [Q, hp] (already dt-scaled);
    B, C: [Q, ds]; h_in: [ds, hp] incoming state.
    Returns (y [Q, hp], h_out [ds, hp]).
    """
    Q = a_cs.shape[0]
    scores = (C @ B.T).astype(jnp.float32)                     # [Q, Q]
    diff = a_cs[:, None] - a_cs[None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    y = (scores * L) @ x.astype(jnp.float32)                   # intra
    y = y + jnp.exp(a_cs)[:, None] * (C.astype(jnp.float32) @
                                      h_in.astype(jnp.float32))
    decay_end = jnp.exp(a_cs[-1] - a_cs)
    h_out = jnp.exp(a_cs[-1]) * h_in.astype(jnp.float32) + \
        (B * decay_end[:, None]).astype(jnp.float32).T @ x.astype(jnp.float32)
    return y.astype(x.dtype), h_out.astype(jnp.float32)


def ssd_multi_chunk_ref(a: jax.Array, x: jax.Array, B: jax.Array,
                        C: jax.Array, h0: jax.Array):
    """Sequential chunks for a single head: a [Nc, Q], x [Nc, Q, hp],
    B/C [Nc, Q, ds], h0 [ds, hp] -> (y [Nc, Q, hp], h [ds, hp])."""
    h = h0
    ys = []
    for c in range(a.shape[0]):
        a_cs = jnp.cumsum(a[c])
        y, h = ssd_chunk_ref(a_cs, x[c], B[c], C[c], h)
        ys.append(y)
    return jnp.stack(ys), h
