"""Jitted wrapper: model-layout adapter for the SSD Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan

INTERPRET = jax.default_backend() != "tpu"


@jax.jit
def ssd_chunked_kernel(x: jax.Array, dt: jax.Array, A_log: jax.Array,
                       Bmat: jax.Array, Cmat: jax.Array):
    """Same contract as repro.models.ssm.ssd_chunked (zero init state).

    x: [B, S, nh, hp]; dt: [B, S, nh]; A_log: [nh]; B/C: [B, S, ds].
    Returns (y [B, S, nh, hp], h [B, nh, ds, hp]).
    """
    Bb, S, nh, hp = x.shape
    ds = Bmat.shape[-1]
    a = (-jnp.exp(A_log.astype(jnp.float32)) * dt)          # [B, S, nh]
    xd = (x.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    # fold (batch, head) -> G; broadcast B/C across heads
    aG = a.transpose(0, 2, 1).reshape(Bb * nh, S)
    xG = xd.transpose(0, 2, 1, 3).reshape(Bb * nh, S, hp)
    bG = jnp.broadcast_to(Bmat[:, None], (Bb, nh, S, ds)).reshape(
        Bb * nh, S, ds).astype(x.dtype)
    cG = jnp.broadcast_to(Cmat[:, None], (Bb, nh, S, ds)).reshape(
        Bb * nh, S, ds).astype(x.dtype)

    y, h = ssd_scan(aG, xG, bG, cG, interpret=INTERPRET)
    y = y.reshape(Bb, nh, S, hp).transpose(0, 2, 1, 3)
    return y.astype(x.dtype), h.reshape(Bb, nh, ds, hp)
