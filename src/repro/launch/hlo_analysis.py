"""Static cost analysis over optimized HLO text, with correct while-loop
trip-count multipliers.

XLA's built-in HloCostAnalysis counts each while-loop body ONCE (verified
empirically: an 8-iteration lax.scan reports exactly 1/8 the flops of its
unrolled twin). Our models scan over up to 80 layers, so every roofline
number would be 1-2 orders of magnitude off. This module parses the
post-optimization HLO, builds the call graph, extracts loop trip counts
from while-condition compares, and multiplies.

Counted:
  flops       — dot: 2 * prod(result_dims) * K (K from lhs contracting
                dims); elementwise/reduce float ops: 1 per output element.
  bytes       — per instruction: operand + result bytes, at fusion
                granularity (fusion-internal instructions contribute flops
                but not bytes — approximating post-fusion HBM traffic).
  collectives — per-device ring wire bytes by kind.

This is a structural estimate: good to ~10-20% on dot-dominated programs,
which is what a roofline needs.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*((?:\([^=]*\)|\S+)\s+)?([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "cosine",
    "sine", "logistic", "expm1", "log1p", "atan2", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "remainder",
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "ragged-all-to-all", "collective-permute")


def _shape_elems_bytes(seg: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * DTYPE_BYTES[dt]
    return elems, bytes_


@dataclass
class Instr:
    name: str
    opcode: str
    result_seg: str          # text left of opcode (result types)
    rest: str                # text from opcode on (operands + attrs)
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def parse_hlo(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in hlo.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if not line:
            continue
        stripped = line.strip()
        is_header = (stripped.endswith("{") and "->" in stripped
                     and "=" not in stripped.split("->")[0])
        if is_header:
            hdr = _COMP_HDR_RE.match(stripped)
            if hdr:
                cur = Computation(hdr.group(1))
                comps[cur.name] = cur
                # header-declared parameters become pseudo-instructions so
                # dot-operand shape lookups work
                for pname, ptype in _PARAM_RE.findall(stripped.split("->")[0]):
                    ins = Instr(name=pname, opcode="parameter",
                                result_seg=ptype, rest="", line=stripped)
                    cur.by_name[pname] = ins
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.match(rhs)
        if not om:
            continue
        opcode = om.group(2)
        idx = rhs.find(opcode + "(")
        instr = Instr(name=name, opcode=opcode, result_seg=rhs[:idx],
                      rest=rhs[idx:], line=line)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    return comps


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    """Trip count from the while condition.

    Canonical form is `compare(iv, K), direction=LT`, but the compare is
    often wrapped in a fusion with K passed as an argument, so the robust
    extraction is: the largest integer constant in the condition
    computation (the loop bound; other condition constants are rare).
    """
    best = 1
    for ins in cond.instrs:
        m = re.match(r"constant\((-?\d+)\)", ins.rest)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation, params_shapes: Dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(ins.result_seg)
    cm = _CONTRACT_RE.search(ins.rest)
    k = 1
    ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
    if cm and ops:
        lhs = ops[0]
        seg = None
        if lhs in comp.by_name:
            seg = comp.by_name[lhs].result_seg
        elif lhs in params_shapes:
            seg = params_shapes[lhs]
        if seg:
            dims_m = _SHAPE_RE.search(seg)
            if dims_m and dims_m.group(2):
                dims = [int(d) for d in dims_m.group(2).split(",")]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
    return 2.0 * res_elems * k


def _param_shapes(hlo_comp_header_line: str) -> Dict[str, str]:
    return {}


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def add(self, other: "Costs", mult: float):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            rec = self.coll.setdefault(k, {"count": 0.0, "wire_bytes": 0.0})
            rec["count"] += v["count"] * mult
            rec["wire_bytes"] += v["wire_bytes"] * mult


def analyze(hlo: str) -> Costs:
    comps = parse_hlo(hlo)
    fusion_bodies = set()
    small_called = set()        # reducers, comparators, scatter combiners
    for comp in comps.values():
        for ins in comp.instrs:
            callee = _attr(ins.rest, "calls")
            if ins.opcode == "fusion" and callee:
                fusion_bodies.add(callee)
            elif ins.opcode in ("reduce", "reduce-window", "sort", "map",
                                "scatter", "select-and-scatter",
                                "all-reduce", "reduce-scatter") :
                m = re.search(r"(?:to_apply|called_computations)=%?([\w.\-]+)",
                              ins.rest)
                if m:
                    small_called.add(m.group(1))

    # param shapes per computation (for dot lhs lookup): parse from header
    # lines is brittle; instead map parameter instrs already in by_name.
    local: Dict[str, Costs] = {}
    for comp in comps.values():
        c = Costs()
        count_bytes = comp.name not in fusion_bodies
        for ins in comp.instrs:
            if ins.opcode in ("parameter", "constant", "iota", "tuple",
                              "get-tuple-element", "bitcast", "while",
                              "conditional", "call", "fusion"):
                flops = 0.0
            elif ins.opcode == "dot" or ins.opcode == "convolution":
                flops = _dot_flops(ins, comp, {})
            elif ins.opcode in ELEMENTWISE_FLOPS or ins.opcode in (
                    "reduce", "compare", "select", "clamp"):
                flops = float(_shape_elems_bytes(ins.result_seg)[0])
                if ins.opcode == "reduce":
                    # flops ~ number of reduced input elements
                    ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
                    if ops and ops[0] in comp.by_name:
                        flops = float(_shape_elems_bytes(
                            comp.by_name[ops[0]].result_seg)[0])
            else:
                flops = 0.0
            c.flops += flops

            if count_bytes and ins.opcode not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional", "call"):
                _, rb = _shape_elems_bytes(ins.result_seg)
                ob = 0
                ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
                for o in ops:
                    if o in comp.by_name:
                        ob += _shape_elems_bytes(comp.by_name[o].result_seg)[1]
                c.bytes += rb + ob

            for kind in COLLECTIVES:
                if ins.opcode in (kind, kind + "-start"):
                    _, rbytes = _shape_elems_bytes(ins.result_seg)
                    g = 1
                    gm = _GROUPS_RE.search(ins.rest)
                    if gm:
                        g = len(gm.group(1).split(","))
                    else:
                        im = _IOTA_RE.search(ins.rest)
                        if im:
                            g = int(im.group(2))
                    if g <= 1:
                        continue
                    if kind == "all-reduce":
                        wire = 2 * rbytes * (g - 1) / g
                    elif kind == "all-gather":
                        wire = rbytes * (g - 1) / g
                    elif kind == "reduce-scatter":
                        wire = rbytes * (g - 1)
                    elif kind in ("all-to-all", "ragged-all-to-all"):
                        wire = rbytes * (g - 1) / g
                    else:
                        wire = rbytes
                    rec = c.coll.setdefault(kind, {"count": 0.0,
                                                   "wire_bytes": 0.0})
                    rec["count"] += 1
                    rec["wire_bytes"] += wire
        local[comp.name] = c

    # Roll up the call graph from ENTRY with multipliers.
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(local, key=lambda n: local[n].flops)

    total = Costs()
    seen_depth = [0]

    def roll(comp_name: str, mult: float):
        if seen_depth[0] > 200:
            return
        seen_depth[0] += 1
        comp = comps[comp_name]
        total.add(local[comp_name], mult)
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = _attr(ins.rest, "body")
                cond = _attr(ins.rest, "condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    roll(body, mult * trips)
                if cond in comps:
                    roll(cond, mult * (trips + 1))
            elif ins.opcode in ("call", "conditional"):
                callee = _attr(ins.rest, "to_apply") or _attr(ins.rest, "calls")
                if callee in comps:
                    roll(callee, mult)
            elif ins.opcode == "fusion":
                callee = _attr(ins.rest, "calls")
                if callee in comps:
                    roll(callee, mult)
            elif ins.opcode.endswith("-start") or ins.opcode in COLLECTIVES:
                callee = _attr(ins.rest, "to_apply")
                # reducer flops negligible; skip
        seen_depth[0] -= 1

    roll(entry, 1.0)
    return total
