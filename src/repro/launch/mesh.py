"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); 2 pods adds a leading DCN
    "pod" axis that only batch/gradient traffic crosses."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for smoke tests / local runs."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
