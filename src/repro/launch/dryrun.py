import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the right step function against abstract,
sharding-annotated inputs, compiles it, and records:
  * memory_analysis()  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * collective traffic — parsed from the optimized HLO, per-device wire
    bytes per collective kind (ring-cost convention)
  * the three roofline terms vs TPU v5e peaks + MODEL_FLOPS ratio

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2-72b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file cells.txt]

Results append to results/dryrun/<arch>__<shape>__<mesh>.json. `--all`
spawns one subprocess per cell (isolation against compiler OOM/crash).
"""
import argparse
import json
import math
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Gradient-accumulation per arch for train_4k (batch 256): activation-heavy
# cells that exceed 16 GB/device at full batch — the standard production
# lever. Values chosen from the measured per-device activation footprints.
TRAIN_MICROBATCHES = {
    "llama4-maverick-400b-a17b": 8,
    "jamba-v0.1-52b": 16,
    "gemma3-4b": 2,
    "qwen2-72b": 2,
}

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(.*?\)|\S+)\s*(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute|ragged-all-to-all)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _types_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(segment):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Per-device wire bytes by collective kind (ring convention)."""
    out = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line.split("=")[0]:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(1))[0]
        result_bytes = _types_bytes(lhs)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            im = _IOTA_RE.search(line)
            if im:
                g = int(im.group(2))
        if g <= 1:
            continue
        if kind == "all-reduce":
            wire = 2 * result_bytes * (g - 1) / g
        elif kind == "all-gather":
            wire = result_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = result_bytes * (g - 1)          # result is the shard
        elif kind in ("all-to-all", "ragged-all-to-all"):
            wire = result_bytes * (g - 1) / g
        else:                                       # collective-permute
            wire = result_bytes
        rec = out.setdefault(kind, {"count": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["wire_bytes"] += wire
    return out


def _shape_census(hlo: str):
    import collections
    sizes = collections.Counter()
    for m in _TYPE_RE.finditer(hlo):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES or not dims:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        sizes[(dt, dims)] = n * DTYPE_BYTES[dt]
    return sizes


def _f32_normalization_bytes(hlo: str) -> int:
    """Bytes of f32 tensors that have an identically-shaped bf16 twin —
    the signature of XLA:CPU's bf16 emulation copies (>=256 MiB only)."""
    sizes = _shape_census(hlo)
    total = 0
    for (dt, dims), b in sizes.items():
        if dt == "f32" and b >= 2 ** 28 and ("bf16", dims) in sizes:
            total += b
    return total


def _largest_tensors(hlo: str, top: int = 8):
    sizes = _shape_census(hlo)
    out = []
    for (dt, dims), b in sorted(sizes.items(), key=lambda kv: -kv[1])[:top]:
        out.append({"type": f"{dt}[{dims}]", "gib": round(b / 2 ** 30, 3)})
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.config import SHAPES, OptimizerConfig, get_config
    from repro.core.costmodel import (TPU_HBM_BW, TPU_ICI_BW_PER_LINK,
                                      TPU_PEAK_FLOPS_BF16)
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as model_lib
    from repro.optim import make_train_step
    from repro.sharding import set_current_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    res = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "ok": False}

    if shape.name == "long_500k" and not cfg.sub_quadratic:
        res["skipped"] = "long-context cell on a full-attention arch (DESIGN.md)"
        res["ok"] = True      # a noted skip, not a failure
        return res

    set_current_mesh(mesh)
    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            # TP-only bf16 weights must fit HBM next to activations;
            # past ~12 GB/device switch the compute weights to FSDP
            # (gathered per scanned layer group) — llama4-400B territory.
            tp = mesh.shape.get("model", 1)
            # TP-only bf16 weights + transient grads both scale with this;
            # past ~3 GB/device FSDP the compute weights (gathered per
            # scanned group) so weight+grad residency stays O(P/chips).
            profile = "serve" if cfg.param_count() * 2 / tp > 3e9 else "train"
            res["param_profile"] = profile
            params = S.abstract_params(cfg, mesh, profile)[0]
            opt = S.abstract_opt_state(cfg, mesh, params)
            batch = S.batch_specs(cfg, shape, mesh, "train")
            micro = TRAIN_MICROBATCHES.get(arch, 1)
            res["microbatches"] = micro
            step = make_train_step(cfg, OptimizerConfig(), microbatches=micro)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, batch)
        elif shape.kind == "prefill":
            params = S.abstract_params(cfg, mesh, "serve")[0]
            batch = S.batch_specs(cfg, shape, mesh, "prefill")
            fn = lambda p, b: model_lib.prefill(p, b, cfg)
            lowered = jax.jit(fn).lower(params, batch)
        else:
            params = S.abstract_params(cfg, mesh, "serve")[0]
            state = S.abstract_state(cfg, shape, mesh)
            batch = S.batch_specs(cfg, shape, mesh, "decode")
            fn = lambda p, st, b: model_lib.decode_step(p, st, b, cfg)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(params, state, batch)
        res["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        res["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    hlo_text_early = compiled.as_text()
    if ma is not None:
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        # XLA:CPU float-normalization materializes f32 twins of bf16
        # loop-carried buffers (stacks, caches). Real TPUs execute bf16
        # natively; estimate the inflation by pairing f32 shapes with
        # their bf16 twins and report a TPU-adjusted peak.
        f32_twin = _f32_normalization_bytes(hlo_text_early)
        res["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_gb": round(peak / 2 ** 30, 3),
            "cpu_f32_normalization_gb": round(f32_twin / 2 ** 30, 3),
            "tpu_adjusted_peak_gb": round((peak - f32_twin) / 2 ** 30, 3),
        }
        res["largest_tensors"] = _largest_tensors(hlo_text_early)
    # NOTE: raw cost_analysis() counts while-loop (lax.scan) bodies ONCE —
    # verified empirically — so we run our own trip-count-aware analyzer.
    from repro.launch.hlo_analysis import analyze
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    costs = analyze(hlo_text)
    flops_dev = costs.flops
    bytes_dev = costs.bytes
    colls = costs.coll
    wire_dev = sum(v["wire_bytes"] for v in colls.values())
    res["raw_cost_analysis"] = {"flops": float(ca.get("flops", 0.0)),
                                "bytes": float(ca.get("bytes accessed", 0.0))}

    mf = S.model_flops(cfg, shape)
    res.update({
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collectives": colls,
        "collective_wire_bytes_per_device": wire_dev,
        "model_flops_global": mf,
        "useful_flops_ratio": round(mf / max(flops_dev * chips, 1.0), 4),
        "roofline_s": {
            "compute": flops_dev / TPU_PEAK_FLOPS_BF16,
            "memory": bytes_dev / TPU_HBM_BW,
            "collective": wire_dev / TPU_ICI_BW_PER_LINK,
        },
    })
    terms = res["roofline_s"]
    res["bottleneck"] = max(terms, key=terms.get)
    res["ok"] = True
    return res


def cell_list():
    from repro.config import SHAPES, get_config, list_archs
    cells = []
    for arch in list_archs():
        if arch in ("mixtral-8x7b", "phi35-moe"):
            continue                       # paper models: bench/smoke only
        for shape in SHAPES:
            cells.append((arch, shape))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        failures = 0
        for arch, shape in cell_list():
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            out = RESULTS_DIR / f"{arch}__{shape}__{mesh_tag}.json"
            if out.exists() and not args.force:
                print(f"[skip] {out.name} exists", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if args.multi_pod:
                cmd.append("--multi-pod")
            print(f"[run ] {arch} x {shape} ({mesh_tag})", flush=True)
            rc = subprocess.run(cmd).returncode
            failures += rc != 0
        print(f"--all done, {failures} subprocess failures", flush=True)
        return

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    out = RESULTS_DIR / f"{args.arch}__{args.shape}__{mesh_tag}.json"
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod)
    except Exception as e:  # noqa: BLE001 — recorded, cell marked failed
        res = {"arch": args.arch, "shape": args.shape, "mesh": mesh_tag,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out.write_text(json.dumps(res, indent=2))
    print(json.dumps({k: v for k, v in res.items() if k != "traceback"},
                     indent=2), flush=True)
    sys.exit(0 if res.get("ok") or "skipped" in res else 1)


if __name__ == "__main__":
    main()
