"""Serving driver: collaborative two-tier MoE engine (the paper) with
continuous batching, or the plain generic path for non-MoE archs.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --tokens 64 [--ways 4 --indexes 8 --policy lru] \
        [--concurrency 4 --requests 8]

Reduced configs by default (this is a CPU container); the full configs are
exercised via the dry-run. Prints tokens/s and the paper's cache counters.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CacheConfig, get_config, reduced
from repro.models import decode_step, init_params, prefill
from repro.serving import CollaborativeEngine, ContinuousBatchingScheduler, \
    EngineConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--indexes", type=int, default=None)
    ap.add_argument("--ways", type=int, default=2)
    ap.add_argument("--policy", default="lru",
                    choices=["lru", "fifo", "random"])
    ap.add_argument("--concurrency", type=int, default=4,
                    help="scheduler slots (padded decode batch T)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests to serve (default: concurrency*2)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompt = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt), 0, cfg.vocab_size),
        np.int32)

    if cfg.moe is not None and cfg.moe_every == 1 and not cfg.is_encdec:
        n = args.indexes if args.indexes is not None else cfg.num_layers // 2
        ccfg = CacheConfig(num_indexes=n, num_ways=args.ways,
                           policy=args.policy)
        R = args.requests or args.concurrency * 2
        print(f"[serve] collaborative engine: {cfg.name} cache=(N={n}, "
              f"M={args.ways}, {args.policy}) slots={args.concurrency} "
              f"requests={R}")
        eng = CollaborativeEngine(cfg, params, EngineConfig(
            cache=ccfg, max_batch=args.concurrency,
            capacity=args.prompt + args.tokens + 1), key=key)
        sched = ContinuousBatchingScheduler(eng)
        rng = np.random.default_rng(args.seed)
        for r in range(R):
            plen = int(rng.integers(max(args.prompt // 2, 1),
                                    args.prompt + 1))
            sched.submit(rng.integers(0, cfg.vocab_size, plen),
                         max_new_tokens=args.tokens)
        t0 = time.time()
        outs = sched.run()
        dt = time.time() - t0
        stats = sched.stats
        total = sum(len(o) for o in outs.values())
        print(f"  served {len(outs)} requests / {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s wall, {stats['steps']} decode steps)")
        print(f"  cache hit rate: {stats['hit_rate']:.3f} "
              f"(hits={stats['hits']} accesses={stats['accesses']} "
              f"fetches={stats['fetched_experts']})")
    else:
        print(f"[serve] generic path: {cfg.name}")
        batch = {"tokens": jnp.asarray(prompt)}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.prompt, cfg.frontend_embed_dim),
                jnp.bfloat16)
        logits, state = jax.jit(lambda p, b: prefill(p, b, cfg))(params, batch)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        dstep = jax.jit(lambda p, s, b: decode_step(p, s, b, cfg),
                        donate_argnums=(1,))
        outs = [np.asarray(tok)]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, state = dstep(params, state, {"tokens": tok})
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        dt = time.time() - t0
        print(f"  generated {np.concatenate(outs,1).shape} in {dt:.2f}s "
              f"({(args.tokens-1)*args.batch/max(dt,1e-9):.1f} tok/s wall)")


if __name__ == "__main__":
    main()
