"""Serving driver: collaborative two-tier MoE engine (the paper) with
continuous batching, or the plain generic path for non-MoE archs.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --tokens 64 [--ways 4 --indexes 8 --policy lru] \
        [--concurrency 4 --requests 8] [--temperature 0.8 --top-p 0.95] \
        [--prefetch --prefetch-min-prob 0.2] \
        [--host-compute --host-threads 8 --host-backend callback] \
        [--kv-paged --page-size 16 --kv-pages 64] \
        [--prefill-segment 8 --prefix-keep-pages 16]

Reduced configs by default (this is a CPU container); the full configs are
exercised via the dry-run. Prints tokens/s and the paper's cache counters.
``--temperature > 0`` turns on per-request sampling (seeded per request:
request r uses seed ``--seed + r``); the default is greedy decoding.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models import decode_step, init_params, prefill
from repro.obs import TraceRecorder, write_chrome_trace
from repro.serving import SamplingParams, build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--indexes", type=int, default=None)
    ap.add_argument("--ways", type=int, default=2)
    ap.add_argument("--policy", default="lru",
                    choices=["lru", "fifo", "random"])
    ap.add_argument("--concurrency", type=int, default=4,
                    help="scheduler slots (padded decode batch T)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests to serve (default: concurrency*2)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0: per-request temperature sampling "
                         "(0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="cache-warming chunked-prefill chunk "
                         "(0 = bypass prefill, cold cache)")
    ap.add_argument("--prefill-segment", type=int, default=0,
                    help="segment-streamed prefill: forward the prompt in "
                         "this-many-token segments between decode ticks, "
                         "fusing KV append and cache warm per segment "
                         "(0 = one full-prompt forward at admission)")
    ap.add_argument("--prefix-keep-pages", type=int, default=0,
                    help="with --kv-paged: park up to this many zero-ref "
                         "prefix-indexed pages in an eviction LRU at "
                         "request retirement so same-prefix admissions "
                         "can adopt them (0 = free eagerly)")
    ap.add_argument("--admit-chunks-per-tick", type=int, default=0,
                    help="overlapped admission: advance a newly admitted "
                         "request's cache-warming replay by at most this "
                         "many chunks per tick between decode steps, so "
                         "established requests keep decoding while it "
                         "warms (0 = synchronous admission)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the scheduler queue; a full queue blocks "
                         "submit() (backpressure) instead of growing "
                         "without limit")
    ap.add_argument("--prefetch", action="store_true",
                    help="cross-layer speculative expert prefetch")
    ap.add_argument("--prefetch-min-prob", type=float, default=0.0,
                    help="confidence gate: only reserve predicted experts "
                         "whose router probability clears this threshold "
                         "(implies --prefetch when > 0)")
    ap.add_argument("--host-compute", action="store_true",
                    help="compute cache-miss experts on the CPU when the "
                         "cost model favors it over the weight fetch "
                         "(repro.hostexec)")
    ap.add_argument("--host-threads", type=int, default=8,
                    help="host executor threads (also the cost model's "
                         "OMP thread count)")
    ap.add_argument("--host-fuse-small", type=int, default=4,
                    help="batch same-step CPU-miss groups with at most "
                         "this many valid tokens into one stacked numpy "
                         "matmul instead of one pool task each (0 = "
                         "never fuse)")
    ap.add_argument("--no-prefetch-rank-votes", action="store_false",
                    dest="prefetch_rank_votes",
                    help="disable vote-count ranking of speculative "
                         "prefetch reservations (default: experts many "
                         "rows predict claim cache ways first)")
    ap.add_argument("--host-backend", default="callback",
                    choices=["callback", "jax"],
                    help="host lane: real numpy thread pool (callback) or "
                         "the bit-exact in-graph fallback (jax)")
    ap.add_argument("--kv-paged", action="store_true",
                    help="paged KV pool with prefix sharing (per-request "
                         "page tables over one global page pool; "
                         "bit-identical tokens to the dense cache)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV tokens per page (with --kv-paged)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="page pool size (default: dense-equivalent "
                         "slots*capacity/page_size)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(request lifecycles, step phases, lane "
                         "counters; open in ui.perfetto.dev or "
                         "chrome://tracing; collaborative path only)")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="print a periodic latency summary every N "
                         "scheduler ticks (p50/p99 TTFT/TPOT/stall from "
                         "the streaming histograms; 0 = off)")
    args = ap.parse_args()
    if not 0.0 < args.top_p <= 1.0:
        ap.error(f"--top-p must be in (0, 1], got {args.top_p}")
    if args.top_k < 0:
        ap.error(f"--top-k must be >= 0, got {args.top_k}")
    if args.temperature < 0:
        ap.error(f"--temperature must be >= 0, got {args.temperature}")

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompt = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt), 0, cfg.vocab_size),
        np.int32)

    # any sampling knob enables sampling (top-k/top-p without an explicit
    # temperature sample at T=1.0 rather than being silently ignored)
    sample_on = args.temperature > 0 or args.top_k > 0 or args.top_p < 1.0
    temp = args.temperature if args.temperature > 0 else 1.0

    if cfg.moe is not None and cfg.moe_every == 1 and not cfg.is_encdec:
        n = args.indexes if args.indexes is not None else cfg.num_layers // 2
        R = args.requests or args.concurrency * 2
        prefetch = args.prefetch or args.prefetch_min_prob > 0
        capacity = args.prompt + args.tokens + 1
        if args.kv_paged:
            # paged KV slices the per-request capacity into whole pages
            capacity = -(-capacity // args.page_size) * args.page_size
        print(f"[serve] collaborative engine: {cfg.name} cache=(N={n}, "
              f"M={args.ways}, {args.policy}) slots={args.concurrency} "
              f"requests={R} "
              f"sampling={f'T={temp}' if sample_on else 'greedy'}"
              + (f" prefetch(min_prob={args.prefetch_min_prob})"
                 if prefetch else "")
              + (f" overlap_admit({args.admit_chunks_per_tick} chunks/tick)"
                 if args.admit_chunks_per_tick else "")
              + (f" segmented_prefill({args.prefill_segment} tok/seg)"
                 if args.prefill_segment else "")
              + (f" max_queue={args.max_queue}"
                 if args.max_queue is not None else "")
              + (f" host_compute({args.host_backend}, "
                 f"{args.host_threads}t)" if args.host_compute else "")
              + (f" kv_paged(page_size={args.page_size})"
                 if args.kv_paged else ""))
        recorder = TraceRecorder() if args.trace_out else None
        _, sched = build(
            cfg,
            cache=dict(num_indexes=n, num_ways=args.ways,
                       policy=args.policy),
            serving=dict(max_batch=args.concurrency,
                         capacity=capacity,
                         prefill_chunk=args.prefill_chunk,
                         prefill_segment=args.prefill_segment,
                         admit_chunks_per_tick=args.admit_chunks_per_tick,
                         prefetch=prefetch,
                         prefetch_min_prob=args.prefetch_min_prob,
                         prefetch_rank_votes=args.prefetch_rank_votes,
                         host_compute=args.host_compute,
                         host_threads=args.host_threads,
                         host_backend=args.host_backend,
                         host_fuse_small=args.host_fuse_small,
                         kv_paged=args.kv_paged,
                         page_size=args.page_size,
                         kv_pages=args.kv_pages,
                         prefix_keep_pages=args.prefix_keep_pages),
            seed=args.seed, params=params, max_queue=args.max_queue,
            recorder=recorder)
        rng = np.random.default_rng(args.seed)
        for r in range(R):
            plen = int(rng.integers(max(args.prompt // 2, 1),
                                    args.prompt + 1))
            sp = SamplingParams(greedy=False, temperature=temp,
                                top_k=args.top_k, top_p=args.top_p,
                                seed=args.seed + r) if sample_on \
                else SamplingParams()
            sched.submit(rng.integers(0, cfg.vocab_size, plen),
                         max_new_tokens=args.tokens, sampling=sp)
        t0 = time.time()
        if args.metrics_every > 0:
            # step-driven drain so the periodic summary can fire between
            # ticks; sched.run() is the one-shot equivalent
            done, tick = 0, 0
            while done < R:
                done += len(sched.step())
                tick += 1
                if tick % args.metrics_every == 0:
                    s = sched.stats
                    print(f"  [metrics] tick={tick} "
                          f"finished={s.requests_finished} "
                          f"active={s.requests_active} "
                          f"queued={s.requests_queued} | "
                          f"ttft_ms {s.ttft_ms_p50:.1f}/"
                          f"{s.ttft_ms_p99:.1f} "
                          f"tpot_ms {s.tpot_ms_p50:.2f}/"
                          f"{s.tpot_ms_p99:.2f} "
                          f"stall_ms {s.stall_ms_p50:.2f}/"
                          f"{s.stall_ms_p99:.2f} (p50/p99)")
            outs = {req.rid: req.output for req in sched.finished}
        else:
            outs = sched.run()
        dt = time.time() - t0
        stats = sched.stats
        total = sum(len(o) for o in outs.values())
        assert total == stats.generated_tokens, (total, stats.generated_tokens)
        print(f"  served {stats.requests_finished} requests / {total} tokens "
              f"in {dt:.2f}s ({total / dt:.1f} tok/s wall, "
              f"{stats.steps} decode steps, "
              f"{stats.admission_stalls} admission stalls)")
        print(f"  cache hit rate: {stats.hit_rate:.3f} "
              f"(hits={stats.hits} accesses={stats.accesses} "
              f"fetches={stats.fetched_experts})")
        if stats.prefill_accesses:
            print(f"  prefill warming: {stats.prefill_tokens} tokens / "
                  f"{stats.prefill_chunks} chunks, hit rate "
                  f"{stats.prefill_hit_rate:.3f} "
                  f"({stats.prefill_fetched} fetches)")
        if prefetch:
            print(f"  prefetch: issued={stats.prefetch_issued} "
                  f"spec_hits={stats.prefetch_hits} "
                  f"wasted={stats.prefetch_wasted} "
                  f"pred_acc={stats.prediction_accuracy:.3f}")
        if args.host_compute:
            print(f"  host execution: {stats.cpu_expert_calls} expert "
                  f"groups / {stats.cpu_tokens} assignments on CPU "
                  f"({stats.fused_groups} fused, offload rate "
                  f"{stats.cpu_offload_rate:.3f}, "
                  f"backend={args.host_backend})")
        if args.prefill_segment:
            print(f"  segmented prefill: {stats.prefill_segments} segments "
                  f"({args.prefill_segment} tok/seg), "
                  f"{stats.prefix_tokens_skipped} prefix tokens skipped")
        if args.kv_paged:
            print(f"  paged KV: page_size={args.page_size} "
                  f"pages_in_use={stats.kv_pages_in_use} "
                  f"prefix_hits={stats.prefix_hits} "
                  f"cow_forks={stats.cow_forks} "
                  f"prefix_pages_retained={stats.prefix_pages_retained}")
        print(f"  latency: ttft_ms p50={stats.ttft_ms_p50:.1f} "
              f"p99={stats.ttft_ms_p99:.1f}, "
              f"tpot_ms p50={stats.tpot_ms_p50:.2f} "
              f"p99={stats.tpot_ms_p99:.2f}, "
              f"stall_ms p50={stats.stall_ms_p50:.2f} "
              f"p99={stats.stall_ms_p99:.2f}")
        if args.trace_out:
            write_chrome_trace(recorder, args.trace_out)
            print(f"  trace: {len(recorder)} events "
                  f"({recorder.dropped} dropped) -> {args.trace_out}")
    else:
        print(f"[serve] generic path: {cfg.name}")
        batch = {"tokens": jnp.asarray(prompt)}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.prompt, cfg.frontend_embed_dim),
                jnp.bfloat16)
        logits, state = jax.jit(lambda p, b: prefill(p, b, cfg))(params, batch)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        dstep = jax.jit(lambda p, s, b: decode_step(p, s, b, cfg),
                        donate_argnums=(1,))
        outs = [np.asarray(tok)]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, state = dstep(params, state, {"tokens": tok})
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        dt = time.time() - t0
        print(f"  generated {np.concatenate(outs,1).shape} in {dt:.2f}s "
              f"({(args.tokens-1)*args.batch/max(dt,1e-9):.1f} tok/s wall)")


if __name__ == "__main__":
    main()
