"""Serving driver: collaborative two-tier MoE engine (the paper) or the
plain generic path for non-MoE archs.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --tokens 64 [--ways 4 --indexes 8 --policy lru]

Reduced configs by default (this is a CPU container); the full configs are
exercised via the dry-run. Prints tokens/s and the paper's cache counters.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CacheConfig, get_config, reduced
from repro.models import decode_step, init_params, prefill
from repro.serving import CollaborativeEngine, EngineConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--indexes", type=int, default=None)
    ap.add_argument("--ways", type=int, default=2)
    ap.add_argument("--policy", default="lru",
                    choices=["lru", "fifo", "random"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompt = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt), 0, cfg.vocab_size),
        np.int32)

    if cfg.moe is not None and cfg.moe_every == 1 and not cfg.is_encdec:
        n = args.indexes if args.indexes is not None else cfg.num_layers // 2
        ccfg = CacheConfig(num_indexes=n, num_ways=args.ways,
                           policy=args.policy)
        print(f"[serve] collaborative engine: {cfg.name} cache=(N={n}, "
              f"M={args.ways}, {args.policy})")
        eng = CollaborativeEngine(cfg, params, EngineConfig(
            cache=ccfg, capacity=args.prompt + args.tokens + 1), key=key)
        t0 = time.time()
        out, stats = eng.generate(prompt, args.tokens, key)
        dt = time.time() - t0
        print(f"  generated {out.shape} in {dt:.2f}s "
              f"({args.tokens * args.batch / dt:.1f} tok/s wall)")
        print(f"  cache hit rate: {stats['hit_rate']:.3f} "
              f"(hits={stats['hits']} accesses={stats['accesses']} "
              f"fetches={stats['fetched_experts']})")
    else:
        print(f"[serve] generic path: {cfg.name}")
        batch = {"tokens": jnp.asarray(prompt)}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.prompt, cfg.frontend_embed_dim),
                jnp.bfloat16)
        logits, state = jax.jit(lambda p, b: prefill(p, b, cfg))(params, batch)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        dstep = jax.jit(lambda p, s, b: decode_step(p, s, b, cfg),
                        donate_argnums=(1,))
        outs = [np.asarray(tok)]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, state = dstep(params, state, {"tokens": tok})
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        dt = time.time() - t0
        print(f"  generated {np.concatenate(outs,1).shape} in {dt:.2f}s "
              f"({(args.tokens-1)*args.batch/max(dt,1e-9):.1f} tok/s wall)")


if __name__ == "__main__":
    main()
