"""Abstract input/parameter/state specs for lowering (no allocation).

Everything returns ShapeDtypeStructs carrying NamedShardings, the pattern
the dry-run lowers against. Batch is sharded over ("pod","data") when
divisible; decode caches shard sequence over "model" (and over the batch
axes too when the cell's batch can't cover them, e.g. long_500k's B=1).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, OptimizerConfig, ShapeConfig
from repro.models import model as model_lib
from repro.optim.adamw import init_opt_state
from repro.sharding.ctx import _filter_spec, batch_axes
from repro.sharding.partition import opt_state_spec, param_specs_for, spec_for


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, _filter_spec(spec, mesh)))


def _batch_ax(mesh: Mesh, b: int):
    axes = batch_axes(mesh)
    n = math.prod(mesh.shape[a] for a in axes) if axes else 1
    return axes if (n > 1 and b % n == 0) else None


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                mode: str) -> Dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    S = shape.seq_len if mode != "decode" else 1
    ba = _batch_ax(mesh, B)
    out = {"tokens": _sds((B, S), jnp.int32, mesh, (ba, None))}
    if mode == "train":
        out["labels"] = _sds((B, S), jnp.int32, mesh, (ba, None))
    if cfg.family == "audio" and mode != "decode":
        out["frames"] = _sds((B, S, cfg.frontend_embed_dim), jnp.bfloat16,
                             mesh, (ba, None, None))
    if cfg.family == "vlm" and mode != "decode":
        out["patches"] = _sds((B, 64, cfg.frontend_embed_dim), jnp.bfloat16,
                              mesh, (ba, None, None))
        out["positions"] = _sds((3, B, S), jnp.int32, mesh, (None, ba, None))
    return out


def abstract_params(cfg: ModelConfig, mesh: Mesh, profile: str = "train"):
    """(ShapeDtypeStruct params pytree, spec pytree).

    profile "train": TP compute sharding. profile "serve": additionally
    FSDP-shard each weight's largest free dim over "data" (weights are
    gathered per scanned layer; decode HBM then holds 1/(data*model) of
    the weights plus one layer's gather).
    """
    shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs_for(shapes, mesh)
    if profile == "serve":
        specs = jax.tree.map(
            lambda sp, sh: opt_state_spec(sp, sh.shape, mesh), specs, shapes)
    params = jax.tree.map(
        lambda sh, sp: _sds(sh.shape, sh.dtype, mesh, tuple(sp)), shapes, specs)
    return params, specs


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh, params_abs):
    shapes = jax.eval_shape(init_opt_state, params_abs)
    pspecs = param_specs_for(
        jax.eval_shape(lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0))),
        mesh)

    def spec_like(leaf_shapes, _):
        return jax.tree.map(
            lambda sh, sp: _sds(sh.shape, sh.dtype, mesh,
                                tuple(opt_state_spec(sp, sh.shape, mesh))),
            leaf_shapes, pspecs)

    from repro.optim.adamw import AdamWState
    return AdamWState(
        step=_sds((), jnp.int32, mesh, ()),
        mu=spec_like(shapes.mu, None),
        nu=spec_like(shapes.nu, None),
        master=spec_like(shapes.master, None),
    )


def _state_spec_for(path_names: Tuple[str, ...], shape: Tuple[int, ...],
                    mesh: Mesh, batch: int):
    ba = _batch_ax(mesh, batch)
    leaf = path_names[-1]
    nd = len(shape)
    model_ok = lambda d: mesh.shape.get("model", 1) > 1 and d % mesh.shape["model"] == 0
    if leaf in ("k", "v") and nd >= 4:
        # [*, B, S, hk, hd]: batch over data axes; sequence over model
        spec = [None] * nd
        spec[nd - 4] = ba
        if ba is None:
            both = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
            n = math.prod(mesh.shape[a] for a in both)
            if shape[nd - 3] % n == 0:
                spec[nd - 3] = both
        elif model_ok(shape[nd - 3]):
            spec[nd - 3] = "model"
        return P(*spec)
    if leaf == "ssd" and nd >= 4:
        spec = [None] * nd
        spec[nd - 4] = ba
        if model_ok(shape[nd - 3]):
            spec[nd - 3] = "model"
        return P(*spec)
    if leaf == "conv" and nd >= 3:
        spec = [None] * nd
        spec[nd - 3] = ba
        if model_ok(shape[nd - 1]):
            spec[nd - 1] = "model"
        return P(*spec)
    return P()


def abstract_state(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Decode-state ShapeDtypeStructs for the serve_step dry-run."""
    B, S = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(lambda: model_lib.init_state(cfg, B, S))
    from repro.sharding.partition import _path_names

    def mk(path, sh):
        spec = _state_spec_for(_path_names(path), sh.shape, mesh, B)
        return _sds(sh.shape, sh.dtype, mesh, tuple(spec))

    return jax.tree_util.tree_map_with_path(mk, shapes)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The 6·N·D / 2·N·D convention (N = active params, D = tokens)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token per seq
