"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --batch 8 --seq 256 [--reduced] [--ckpt-dir /tmp/ckpt]

Runs the full substrate stack: synthetic sharded data pipeline, AdamW with
ZeRO resharding (on >1 device), remat, async checkpointing, and the
fault-tolerance supervisor (restart-from-checkpoint on failure; pass
--inject-failure N to watch it recover).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import (OptimizerConfig, SHAPES, ShapeConfig, get_config,
                          reduced)
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import init_opt_state, make_train_step
from repro.runtime import TrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    ocfg = OptimizerConfig(warmup_steps=10, total_steps=args.steps)

    print(f"[train] {cfg.name} reduced={args.reduced} "
          f"params={cfg.param_count()/1e6:.1f}M batch={args.batch}x{args.seq}")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg, shape, seed=args.seed)
    step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    def do_step(state, i):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
        return (params, opt)

    def save(i, state):
        mgr.save(i, {"params": state[0], "opt": state[1]})

    def restore():
        tpl = {"params": params, "opt": opt}
        restored, step = mgr.restore(tpl)
        print(f"  [recovered from checkpoint @ step {step}]", flush=True)
        return (restored["params"], restored["opt"]), step

    sup = TrainSupervisor(do_step, save, restore, ckpt_every=args.ckpt_every)
    t0 = time.time()
    save(0, (params, opt))     # step-0 baseline so recovery always has one
    state, end = sup.run((params, opt), 0, args.steps,
                         failure_at=args.inject_failure)
    mgr.wait()
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[train] done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"{sup.restarts} restarts, {dt:.1f}s")


if __name__ == "__main__":
    main()
