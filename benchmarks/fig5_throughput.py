"""Paper Fig. 5: tokens/s of five methods x CPU threads x cache configs,
for Mixtral 8x7B and Phi-3.5-MoE, via the calibrated discrete-event
simulator over traces matching the paper's router statistics.

Validated claims printed inline: 4.8 / 10.4 tok/s peaks, 4.4x / 4.3x vs
Pre-gated, ~1.6x vs Fiddler, +15-35% / +50-250% over CPU-only.

``--live`` additionally drives a reduced live model through the batched
serving path (continuous-batching scheduler over one shared expert cache)
at several concurrency levels — wall-clock throughput scaling on this
container, NOT the paper metric (the calibrated simulator above is).
"""
from __future__ import annotations

import argparse

from repro.core import TraceConfig, synthetic_trace
from repro.core.costmodel import PAPER_TIMINGS
from repro.core.simulator import best_cache_config, simulate
from .common import check, emit


def live_scaling() -> None:
    """Wall tok/s of the live batched engine at concurrency 1 / 2 / 4."""
    from .common import record_run, run_live_scheduler
    print("=== live (reduced model): scheduler concurrency scaling ===")
    for slots in (1, 2, 4):
        outs, stats, dt = run_live_scheduler(slots=slots)
        record_run(f"fig5.live.slots{slots}", stats)
        total = sum(len(o) for o in outs.values())
        emit(f"live.mixtral_reduced.slots{slots}.tok_s", total / dt * 1e6,
             f"steps={stats.steps} hit_rate={stats.hit_rate:.3f} "
             f"(wall clock on this container, not the paper metric)")
        # latency percentiles from the scheduler's streaming log-bucket
        # histograms (RunStats carries them; no ad-hoc percentile math)
        emit(f"live.mixtral_reduced.slots{slots}.ttft_p50_us",
             stats.ttft_ms_p50 * 1e3,
             f"p99={stats.ttft_ms_p99 * 1e3:.0f}us (streaming histogram)")
        emit(f"live.mixtral_reduced.slots{slots}.tpot_p50_us",
             stats.tpot_ms_p50 * 1e3,
             f"p99={stats.tpot_ms_p99 * 1e3:.0f}us (streaming histogram)")

THREADS = (1, 2, 4, 8, 16, 24)
# Phi-3.5's published hit rates (Fig. 6b: LRU >> random) imply stickier
# routing than Mixtral's; stickiness calibrated to reproduce Fig. 5b peaks.
TRACES = {
    "mixtral-8x7b": TraceConfig(num_tokens=600, num_layers=32, num_experts=8),
    "phi35-moe": TraceConfig(num_tokens=600, num_layers=32, num_experts=16,
                             stickiness=0.50),
}
PAPER_PEAK = {"mixtral-8x7b": 4.8, "phi35-moe": 10.4}
PAPER_SPEEDUP_PREGATED = {"mixtral-8x7b": 4.4, "phi35-moe": 4.3}
# vs Fiddler: paper text says ~1.6x overall, but its Fig. 5b shows Fiddler
# collapsing to ~2.4 tok/s on Phi ("performs poorly ... exponential
# complexity") -> the Phi expectation is the figure-derived ~4.3x.
PAPER_SPEEDUP_FIDDLER = {"mixtral-8x7b": 1.6, "phi35-moe": 4.3}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="also run the live batched-scheduler scaling probe")
    args, _ = ap.parse_known_args()
    print("=== Fig. 5: tokens/s by method x threads x cache config ===")
    for name, tm in PAPER_TIMINGS.items():
        trace = synthetic_trace(TRACES[name])
        cfgs = best_cache_config(tm)
        best_overall = 0.0
        rows = {}
        for t in THREADS:
            row = {
                "cpu_only": simulate(trace, tm, t, "cpu_only").tokens_per_s,
                "on_demand": simulate(trace, tm, t, "on_demand").tokens_per_s,
                "pregated": simulate(trace, tm, t, "pregated").tokens_per_s,
                "fiddler": simulate(trace, tm, t, "fiddler",
                                    ccfg=cfgs[4]).tokens_per_s,
            }
            for m, c in cfgs.items():
                key = f"ours({c.num_indexes},{m})"
                row[key] = simulate(trace, tm, t, "ours", ccfg=c).tokens_per_s
                best_overall = max(best_overall, row[key])
            rows[t] = row
            ours_best = max(v for k, v in row.items() if k.startswith("ours"))
            emit(f"{name}.t{t}.ours_best", ours_best * 1e6,
                 " ".join(f"{k}={v:.2f}" for k, v in row.items()))

        r24 = rows[24]
        ours24 = max(v for k, v in r24.items() if k.startswith("ours"))
        print(check(f"{name}.peak_tok_s", best_overall, PAPER_PEAK[name], 0.15))
        print(check(f"{name}.speedup_vs_pregated", ours24 / r24["pregated"],
                    PAPER_SPEEDUP_PREGATED[name], 0.20))
        print(check(f"{name}.speedup_vs_fiddler", ours24 / r24["fiddler"],
                    PAPER_SPEEDUP_FIDDLER[name], 0.30))
        impr = ours24 / r24["cpu_only"] - 1
        band = (0.15, 0.35) if name == "mixtral-8x7b" else (0.28, 2.50)
        ok = band[0] - 0.05 <= impr <= band[1] + 0.05
        print(f"{name}.improvement_over_cpu_only: {impr:.1%} "
              f"(paper band {band[0]:.0%}~{band[1]:.0%}) "
              f"[{'OK' if ok else 'DIVERGES'}]")

    if args.live:
        live_scaling()


if __name__ == "__main__":
    main()
