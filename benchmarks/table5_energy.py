"""Paper Tables IV + V: power draw and Joules/token vs Pre-gated MoE.

Power numbers are the paper's RAPL/nvidia-smi measurements (cost-model
constants); energy = power x simulated token latency. Validates the
headline: at 24 cores ours uses 29.9% (Mixtral) / 27.8% (Phi) of the
prefetching method's energy.
"""
from __future__ import annotations

from repro.core import TraceConfig, synthetic_trace
from repro.core.costmodel import PAPER_TIMINGS
from repro.core.simulator import best_cache_config, simulate
from .common import check, emit

PAPER_J_PER_TOK = {
    "mixtral-8x7b": {1: 177.7, 2: 115.3, 4: 95.8, 8: 82.5, 16: 55.5, 24: 51.1,
                     "pregated": 171.3},
    "phi35-moe": {1: 49.1, 2: 33.8, 4: 26.7, 8: 25.9, 16: 22.1, 24: 21.9,
                  "pregated": 78.7},
}
TRACES = {
    "mixtral-8x7b": TraceConfig(num_tokens=500, num_layers=32, num_experts=8),
    "phi35-moe": TraceConfig(num_tokens=500, num_layers=32, num_experts=16,
                             stickiness=0.50),
}
ENERGY_RATIO = {"mixtral-8x7b": 0.299, "phi35-moe": 0.278}


def main() -> None:
    print("=== Tables IV/V: power (W) and energy (J/token) ===")
    for name, tm in PAPER_TIMINGS.items():
        trace = synthetic_trace(TRACES[name])
        cfgs = best_cache_config(tm)
        paper = PAPER_J_PER_TOK[name]
        for threads in (1, 2, 4, 8, 16, 24):
            best = min(
                (simulate(trace, tm, threads, "ours", ccfg=c)
                 for c in cfgs.values()),
                key=lambda r: r.joules_per_token)
            emit(f"{name}.t{threads}.j_per_tok", best.joules_per_token * 1e6,
                 check(f"J/tok@{threads}", best.joules_per_token,
                       paper[threads], 0.25) +
                 f" | P_cpu={best.cpu_power_w}W P_gpu={best.gpu_power_w}W")
        pre = simulate(trace, tm, 24, "pregated", ccfg=cfgs[4])
        emit(f"{name}.pregated.j_per_tok", pre.joules_per_token * 1e6,
             check("J/tok pregated", pre.joules_per_token, paper["pregated"],
                   0.2))
        ours24 = min((simulate(trace, tm, 24, "ours", ccfg=c)
                      for c in cfgs.values()),
                     key=lambda r: r.joules_per_token)
        print(check(f"{name}.energy_ratio_vs_prefetch",
                    ours24.joules_per_token / pre.joules_per_token,
                    ENERGY_RATIO[name], 0.25))


if __name__ == "__main__":
    main()
