"""Benchmark entry point: one section per paper table/figure + kernels +
the dry-run roofline summary. Prints ``name,us_per_call,derived`` CSV rows
plus validation lines against the paper's reported numbers.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from . import (admission_overlap, decode_prefetch, fig2_patterns,
                   fig5_throughput, fig6_hitrate, host_compute,
                   kernels_micro, table1_compute_comm, table5_energy)
    sections = [table1_compute_comm, fig2_patterns, fig5_throughput,
                fig6_hitrate, table5_energy, kernels_micro, decode_prefetch,
                host_compute, admission_overlap]
    if not args.skip_roofline:
        from . import roofline
        sections.append(roofline)

    failures = 0
    for mod in sections:
        print(f"\n########## {mod.__name__} ##########")
        try:
            mod.main()
        except Exception:  # noqa: BLE001 — report all sections
            failures += 1
            traceback.print_exc()
    if failures:
        print(f"\n{failures} benchmark section(s) failed", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
