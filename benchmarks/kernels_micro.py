"""Kernel microbenchmarks (interpret-mode wall time is NOT a TPU metric;
reported for harness completeness plus the analytic VMEM/roofline numbers
that ARE the TPU-relevant quantities)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.costmodel import TPU_HBM_BW, TPU_PEAK_FLOPS_BF16
from repro.kernels.moe_gmm import grouped_matmul, moe_ffn
from repro.kernels.decode_attention import decode_attention
from .common import emit, timeit


def main() -> None:
    print("=== kernels: analytic roofline + interpret-mode correctness ===")
    # mixtral-shaped expert pair on one device
    E, C, D, F = 2, 128, 512, 1792        # scaled-down for interpret mode
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (E, C, D), jnp.bfloat16)
    w1 = jax.random.normal(ks[1], (E, D, F), jnp.bfloat16) * 0.05
    w3 = jax.random.normal(ks[2], (E, D, F), jnp.bfloat16) * 0.05
    w2 = jax.random.normal(ks[3], (E, F, D), jnp.bfloat16) * 0.05

    us = timeit(lambda: jax.block_until_ready(moe_ffn(x, w1, w3, w2)),
                iters=2, warmup=1)
    flops = 2 * E * C * D * F * 3
    weight_bytes = 3 * E * D * F * 2
    t_compute = flops / TPU_PEAK_FLOPS_BF16
    t_memory = weight_bytes / TPU_HBM_BW
    emit("moe_ffn.interpret", us,
         f"tpu_roofline: compute={t_compute*1e6:.1f}us "
         f"memory={t_memory*1e6:.1f}us "
         f"bound={'memory' if t_memory > t_compute else 'compute'} "
         f"(C={C}: decode-like, weight-streaming bound)")

    B, H, Hk, hd, S = 2, 8, 2, 128, 4096
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), jnp.bfloat16)
    us = timeit(lambda: jax.block_until_ready(
        decode_attention(q, k, v, jnp.int32(S - 1))), iters=2, warmup=1)
    kv_bytes = 2 * B * S * Hk * hd * 2
    emit("flash_decode.interpret", us,
         f"tpu_roofline: kv_stream={kv_bytes/TPU_HBM_BW*1e6:.1f}us "
         f"(pure HBM-bandwidth bound at decode)")

    from repro.kernels.ssd_scan import ssd_chunked_kernel
    Bb, S2, nh, hp, ds = 1, 512, 4, 64, 128
    x = jax.random.normal(ks[0], (Bb, S2, nh, hp), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S2, nh)))
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    Bm = jax.random.normal(ks[3], (Bb, S2, ds)) * 0.3
    Cm = jax.random.normal(ks[0], (Bb, S2, ds)) * 0.3
    us = timeit(lambda: jax.block_until_ready(
        ssd_chunked_kernel(x, dt, A_log, Bm, Cm)), iters=2, warmup=1)
    # the win: state [ds,hp] stays in VMEM across chunks instead of
    # round-tripping HBM every lax.scan step
    state_traffic = (S2 // 128) * Bb * nh * ds * hp * 4 * 2
    emit("ssd_scan.interpret", us,
         f"tpu: saved state HBM round-trips={state_traffic/1e6:.2f}MB/layer "
         f"({(S2 // 128)} chunks x {Bb*nh} heads, kept in VMEM scratch)")


if __name__ == "__main__":
    main()
