"""Kernel microbenchmarks (interpret-mode wall time is NOT a TPU metric;
reported for harness completeness plus the analytic VMEM/roofline numbers
that ARE the TPU-relevant quantities)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.costmodel import TPU_HBM_BW, TPU_PEAK_FLOPS_BF16
from repro.kernels.moe_gmm import grouped_matmul, moe_ffn
from repro.kernels.decode_attention import decode_attention
from .common import emit, timeit


def bench_cache_access() -> None:
    """Expert-cache access: seed per-pick scan vs vectorized row update.

    The paper-scale geometry (N=32 layers, M=8 ways) at decode assignment
    counts from a single request (T*K = 4) up to a full continuous batch
    (T*K = 64). The vectorized path gathers the set row once and services
    picks with O(M) vector ops; the seed path re-slices the full [N, M]
    arrays per pick inside a lax.scan.
    """
    import jax.numpy as jnp
    from repro.config import CacheConfig
    from repro.core.cache import access, access_scan_reference, \
        init_cache_state

    print("=== expert-cache access: seed scan vs vectorized row update ===")
    ccfg = CacheConfig(num_indexes=32, num_ways=8, policy="lru")
    state = init_cache_state(ccfg)
    layer = jnp.int32(3)
    for A in (4, 16, 64):
        experts = jax.random.randint(jax.random.PRNGKey(A), (A,), 0, 16,
                                     jnp.int32)
        new = jax.jit(lambda s, e: access(s, layer, e, "lru"))
        old = jax.jit(lambda s, e: access_scan_reference(s, layer, e, "lru"))
        t_new = timeit(lambda: jax.block_until_ready(new(state, experts)),
                       iters=50, warmup=5)
        t_old = timeit(lambda: jax.block_until_ready(old(state, experts)),
                       iters=50, warmup=5)
        emit(f"cache_access.A{A}.vectorized", t_new,
             f"seed_scan={t_old:.1f}us speedup={t_old / t_new:.2f}x "
             f"(N=32 M=8 lru, {A} assignments/step)")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    args, _ = ap.parse_known_args()

    bench_cache_access()
    print("=== kernels: analytic roofline + interpret-mode correctness ===")
    # mixtral-shaped expert pair on one device
    E, C, D, F = 2, 128, 512, 1792        # scaled-down for interpret mode
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (E, C, D), jnp.bfloat16)
    w1 = jax.random.normal(ks[1], (E, D, F), jnp.bfloat16) * 0.05
    w3 = jax.random.normal(ks[2], (E, D, F), jnp.bfloat16) * 0.05
    w2 = jax.random.normal(ks[3], (E, F, D), jnp.bfloat16) * 0.05

    us = timeit(lambda: jax.block_until_ready(moe_ffn(x, w1, w3, w2)),
                iters=2, warmup=1)
    flops = 2 * E * C * D * F * 3
    weight_bytes = 3 * E * D * F * 2
    t_compute = flops / TPU_PEAK_FLOPS_BF16
    t_memory = weight_bytes / TPU_HBM_BW
    emit("moe_ffn.interpret", us,
         f"tpu_roofline: compute={t_compute*1e6:.1f}us "
         f"memory={t_memory*1e6:.1f}us "
         f"bound={'memory' if t_memory > t_compute else 'compute'} "
         f"(C={C}: decode-like, weight-streaming bound)")

    B, H, Hk, hd, S = 2, 8, 2, 128, 4096
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), jnp.bfloat16)
    us = timeit(lambda: jax.block_until_ready(
        decode_attention(q, k, v, jnp.int32(S - 1))), iters=2, warmup=1)
    kv_bytes = 2 * B * S * Hk * hd * 2
    emit("flash_decode.interpret", us,
         f"tpu_roofline: kv_stream={kv_bytes/TPU_HBM_BW*1e6:.1f}us "
         f"(pure HBM-bandwidth bound at decode)")

    from repro.kernels.ssd_scan import ssd_chunked_kernel
    Bb, S2, nh, hp, ds = 1, 512, 4, 64, 128
    x = jax.random.normal(ks[0], (Bb, S2, nh, hp), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S2, nh)))
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    Bm = jax.random.normal(ks[3], (Bb, S2, ds)) * 0.3
    Cm = jax.random.normal(ks[0], (Bb, S2, ds)) * 0.3
    us = timeit(lambda: jax.block_until_ready(
        ssd_chunked_kernel(x, dt, A_log, Bm, Cm)), iters=2, warmup=1)
    # the win: state [ds,hp] stays in VMEM across chunks instead of
    # round-tripping HBM every lax.scan step
    state_traffic = (S2 // 128) * Bb * nh * ds * hp * 4 * 2
    emit("ssd_scan.interpret", us,
         f"tpu: saved state HBM round-trips={state_traffic/1e6:.2f}MB/layer "
         f"({(S2 // 128)} chunks x {Bb*nh} heads, kept in VMEM scratch)")

    if args.json:
        from .common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
