"""Decode-step microbenchmark: cross-layer speculative prefetch on vs off.

Times one jitted decode step of the batched collaborative engine (reduced
Mixtral geometry, 4-slot batch, shared LRU expert cache) with
``EngineConfig.prefetch`` disabled and enabled, and reports the measured
demand hit rates and prefetch counters over a short greedy generation.

Interpret-mode wall time on this container is NOT the paper metric (the
calibrated simulator is — see fig5/fig6); what this harness pins down is
(a) the per-step cost of the prediction + reservation stages and (b) the
live hit-rate uplift, both of which should track on real hardware.

    PYTHONPATH=src python -m benchmarks.decode_prefetch [--json PATH]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from .common import dump_json, emit, record_run, timeit

SLOTS = 4
STEPS = 24


def bench(prefetch: bool, rank_votes: bool = True):
    from repro.serving import build

    eng, _ = build("mixtral-8x7b",
                   serving=dict(max_batch=SLOTS, capacity=64,
                                prefetch=prefetch,
                                prefetch_rank_votes=rank_votes),
                   seed=0)
    cfg = eng.cfg

    # hit-rate probe: short greedy generation through the decode path
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(0),
                                           (SLOTS, 8), 0,
                                           cfg.vocab_size), np.int32)
    out, stats = eng.generate(prompt, steps=STEPS)

    # step-latency probe: one jitted decode step, steady state
    state = eng.init_slots()
    state["pos"] = jnp.full((SLOTS,), 8, jnp.int32)
    tok = np.zeros((SLOTS, 1), np.int32)
    active = jnp.ones((SLOTS,), bool)

    def step():
        nonlocal state
        logits, state = eng.decode_batch(tok, state, active)
        jax.block_until_ready(logits)

    us = timeit(step, iters=10, warmup=3)
    return us, stats, out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    args, _ = ap.parse_known_args()

    print("=== decode step: cross-layer speculative prefetch on/off ===")
    us_off, s_off, _ = bench(prefetch=False)
    us_on, s_on, out_rv = bench(prefetch=True)
    record_run("decode_prefetch.off", s_off)
    record_run("decode_prefetch.on", s_on)
    # batch-aware reservation ranking self-check: vote-ranked claims must
    # not lose speculative hits vs insertion order, and never touch tokens
    _, s_nrv, out_nrv = bench(prefetch=True, rank_votes=False)
    assert np.array_equal(out_rv, out_nrv), \
        "rank_votes changed generated tokens (must be residency-only)"
    assert s_on.prefetch_hits >= s_nrv.prefetch_hits, \
        (s_on.prefetch_hits, s_nrv.prefetch_hits)
    emit("decode_step.rank_votes_spec_hits",
         float(s_on.prefetch_hits - s_nrv.prefetch_hits),
         f"spec hits {s_nrv.prefetch_hits} -> {s_on.prefetch_hits} with "
         f"vote-ranked reservations (tokens bit-identical)")
    hr_off = s_off.hit_rate
    hr_on = s_on.hit_rate
    emit("decode_step.prefetch_off", us_off,
         f"hit_rate={hr_off:.3f} ({SLOTS}-slot batch, lru 2-way)")
    emit("decode_step.prefetch_on", us_on,
         f"hit_rate={hr_on:.3f} overhead={us_on / us_off:.2f}x "
         f"pred_acc={s_on.prediction_accuracy:.3f} "
         f"issued={s_on.prefetch_issued} "
         f"spec_hits={s_on.prefetch_hits} "
         f"wasted={s_on.prefetch_wasted}")
    emit("decode_step.prefetch_hit_uplift", (hr_on - hr_off) * 1e6,
         f"demand hit rate {hr_off:.3f} -> {hr_on:.3f} on the same "
         f"prompts/weights (prefetch changes residency, never logits)")
    if args.json:
        dump_json(args.json)


if __name__ == "__main__":
    main()
