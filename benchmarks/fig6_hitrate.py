"""Paper Fig. 6: expert-cache hit rates by configuration and eviction
policy (LRU vs FIFO vs static-random + its closed form), for both models.

Two modes: calibrated synthetic traces (default, matches the paper's
measured router statistics) and --live, which captures real router
decisions from a reduced repro model.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.config import CacheConfig
from repro.core import (NumpyCache, TraceConfig, random_policy_hit_probs,
                        synthetic_trace)
from repro.core.costmodel import PAPER_TIMINGS
from repro.core.simulator import best_cache_config
from .common import emit

TRACES = {
    "mixtral-8x7b": TraceConfig(num_tokens=1500, num_layers=32, num_experts=8),
    "phi35-moe": TraceConfig(num_tokens=1500, num_layers=32, num_experts=16,
                             stickiness=0.50),
}


def run_policy(trace, ccfg: CacheConfig, num_experts: int):
    c = NumpyCache(ccfg, num_experts=num_experts, seed=3)
    anyh = both = 0
    T, L, K = trace.shape
    for t in range(T):
        for l in range(L):
            h = c.access(l, trace[t, l])
            anyh += any(h)
            both += all(h)
    return anyh / (T * L), both / (T * L)


def live_trace(steps: int = 200):
    import jax
    from repro.config import get_config, reduced
    from repro.core.router_trace import capture_trace
    from repro.models import init_params
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, steps), 0, cfg.vocab_size)
    return capture_trace(cfg, params, toks), cfg.moe.num_experts


def live_serving(policy: str, prefetch: bool = False,
                 prefetch_min_prob: float = 0.0, rank_votes: bool = True):
    """Measured stats of the real serving path: the batched engine +
    continuous-batching scheduler, 4 concurrent requests sharing one
    expert cache (grouped gmm execution, per-slot KV positions, optional
    cross-layer speculative prefetch, optionally confidence-gated).
    Returns (outputs {rid: tokens}, RunStats)."""
    from .common import record_run, run_live_scheduler
    outs, stats, _ = run_live_scheduler(policy=policy, prefetch=prefetch,
                                        prefetch_min_prob=prefetch_min_prob,
                                        prefetch_rank_votes=rank_votes)
    gate = f".gate{prefetch_min_prob}" if prefetch_min_prob else ""
    rv = ".norank" if not rank_votes else ""
    record_run(f"fig6.live.{policy}{'.pf' if prefetch else ''}{gate}{rv}",
               stats)
    return outs, stats


def prefetch_uplift_sim() -> None:
    """Cross-layer speculative prefetch in the calibrated simulator: the
    window-gated speculative fetches convert next-layer misses into hits
    where the CPU expert compute leaves transfer bubbles (low thread
    counts); at saturated-link configurations the gate keeps prefetch
    out of the demand path's way (no regression by construction)."""
    from repro.core.simulator import simulate
    print("=== prefetch uplift (calibrated simulator, ours vs "
          "ours_prefetch) ===")
    for name, tm in PAPER_TIMINGS.items():
        trace = synthetic_trace(TRACES[name])
        for threads in (1, 8):
            for m, ccfg in best_cache_config(tm).items():
                base = simulate(trace, tm, threads, "ours", ccfg=ccfg)
                pf = simulate(trace, tm, threads, "ours_prefetch", ccfg=ccfg)
                emit(f"{name}.t{threads}.M{m}.prefetch_hit_rate",
                     pf.hit_rate * 1e6,
                     f"ours={base.hit_rate:.3f} tok_s={pf.tokens_per_s:.2f} "
                     f"vs {base.tokens_per_s:.2f} "
                     f"issued={pf.extra.get('prefetch_issued', 0)} "
                     f"wasted={pf.extra.get('prefetch_wasted', 0)}")
                # the window gate makes prefetch best-effort: it may be
                # neutral (gate closed) but must never lose throughput
                assert pf.tokens_per_s >= base.tokens_per_s * 0.995, \
                    (name, threads, m, pf.tokens_per_s, base.tokens_per_s)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="capture router trace from a live reduced model")
    args, _ = ap.parse_known_args()

    print("=== Fig. 6: hit rates by cache config x policy ===")
    for name, tm in PAPER_TIMINGS.items():
        trace = synthetic_trace(TRACES[name])
        E = tm.num_experts
        for m, ccfg in best_cache_config(tm).items():
            tag = f"{name}.(N={ccfg.num_indexes},M={m})"
            lru_any, lru_both = run_policy(
                trace, CacheConfig(ccfg.num_indexes, m, "lru"), E)
            fifo_any, _ = run_policy(
                trace, CacheConfig(ccfg.num_indexes, m, "fifo"), E)
            rnd_any, rnd_both = run_policy(
                trace, CacheConfig(ccfg.num_indexes, m, "random"), E)
            cf_any, cf_both = random_policy_hit_probs(E, m)
            # coverage-weighted closed form (layers >= N always miss)
            cov = min(ccfg.num_indexes, 32) / 32
            emit(f"{tag}.lru_any", lru_any * 1e6,
                 f"fifo={fifo_any:.3f} random={rnd_any:.3f} "
                 f"closed_form={cf_any*cov:.3f} both_lru={lru_both:.3f}")
            assert lru_any >= fifo_any - 0.02, "paper: LRU >= FIFO"
            assert lru_any >= rnd_any - 0.02, "paper: LRU beats random"

    prefetch_uplift_sim()

    if args.live:
        trace, E = live_trace()
        lru_any, _ = run_policy(
            trace, CacheConfig(trace.shape[1], 2, "lru"), E)
        rnd_any, _ = run_policy(
            trace, CacheConfig(trace.shape[1], 2, "random"), E)
        emit("live.mixtral_reduced.lru_any", lru_any * 1e6,
             f"random={rnd_any:.3f} (untrained router: near-chance reuse)")
        _, s_lru = live_serving("lru")
        served_lru = s_lru.hit_rate
        served_rnd = live_serving("random")[1].hit_rate
        emit("live.mixtral_reduced.served_lru_hit_rate", served_lru * 1e6,
             f"random={served_rnd:.3f} (batched scheduler, 4 slots sharing "
             f"one cache; per-assignment hit rate of the serving engine)")
        # cross-layer speculative prefetch on the SAME trace/engine/policy:
        # the demand hit rate must strictly improve (the pre-gating
        # predictor runs layer l+1's router one layer early; its accuracy
        # is near-perfect on the slowly-moving residual stream)
        outs_pf, pf = live_serving("lru", prefetch=True)
        emit("live.mixtral_reduced.served_lru_prefetch_hit_rate",
             pf.hit_rate * 1e6,
             f"baseline={served_lru:.3f} "
             f"pred_acc={pf.prediction_accuracy:.3f} "
             f"issued={pf.prefetch_issued} "
             f"spec_hits={pf.prefetch_hits} "
             f"wasted={pf.prefetch_wasted}")
        assert pf.hit_rate > served_lru, \
            ("prefetch must beat the no-prefetch baseline",
             pf.hit_rate, served_lru)
        # confidence-gated prefetch: thresholding reservations on router
        # probability cuts the speculative transfer volume — and with it
        # prefetch_wasted, the only source of cache pollution — while the
        # generated tokens stay IDENTICAL (gating changes residency,
        # never logits). The untrained reduced router's one-layer-ahead
        # predictions are near-perfect (pred_acc above), so the ungated
        # baseline often has zero waste to begin with; the waste assert
        # is strict exactly when there is waste to cut.
        GATE = 0.35                      # ~p75 pick prob, untrained
        outs_g, pfg = live_serving("lru", prefetch=True,
                                   prefetch_min_prob=GATE)
        emit("live.mixtral_reduced.served_lru_prefetch_gated_wasted",
             pfg.prefetch_wasted * 1e6,
             f"ungated_wasted={pf.prefetch_wasted} gate={GATE} "
             f"issued={pfg.prefetch_issued} vs {pf.prefetch_issued} "
             f"predicted={pfg.predicted} vs {pf.predicted} "
             f"hit_rate={pfg.hit_rate:.3f}")
        assert sorted(outs_g) == sorted(outs_pf)
        for rid in outs_pf:
            np.testing.assert_array_equal(outs_g[rid], outs_pf[rid])
        assert pfg.predicted < pf.predicted, \
            ("the gate must suppress low-confidence predictions",
             pfg.predicted, pf.predicted)
        assert pfg.prefetch_issued < pf.prefetch_issued, \
            ("the gate must cut the speculative transfer volume",
             pfg.prefetch_issued, pf.prefetch_issued)
        assert pfg.prefetch_wasted <= pf.prefetch_wasted, \
            ("gating must never add waste",
             pfg.prefetch_wasted, pf.prefetch_wasted)
        if pf.prefetch_wasted:
            assert pfg.prefetch_wasted < pf.prefetch_wasted, \
                ("confidence gating must cut wasted prefetches",
                 pfg.prefetch_wasted, pf.prefetch_wasted)
        # batch-aware reservation ranking: vote-ranked way claims must
        # never lose speculative hits vs insertion order, and (like every
        # prefetch knob) never change the generated tokens
        outs_nr, pf_nr = live_serving("lru", prefetch=True,
                                      rank_votes=False)
        emit("live.mixtral_reduced.served_lru_prefetch_rank_votes",
             pf.prefetch_hits * 1e6,
             f"spec_hits ranked={pf.prefetch_hits} "
             f"unranked={pf_nr.prefetch_hits} "
             f"hit_rate {pf_nr.hit_rate:.3f} -> {pf.hit_rate:.3f}")
        assert sorted(outs_nr) == sorted(outs_pf)
        for rid in outs_pf:
            np.testing.assert_array_equal(outs_nr[rid], outs_pf[rid])
        assert pf.prefetch_hits >= pf_nr.prefetch_hits, \
            ("vote ranking must not lose speculative hits",
             pf.prefetch_hits, pf_nr.prefetch_hits)
        assert pf.hit_rate >= pf_nr.hit_rate, \
            ("vote ranking must keep the demand hit rate non-decreasing",
             pf.hit_rate, pf_nr.hit_rate)


if __name__ == "__main__":
    main()
