"""Tracing-overhead benchmark: the instrumented serving stack with a live
``TraceRecorder`` vs the default no-op recorder.

The drain-point design (repro.obs: plain ``perf_counter_ns`` reads in the
hot path, emission only at the ``_obs_*`` drain helpers, never a device
sync) claims the trace is close to free. This benchmark pins that claim:
it serves the SAME workload (same seed, same prompts) twice per repeat —
once untraced, once with a recorder — and checks

  * tokens are BIT-identical traced vs untraced (observability never
    touches numerics), and
  * the median tokens/s delta across repeats stays under 5%, and
  * the captured trace validates as Chrome trace-event JSON with every
    request's full lifecycle covered.

Both arms build a fresh stack, so compile/tracing costs are symmetric;
the arms interleave within each repeat so drift hits both equally.

    PYTHONPATH=src python -m benchmarks.obs_overhead [--json PATH]
        [--repeats 3]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.obs import TraceRecorder, chrome_trace, validate_chrome_trace
from repro.obs.export import lifecycle_coverage

from .common import check, dump_json, emit, record_run, run_live_scheduler

SLOTS = 3
REQUESTS = 5
NEW_TOKENS = 16
OVERHEAD_TOL = 0.05


def serve(recorder=None):
    return run_live_scheduler(slots=SLOTS, requests=REQUESTS,
                              new_tokens=NEW_TOKENS, recorder=recorder)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    ap.add_argument("--repeats", type=int, default=5)
    args, _ = ap.parse_known_args()

    print(f"=== observability overhead: traced vs no-op recorder, "
          f"{REQUESTS} requests x {NEW_TOKENS} tokens, "
          f"median of {args.repeats} repeats ===")
    # throwaway run warms the XLA executable cache so the measured pairs
    # compare steady-state serving, not first-compile
    serve()

    tok_off, tok_on = [], []
    outs_off = outs_on = stats_on = rec = None
    for rep in range(args.repeats):
        # alternate arm order so slow drift (thermal, background load)
        # hits both arms symmetrically across the repeat set
        if rep % 2 == 0:
            outs_off, _, dt_off = serve()
            rec = TraceRecorder()
            outs_on, stats_on, dt_on = serve(rec)
        else:
            rec = TraceRecorder()
            outs_on, stats_on, dt_on = serve(rec)
            outs_off, _, dt_off = serve()
        total = sum(len(o) for o in outs_off.values())
        tok_off.append(total / dt_off)
        tok_on.append(sum(len(o) for o in outs_on.values()) / dt_on)

    # self-check 1: tracing never touches numerics
    assert sorted(outs_on) == sorted(outs_off)
    for rid in outs_off:
        np.testing.assert_array_equal(outs_on[rid], outs_off[rid])
    print("[self-check OK] tokens bit-identical traced vs untraced")

    # self-check 2: the trace itself is well-formed and complete
    doc = chrome_trace(rec)
    problems = validate_chrome_trace(doc)
    assert not problems, problems
    cover = lifecycle_coverage(doc)
    assert len(cover) == REQUESTS, sorted(cover)
    for track, spans in cover.items():
        assert {"queued", "prefill", "decode"} <= spans, (track, spans)
    print(f"[self-check OK] trace valid, {len(rec)} events, "
          f"{len(cover)} request lifecycles covered")

    r_off = float(np.median(tok_off))
    r_on = float(np.median(tok_on))
    delta = abs(r_on - r_off) / max(r_off, 1e-12)
    emit("obs_overhead.tok_s.untraced", r_off * 1e6,
         "median wall tok/s, no-op recorder")
    emit("obs_overhead.tok_s.traced", r_on * 1e6,
         "median wall tok/s, live TraceRecorder")
    emit("obs_overhead.overhead_pct", delta * 100,
         f"|traced - untraced| / untraced (bound {OVERHEAD_TOL:.0%})")
    record_run("obs_overhead.traced", stats_on)
    print(check("obs_overhead.tok_s_ratio", r_on / r_off, 1.0,
                OVERHEAD_TOL))

    # self-check 3: the overhead bound the drain-point design promises
    assert delta <= OVERHEAD_TOL, \
        ("tracing overhead above bound", delta, r_off, r_on)
    print(f"[self-check OK] tracing overhead {delta:.1%} "
          f"(bound {OVERHEAD_TOL:.0%})")

    if args.json:
        dump_json(args.json)


if __name__ == "__main__":
    main()
