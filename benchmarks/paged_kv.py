"""Paged-KV microbenchmark: memory footprint + admission-latency wins of
the paged KV pool with copy-on-write prefix sharing.

Three probes, all on the reduced-Mixtral serving stack:

1. **A/B bit-identity** — the same shared-prefix request fleet served
   through the continuous-batching scheduler with dense per-slot KV and
   with the paged pool; the generated tokens must match bitwise (paging
   changes memory layout and residency, never logits).
2. **Footprint** — the paged run's peak page occupancy versus the
   dense-equivalent page count (every resident request paying
   ``capacity / page_size`` pages); prefix sharing must hold strictly
   fewer pages.
3. **TTFT** — cold admission versus prefix-hit admission of the same
   prompt length through the engine's request primitives: the prefix hit
   must replay strictly fewer warm chunks and land strictly lower
   wall-clock (the shared span's routing already warmed the cache when
   the prefix holder was admitted).

Interpret-mode wall time is not the paper metric, but the chunk counts
and page accounting are exact, and the TTFT ordering tracks on real
hardware (the win is skipped work, not kernel speed).

    PYTHONPATH=src python -m benchmarks.paged_kv [--json PATH]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .common import dump_json, emit, record_run

SLOTS = 4
CAP = 64            # per-request KV capacity (tokens)
PS = 8              # page size (tokens)
PREFIX = 40         # shared prompt prefix (5 full pages)
SUFFIX = 8          # unique per-request tail
NEW = 12            # decode budget per request
REQUESTS = 6


def _prompts(vocab: int):
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, vocab, PREFIX)
    return [np.concatenate([prefix, rng.integers(0, vocab, SUFFIX)])
            .astype(np.int32) for _ in range(REQUESTS)], prefix


def serve_fleet(kv_paged: bool):
    """One scheduler run over the shared-prefix fleet; returns
    (engine, outputs, RunStats)."""
    from repro.config import get_config, reduced
    from repro.serving import build

    cfg = reduced(get_config("mixtral-8x7b"))
    eng, sched = build(cfg, cache=dict(policy="lru"),
                       serving=dict(max_batch=SLOTS, capacity=CAP,
                                    prefill_chunk=PS, kv_paged=kv_paged,
                                    page_size=PS),
                       seed=0)
    prompts, _ = _prompts(cfg.vocab_size)
    for p in prompts:
        sched.submit(p, max_new_tokens=NEW)
    outs = sched.run()
    return eng, outs, sched.stats


def ttft_probe():
    """Cold vs prefix-hit admission latency through the engine
    primitives. Returns (cold_s, hit_s, cold_chunks, hit_chunks)."""
    from repro.config import get_config, reduced
    from repro.serving import build

    cfg = reduced(get_config("mixtral-8x7b"))
    eng, _ = build(cfg, serving=dict(max_batch=2, capacity=CAP,
                                     prefill_chunk=PS, kv_paged=True,
                                     page_size=PS),
                   seed=0)
    state = eng.init_slots()
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, PREFIX)
    holder = np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, SUFFIX)]).astype(np.int32)
    # admit the prefix holder: bind_slot registers its full-page prompt
    # prefixes in the pool's index, making later admissions shareable
    tkt = eng.start_prefill(holder, max_total_tokens=holder.size + 1)
    eng.advance_prefill(tkt, tkt.n_chunks)
    eng.bind_slot(state, tkt, 0)

    hit_p = np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, SUFFIX)]).astype(np.int32)
    cold_p = rng.integers(0, cfg.vocab_size, holder.size).astype(np.int32)

    def probe(p):
        t0 = time.perf_counter()
        t = eng.start_prefill(p, max_total_tokens=p.size + 1)
        replayed = t.n_chunks - t.cursor
        eng.advance_prefill(t, t.n_chunks)
        jax.block_until_ready(t.logits)
        dt = time.perf_counter() - t0
        eng.kv_pool.free(t.table)   # probe only: never bound to a slot
        return dt, replayed

    probe(cold_p), probe(hit_p)               # compile both paths
    cold = [probe(cold_p) for _ in range(5)]
    hit = [probe(hit_p) for _ in range(5)]
    return (min(d for d, _ in cold), min(d for d, _ in hit),
            cold[0][1], hit[0][1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    args, _ = ap.parse_known_args()

    print("=== paged KV pool: prefix sharing vs dense per-slot cache ===")
    eng_d, outs_d, s_d = serve_fleet(kv_paged=False)
    eng_p, outs_p, s_p = serve_fleet(kv_paged=True)
    record_run("paged_kv.dense", s_d)
    record_run("paged_kv.paged", s_p)

    # 1) bit-identity: paging must never change the generated tokens
    assert sorted(outs_d) == sorted(outs_p)
    for rid in outs_d:
        np.testing.assert_array_equal(outs_d[rid], outs_p[rid])

    # 2) footprint: a fleet sharing a 5-page prefix must peak strictly
    #    below the dense-equivalent (every resident slot paying CAP/PS
    #    pages of private storage)
    pool = eng_p.kv_pool
    dense_eq = SLOTS * (CAP // PS)
    emit("paged_kv.peak_pages", float(pool.peak_pages_in_use),
         f"dense_equivalent={dense_eq} "
         f"prefix_hits={s_p.prefix_hits} "
         f"shared_tokens={pool.prefix_tokens_shared} "
         f"cow_forks={s_p.cow_forks}")
    assert pool.peak_pages_in_use < dense_eq, \
        ("prefix sharing must beat dense-equivalent page count",
         pool.peak_pages_in_use, dense_eq)
    assert s_p.prefix_hits >= 1, "shared-prefix fleet saw no prefix hits"
    assert pool.pages_in_use == 0, \
        ("drained fleet must return every page", pool.pages_in_use)
    pool.check_invariants()

    # 3) TTFT: a prefix-hit admission skips the shared span's warm replay
    cold_s, hit_s, cold_chunks, hit_chunks = ttft_probe()
    emit("paged_kv.ttft_cold_us", cold_s * 1e6,
         f"warm_chunks={cold_chunks}")
    emit("paged_kv.ttft_prefix_hit_us", hit_s * 1e6,
         f"warm_chunks={hit_chunks} "
         f"speedup={cold_s / max(hit_s, 1e-12):.2f}x")
    assert hit_chunks < cold_chunks, \
        ("prefix hit must skip shared-span warm chunks",
         hit_chunks, cold_chunks)
    assert hit_s < cold_s, \
        ("prefix-hit admission must be strictly faster", hit_s, cold_s)

    if args.json:
        dump_json(args.json)


if __name__ == "__main__":
    main()
