"""Paper Fig. 2: expert-selection patterns (Consecutive Layers /
Consecutive Tokens) — measured on the calibrated trace process and
checked against the paper's reported bands for Mixtral 8x7B:

  * Consecutive Tokens: P(>=1 of top-2 repeats from t-1) in 40-60%/layer
  * Consecutive Layers: ~44% same-id overlap with the previous layer
  * run persistence: ~23% (t-2) / ~18% (t-3+) among repeating tokens
  * baseline: chance overlap for E=8, K=2 is already 46.4% — reported so
    the stickiness the cache exploits is visible above chance.
"""
from __future__ import annotations

from math import comb

from repro.core import TraceConfig, synthetic_trace, trace_stats
from .common import emit


def chance_overlap(E: int, K: int) -> float:
    return 1.0 - comb(E - K, K) / comb(E, K)


def main() -> None:
    print("=== Fig. 2: router selection patterns ===")
    for name, E, stick in (("mixtral-8x7b", 8, 0.10), ("phi35-moe", 16, 0.50)):
        tc = TraceConfig(num_tokens=2000, num_layers=32, num_experts=E,
                         stickiness=stick)
        s = trace_stats(synthetic_trace(tc))
        ch = chance_overlap(E, 2)
        emit(f"{name}.consec_token_repeat", s["consec_token_repeat_mean"] * 1e6,
             f"range=[{s['consec_token_repeat_min']:.3f},"
             f"{s['consec_token_repeat_max']:.3f}] paper_band=[0.40,0.60] "
             f"chance={ch:.3f}")
        emit(f"{name}.consec_layer_repeat", s["consec_layer_repeat"] * 1e6,
             "paper~0.44 (mixtral)")
        emit(f"{name}.persist_t2|repeat", s["persist_t2_given_repeat"] * 1e6,
             "paper~0.23 (mixtral)")
        emit(f"{name}.persist_t3|repeat", s["persist_t3_given_repeat"] * 1e6,
             "paper~0.18 (mixtral)")
        if name == "mixtral-8x7b":
            assert 0.40 <= s["consec_token_repeat_min"] and \
                s["consec_token_repeat_max"] <= 0.65
            assert 0.35 <= s["consec_layer_repeat"] <= 0.60


if __name__ == "__main__":
    main()
