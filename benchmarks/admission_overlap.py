"""Admission head-of-line-blocking microbenchmark: overlapped
chunk-interleaved prefill on/off.

Serves a small batch of *established* short-prompt requests through the
continuous-batching scheduler, then admits a LONG-prompt newcomer
mid-stream and measures the established requests' inter-token latency
around the admission — the paper-regime pathology this repo's PR 5 fixes.
With synchronous admission (``admit_chunks_per_tick=0``) the newcomer's
whole cache-warming replay runs on the admission tick, stalling every
in-flight decode for the full prompt; with overlapped admission the slot
sits in the PREFILLING phase and replays at most one chunk per tick
between decode steps, so the established streams keep flowing.

Reported per mode: p50/p99 established inter-token latency and the
*stall* (max established inter-token gap, i.e. the admission tick).
Self-checks:
  * established requests' decode tokens are BIT-identical between the
    overlapped and the synchronous path (warming pace never touches
    numerics) — and so are the newcomer's;
  * the median-over-repeats stall is strictly lower with overlap on.

    PYTHONPATH=src python -m benchmarks.admission_overlap [--json PATH]
        [--repeats 2] [--long-prompt 48] [--chunk 4]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import dump_json, emit, record_run

SLOTS = 3
ESTABLISHED = 2
EST_PROMPT = 6
EST_TOKENS = 24
NEW_TOKENS = 4


def serve_once(admit_chunks: int, long_prompt: int, chunk: int, seed: int):
    """One admission episode. Returns (established outputs {rid: tokens},
    newcomer tokens, established inter-token gaps [s] from the admission
    window, RunStats)."""
    from repro.config import get_config, reduced
    from repro.serving import build

    cfg = reduced(get_config("mixtral-8x7b"))
    _, sched = build(cfg,
                     serving=dict(max_batch=SLOTS,
                                  capacity=long_prompt + NEW_TOKENS + 8,
                                  prefill_chunk=chunk,
                                  admit_chunks_per_tick=admit_chunks),
                     seed=seed)
    rng = np.random.default_rng(seed)
    stamps = {}

    def stamp(rid):
        return lambda tok, done: stamps[rid].append(time.perf_counter())

    est = []
    for _ in range(ESTABLISHED):
        r = sched.submit(rng.integers(0, cfg.vocab_size, EST_PROMPT),
                         max_new_tokens=EST_TOKENS)
        stamps[r.rid] = []
        r.on_token = stamp(r.rid)
        est.append(r)

    # establish + warm the compile caches (prefill trace, warm chunk,
    # decode step) before any timing: the first ticks pay tracing/lowering
    for _ in range(6):
        sched.step()
    t_submit = time.perf_counter()
    newcomer = sched.submit(rng.integers(0, cfg.vocab_size, long_prompt),
                            max_new_tokens=NEW_TOKENS)
    outs = sched.run()
    stats = sched.stats

    gaps = []
    for r in est:
        # anchor the window at the submit instant: the first gap is then
        # exactly the established request's wait across the admission
        # tick (prefill trace + however much warm replay the mode runs)
        ts = [t_submit] + [t for t in stamps[r.rid] if t >= t_submit]
        gaps += list(np.diff(ts))
    return ({r.rid: outs[r.rid] for r in est}, outs[newcomer.rid],
            np.asarray(gaps), stats)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--long-prompt", type=int, default=48)
    ap.add_argument("--chunk", type=int, default=4)
    args, _ = ap.parse_known_args()
    n_chunks = -(-args.long_prompt // args.chunk)

    print(f"=== admission overlap: {ESTABLISHED} established requests, "
          f"{args.long_prompt}-token prompt admits mid-stream "
          f"({n_chunks} warm chunks) ===")
    stalls = {0: [], 1: []}
    gaps_all = {0: [], 1: []}
    last = {}
    for rep in range(args.repeats):
        for admit in (0, 1):
            est, new, gaps, stats = serve_once(
                admit, args.long_prompt, args.chunk, seed=rep)
            stalls[admit].append(float(gaps.max()))
            gaps_all[admit] += list(gaps)
            last[admit] = (est, new, stats)

    for admit, name in ((0, "off"), (1, "on")):
        g = np.asarray(gaps_all[admit])
        stall = float(np.median(stalls[admit]))
        emit(f"admission_overlap.inter_token_p50.{name}",
             float(np.percentile(g, 50)) * 1e6,
             f"established inter-token p50 (overlap {name})")
        emit(f"admission_overlap.inter_token_p99.{name}",
             float(np.percentile(g, 99)) * 1e6,
             f"established inter-token p99 (overlap {name})")
        emit(f"admission_overlap.stall.{name}", stall * 1e6,
             f"max established inter-token gap during admission "
             f"(median of {args.repeats} repeats)")
        record_run(f"admission_overlap.{name}", last[admit][2])

    # self-check 1: overlapping the warm replay never changes tokens —
    # established AND newcomer decode bit-identical to synchronous
    est_off, new_off, _ = last[0]
    est_on, new_on, _ = last[1]
    assert sorted(est_on) == sorted(est_off)
    for rid in est_off:
        np.testing.assert_array_equal(est_on[rid], est_off[rid])
    np.testing.assert_array_equal(new_on, new_off)
    print("[self-check OK] established + newcomer tokens bit-identical "
          "(overlap on vs off)")

    # self-check 2: the head-of-line stall really shrank — the admission
    # tick no longer carries the whole warm replay
    stall_off = float(np.median(stalls[0]))
    stall_on = float(np.median(stalls[1]))
    assert stall_on < stall_off, \
        ("overlapped admission must lower the established-request stall",
         stall_on, stall_off)
    print(f"[self-check OK] admission stall {stall_off * 1e3:.1f} -> "
          f"{stall_on * 1e3:.1f} ms "
          f"({(1 - stall_on / max(stall_off, 1e-12)) * 100:.0f}% lower)")
    if args.json:
        dump_json(args.json)


if __name__ == "__main__":
    main()
