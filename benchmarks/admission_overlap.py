"""Admission head-of-line-blocking microbenchmark: synchronous vs
overlapped chunk-interleaved prefill vs segment-streamed prefill.

Serves a small batch of *established* short-prompt requests through the
continuous-batching scheduler, then admits a LONG-prompt newcomer
mid-stream and measures the established requests' inter-token latency
around the admission — the paper-regime pathology this repo's PR 5 fixes.
With synchronous admission (``admit_chunks_per_tick=0``) the newcomer's
whole cache-warming replay runs on the admission tick, stalling every
in-flight decode for the full prompt; with overlapped admission the slot
sits in the PREFILLING phase and replays at most one chunk per tick
between decode steps — but the full-prompt prefill FORWARD still runs on
the admission tick. Segment-streamed prefill (``prefill_segment``)
removes that last O(prompt) step too: the admission tick only allocates,
and each tick forwards ONE segment (KV append + cache warm fused), so
the worst established-request gap is bounded by a segment.

Reported per mode (off / on / seg): TTFT/TPOT/stall p50/p99 from the
scheduler's streaming log-bucket histograms (``RunStats`` carries them —
no ad-hoc percentile math over collected gap lists) and the *stall* (max
established inter-token gap, i.e. the admission tick, which the
self-checks gate on). A second episode measures prefix-skip TTFT: under paged
KV + retention, a repeat admission of an identical prompt skips the
shared span's forward outright — time-to-first-token and forwarded
tokens both drop, tokens stay identical.
Self-checks:
  * established requests' decode tokens are BIT-identical across all
    three modes (prefill pacing never touches numerics) — and so are
    the newcomer's;
  * the median-over-repeats stall is strictly lower with overlap on
    than off, and strictly lower again with segment streaming;
  * the prefix-hit admission forwards fewer tokens than the cold one
    and produces the identical output tokens.

    PYTHONPATH=src python -m benchmarks.admission_overlap [--json PATH]
        [--repeats 2] [--long-prompt 48] [--chunk 4]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import dump_json, emit, record_run

SLOTS = 3
ESTABLISHED = 2
EST_PROMPT = 6
EST_TOKENS = 24
NEW_TOKENS = 4
MODES = (("off", 0, 0), ("on", 1, 0), ("seg", 1, 1))
PREFIX_PROMPT = 32
PREFIX_TOKENS = 6


def serve_once(admit_chunks: int, long_prompt: int, chunk: int, seed: int,
               segment: int = 0):
    """One admission episode. Returns (established outputs {rid: tokens},
    newcomer tokens, established inter-token gaps [s] from the admission
    window, RunStats)."""
    from repro.config import get_config, reduced
    from repro.serving import build

    cfg = reduced(get_config("mixtral-8x7b"))
    _, sched = build(cfg,
                     serving=dict(max_batch=SLOTS,
                                  capacity=long_prompt + NEW_TOKENS + 8,
                                  prefill_chunk=chunk,
                                  prefill_segment=segment,
                                  admit_chunks_per_tick=admit_chunks),
                     seed=seed)
    rng = np.random.default_rng(seed)
    stamps = {}

    def stamp(rid):
        return lambda tok, done: stamps[rid].append(time.perf_counter())

    est = []
    for _ in range(ESTABLISHED):
        r = sched.submit(rng.integers(0, cfg.vocab_size, EST_PROMPT),
                         max_new_tokens=EST_TOKENS)
        stamps[r.rid] = []
        r.on_token = stamp(r.rid)
        est.append(r)

    # establish + warm the compile caches (prefill trace, warm chunk,
    # decode step) before any timing: the first ticks pay tracing/lowering
    for _ in range(6):
        sched.step()
    t_submit = time.perf_counter()
    newcomer = sched.submit(rng.integers(0, cfg.vocab_size, long_prompt),
                            max_new_tokens=NEW_TOKENS)
    outs = sched.run()
    stats = sched.stats

    gaps = []
    for r in est:
        # anchor the window at the submit instant: the first gap is then
        # exactly the established request's wait across the admission
        # tick (prefill trace + however much warm replay the mode runs)
        ts = [t_submit] + [t for t in stamps[r.rid] if t >= t_submit]
        gaps += list(np.diff(ts))
    return ({r.rid: outs[r.rid] for r in est}, outs[newcomer.rid],
            np.asarray(gaps), stats)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--long-prompt", type=int, default=48)
    ap.add_argument("--chunk", type=int, default=4)
    args, _ = ap.parse_known_args()
    n_chunks = -(-args.long_prompt // args.chunk)

    print(f"=== admission overlap: {ESTABLISHED} established requests, "
          f"{args.long_prompt}-token prompt admits mid-stream "
          f"({n_chunks} warm chunks / segments) ===")
    stalls = {name: [] for name, _, _ in MODES}
    last = {}
    for rep in range(args.repeats):
        for name, admit, seg in MODES:
            est, new, gaps, stats = serve_once(
                admit, args.long_prompt, args.chunk, seed=rep,
                segment=seg * args.chunk)
            stalls[name].append(float(gaps.max()))
            last[name] = (est, new, stats)

    for name, _, _ in MODES:
        stall = float(np.median(stalls[name]))
        stats = last[name][2]
        # percentiles from the scheduler's streaming log-bucket
        # histograms (last repeat) — RunStats carries them, replacing
        # the np.percentile math over hand-collected gap lists
        emit(f"admission_overlap.ttft_p50.{name}",
             stats.ttft_ms_p50 * 1e3,
             f"TTFT p50 (streaming histogram, mode {name}, "
             f"p99={stats.ttft_ms_p99 * 1e3:.0f}us)")
        emit(f"admission_overlap.tpot_p50.{name}",
             stats.tpot_ms_p50 * 1e3,
             f"inter-token p50 (streaming histogram, mode {name}, "
             f"p99={stats.tpot_ms_p99 * 1e3:.0f}us)")
        emit(f"admission_overlap.stall_p99.{name}",
             stats.stall_ms_p99 * 1e3,
             f"admission-work stall p99 absorbed by the decode loop "
             f"(streaming histogram, mode {name})")
        emit(f"admission_overlap.stall.{name}", stall * 1e6,
             f"max established inter-token gap during admission "
             f"(median of {args.repeats} repeats)")
        record_run(f"admission_overlap.{name}", stats)

    # self-check 1: prefill pacing never changes tokens — established
    # AND newcomer decode bit-identical across all three modes
    est_off, new_off, _ = last["off"]
    for name in ("on", "seg"):
        est_m, new_m, _ = last[name]
        assert sorted(est_m) == sorted(est_off)
        for rid in est_off:
            np.testing.assert_array_equal(est_m[rid], est_off[rid])
        np.testing.assert_array_equal(new_m, new_off)
    print("[self-check OK] established + newcomer tokens bit-identical "
          "(off vs on vs seg)")

    # self-check 2: the head-of-line stall really shrank — overlap moves
    # the warm replay off the admission tick, segment streaming moves
    # the prefill forward itself off it too
    stall_off = float(np.median(stalls["off"]))
    stall_on = float(np.median(stalls["on"]))
    stall_seg = float(np.median(stalls["seg"]))
    assert stall_on < stall_off, \
        ("overlapped admission must lower the established-request stall",
         stall_on, stall_off)
    assert stall_seg < stall_on, \
        ("segment-streamed prefill must lower the stall below the "
         "overlapped replay (the full-prompt forward left the admission "
         "tick)", stall_seg, stall_on)
    print(f"[self-check OK] admission stall {stall_off * 1e3:.1f} -> "
          f"{stall_on * 1e3:.1f} -> {stall_seg * 1e3:.1f} ms "
          f"(seg {(1 - stall_seg / max(stall_off, 1e-12)) * 100:.0f}% "
          f"below sync)")

    prefix_ttft(args)
    if args.json:
        dump_json(args.json)


def prefix_ttft(args) -> None:
    """Prefix-skip episode: paged KV + retention + segment streaming.

    Admits a PREFIX_PROMPT-token request cold, retires it, then admits
    the IDENTICAL prompt again — the prefix index serves the repeat from
    retained pages and the segment stream starts past the shared span,
    so only the last prompt token forwards. Measures time-to-first-token
    for both and self-checks: fewer forwarded prompt tokens, skipped
    tokens counted, identical output tokens."""
    from repro.config import get_config, reduced
    from repro.serving import build

    cfg = reduced(get_config("mixtral-8x7b"))
    cap = -(-(PREFIX_PROMPT + PREFIX_TOKENS + 8) // 4) * 4
    _, sched = build(cfg,
                     serving=dict(max_batch=2,
                                  capacity=cap,
                                  prefill_chunk=args.chunk,
                                  prefill_segment=args.chunk,
                                  admit_chunks_per_tick=1,
                                  kv_paged=True, page_size=4,
                                  prefix_keep_pages=64),
                     seed=0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, PREFIX_PROMPT)
    warmup = rng.integers(0, cfg.vocab_size, PREFIX_PROMPT)
    engine = sched.engine

    def admit_once(p):
        first = []
        t0 = time.perf_counter()
        req = sched.submit(p, max_new_tokens=PREFIX_TOKENS,
                           on_token=lambda tok, done:
                           first.append(time.perf_counter())
                           if not first else None)
        before = engine.stats.prefill_tokens
        outs = sched.run()
        return (first[0] - t0, outs[req.rid],
                engine.stats.prefill_tokens - before)

    # one throwaway admission (DIFFERENT prompt — it must not seed the
    # prefix index for the measured pair) warms the compile caches so
    # the cold/hit TTFT contrast measures work, not tracing
    admit_once(warmup)
    ttft_cold, out_cold, fwd_cold = admit_once(prompt)
    ttft_hit, out_hit, fwd_hit = admit_once(prompt)
    stats = engine.stats
    emit("admission_overlap.prefix_ttft.cold", ttft_cold * 1e6,
         f"TTFT, cold {PREFIX_PROMPT}-token prompt (segmented, paged)")
    emit("admission_overlap.prefix_ttft.hit", ttft_hit * 1e6,
         f"TTFT, identical prompt re-admitted (prefix pages retained)")
    record_run("admission_overlap.prefix", sched.stats)

    np.testing.assert_array_equal(out_cold, out_hit)
    assert fwd_hit < fwd_cold, \
        ("prefix hit must forward fewer prompt tokens", fwd_hit, fwd_cold)
    assert stats.prefix_tokens_skipped > 0
    print(f"[self-check OK] prefix skip: {fwd_cold} -> {fwd_hit} forwarded "
          f"prompt tokens, {stats.prefix_tokens_skipped} skipped, tokens "
          f"identical; TTFT {ttft_cold * 1e3:.1f} -> {ttft_hit * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
