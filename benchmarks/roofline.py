"""Roofline report: reads results/dryrun/*.json (written by the dry-run)
and renders the per-(arch x shape x mesh) three-term roofline table for
EXPERIMENTS.md — compute / memory / collective seconds, dominant term,
MODEL_FLOPS ratio, and a what-would-move-it note.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

NOTES = {
    ("memory", "train"): "raise arithmetic intensity: fuse/bf16 score "
                         "traffic, larger matmul tiles, less remat recompute",
    ("memory", "prefill"): "KV/score traffic dominates: bigger flash chunk, "
                           "bf16 intermediates",
    ("memory", "decode"): "weight+KV streaming bound (expected at batch<=128"
                          "): raise batch or quantize weights/KV",
    ("collective", "train"): "shrink ZeRO gathers (overlap with compute, "
                             "quantized collectives) or reshard",
    ("collective", "prefill"): "reshard attention/MoE boundary to cut "
                               "all-to-all/all-gather volume",
    ("collective", "decode"): "per-layer weight gathers dominate: cache "
                              "hot weights (EP cache) or widen TP",
    ("compute", "train"): "near roofline: tune remat policy / MXU tiling",
    ("compute", "prefill"): "near roofline: tune flash chunking",
    ("compute", "decode"): "compute-bound decode is unusual; check "
                           "wasted expert compute",
}


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def load(mesh: str = "16x16"):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        rows.append(d)
    return rows


def markdown_table(mesh: str = "16x16") -> str:
    """Render the roofline table as markdown (EXPERIMENTS.md appendix)."""
    rows = load(mesh)
    out = [f"**Mesh {mesh}** (per-device terms, TPU v5e peaks; "
           "mem = raw / TPU-adjusted GB):\n",
           "| arch | shape | compute_s | memory_s | collective_s | bound | "
           "MF/HLO | GB/dev | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("skipped"):
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — | "
                       f"— | skipped: full-attention long-context |")
            continue
        if not d.get("ok"):
            out.append(f"| {d['arch']} | {d['shape']} | FAILED "
                       f"{d.get('error','?')[:40]} |")
            continue
        r = d["roofline_s"]
        m = d["memory"]
        adj = m.get("tpu_adjusted_peak_gb", m["peak_per_device_gb"])
        note = NOTES.get((d["bottleneck"], kind_of(d["shape"])), "")
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['compute']:.2e} | "
            f"{r['memory']:.2e} | {r['collective']:.2e} | "
            f"{d['bottleneck']} | {d['useful_flops_ratio']:.3f} | "
            f"{m['peak_per_device_gb']:.1f}/{adj:.1f} | {note[:46]} |")
    return "\n".join(out)


def main() -> None:
    import sys
    if "--markdown" in sys.argv:
        for mesh in ("16x16", "2x16x16"):
            print(markdown_table(mesh))
            print()
        return
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        if not rows:
            print(f"(no dry-run results for {mesh} yet)")
            continue
        print(f"\n=== Roofline: mesh {mesh} "
              f"(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI) ===")
        hdr = (f"{'arch':<26s}{'shape':<12s}{'comp_s':>10s}{'mem_s':>10s}"
               f"{'coll_s':>10s}{'bound':>7s}{'MF/HLO':>7s}{'GB/dev':>8s} ok")
        print(hdr)
        for d in rows:
            if d.get("skipped"):
                print(f"{d['arch']:<26s}{d['shape']:<12s}"
                      f"{'— skipped (full-attention long-context)':>44s}")
                continue
            if not d.get("ok"):
                print(f"{d['arch']:<26s}{d['shape']:<12s}  FAILED: "
                      f"{d.get('error', '?')[:60]}")
                continue
            r = d["roofline_s"]
            print(f"{d['arch']:<26s}{d['shape']:<12s}"
                  f"{r['compute']:>10.3e}{r['memory']:>10.3e}"
                  f"{r['collective']:>10.3e}{d['bottleneck'][:6]:>7s}"
                  f"{d['useful_flops_ratio']:>7.3f}"
                  f"{d['memory']['peak_per_device_gb']:>8.2f}  "
                  f"{'Y' if d['ok'] else 'N'}")
        if mesh == "16x16":
            print("\nper-cell bottleneck notes:")
            for d in rows:
                if d.get("skipped") or not d.get("ok"):
                    continue
                note = NOTES.get((d["bottleneck"], kind_of(d["shape"])), "")
                print(f"  {d['arch']} x {d['shape']}: {d['bottleneck']}-bound"
                      f" -> {note}")


if __name__ == "__main__":
    main()
