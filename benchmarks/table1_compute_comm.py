"""Paper Table I / III: expert compute vs communication time.

Reports the paper's measured per-layer (top-2) times from the calibrated
cost model alongside first-principles derivations:
  * transfer time from expert bytes / effective PCIe bandwidth,
  * CPU compute time from expert FLOPs / (per-core GFLOPs * threads *
    measured parallel efficiency),
and the derived crossover (#threads where CPU beats PCIe fetch) — the
paper's Table-I insight that 2 CPU cores beat GPU offloading for Mixtral.
"""
from __future__ import annotations

from repro.config import get_config
from repro.core.costmodel import PAPER_TIMINGS, cpu_pair_ms, fetch_expert_ms
from .common import check, emit


def main() -> None:
    print("=== Table I/III: expert computation vs communication (ms) ===")
    for name, tm in PAPER_TIMINGS.items():
        cfg = get_config(name)
        expert_bytes = cfg.expert_bytes()
        flops_pair = 2 * 3 * cfg.d_model * cfg.moe.d_ff * tm.top_k

        # first-principles transfer: measured effective PCIe ~24 GB/s
        eff_bw = 24e9
        t_fetch_derived = tm.top_k * expert_bytes / eff_bw * 1e3
        emit(f"{name}.comm_pair_ms", tm.comm_pair_ms * 1e3,
             check("comm", t_fetch_derived, tm.comm_pair_ms, 0.15))

        for threads, ms in sorted(tm.cpu_pair_ms.items()):
            # Expert GEMV at batch 1 is DRAM-bandwidth-bound, not
            # FLOP-bound: time = pair weight bytes / bw(threads), with
            # bw(t) ~ 15.4 GB/s * t^0.72 saturating at ~93 GB/s
            # (the paper's own Table III data fits this curve; the 8/16-
            # thread points sit ~30% high — cross-CCD contention on the
            # 7960X — noted, tolerance 45%).
            bw = min(15.4e9 * threads ** 0.72, 93e9)
            derived = tm.top_k * expert_bytes / bw * 1e3
            emit(f"{name}.cpu_pair_ms.t{threads}", ms * 1e3,
                 check(f"cpu@{threads}", derived, ms, 0.45))

        # crossover: smallest thread count where CPU compute < PCIe fetch
        crossover = next((t for t in sorted(tm.cpu_pair_ms)
                          if cpu_pair_ms(tm, t) < tm.comm_pair_ms), None)
        emit(f"{name}.cpu_beats_pcie_at_threads", float(crossover or -1),
             f"paper: 2 threads suffice for Mixtral (got {crossover})")
        assert name != "mixtral-8x7b" or crossover == 2


if __name__ == "__main__":
    main()
