"""Host-execution on/off microbenchmark: compute cache-miss experts on
the CPU (repro.hostexec) vs fetching their weights to the device.

Times one jitted decode step of the batched collaborative engine (reduced
Mixtral geometry, 4-slot batch, shared LRU expert cache) with
``EngineConfig.host_compute`` off and on (the real ``callback`` backend —
numpy thread pool bridged via ``jax.pure_callback``), and reports the
dispatcher's split counters over a short greedy generation.

Interpret-mode wall time on this container is NOT the paper metric; the
carried number is the calibrated cost model's **per-step miss-handling
time**: what the step's misses cost when every one pays the weight
transfer (off) vs when the dispatcher routes the cost-model-favored
groups to the CPU (on). The self-check asserts the reduction is positive
whenever ``cpu_expert_ms(threads) < fetch_expert_ms`` — i.e. whenever the
paper's Table III says host execution should win — and that the
dispatcher then actually sent work to the host.

    PYTHONPATH=src python -m benchmarks.host_compute [--json PATH]
        [--threads 8]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import cpu_expert_ms, fetch_expert_ms, \
    gpu_expert_ms
from repro.hostexec import HostDispatchPolicy

from .common import dump_json, emit, record_run, timeit

SLOTS = 4
STEPS = 24


def bench(host_compute: bool, threads: int = 8, backend: str = "callback"):
    from repro.serving import build

    eng, _ = build("mixtral-8x7b",
                   serving=dict(max_batch=SLOTS, capacity=64,
                                host_compute=host_compute,
                                host_threads=threads,
                                host_backend=backend),
                   seed=0)
    cfg = eng.cfg

    # split-counter probe: short greedy generation through the decode path
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(0),
                                           (SLOTS, 8), 0,
                                           cfg.vocab_size), np.int32)
    _, stats = eng.generate(prompt, steps=STEPS)

    # step-latency probe: one jitted decode step, steady state
    state = eng.init_slots()
    state["pos"] = jnp.full((SLOTS,), 8, jnp.int32)
    tok = np.zeros((SLOTS, 1), np.int32)
    active = jnp.ones((SLOTS,), bool)

    def step():
        nonlocal state
        logits, state = eng.decode_batch(tok, state, active)
        jax.block_until_ready(logits)

    us = timeit(step, iters=10, warmup=3)
    return us, stats, eng


def miss_handling_ms(stats, policy: HostDispatchPolicy):
    """Cost-model miss-handling time per decode step, (off, on).

    off — every executed miss group pays the weight read and computes on
    the device: ``miss_expert_groups * fetch_expert_ms +
    miss_tokens * gpu_expert_ms``.
    on  — the same run with its CPU-dispatched groups re-priced on the
    host lane (activation round-trip + multithreaded FFN). Both are
    evaluated on ONE run's counters, so the delta is exactly the sum of
    the per-group savings the dispatcher's decision rule guarantees."""
    tm, thr = policy.timings, policy.threads
    steps = max(stats.steps, 1)
    off = stats.miss_expert_groups * fetch_expert_ms(tm) \
        + stats.host_assignments * gpu_expert_ms(tm)
    on = stats.cpu_expert_calls * tm.act_transfer_ms \
        + stats.cpu_tokens * cpu_expert_ms(tm, thr) \
        + (stats.miss_expert_groups - stats.cpu_expert_calls) \
        * fetch_expert_ms(tm) \
        + max(stats.host_assignments - stats.cpu_tokens, 0) \
        * gpu_expert_ms(tm)
    return off / steps, on / steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the results to this BENCH_*.json path")
    ap.add_argument("--threads", type=int, default=8,
                    help="host executor / cost-model thread count")
    args, _ = ap.parse_known_args()

    print("=== decode step: host execution of cache-miss experts on/off "
          "===")
    us_off, s_off, _ = bench(host_compute=False)
    us_on, s_on, eng = bench(host_compute=True, threads=args.threads)
    record_run("host_compute.off", s_off)
    record_run("host_compute.on", s_on)

    policy = eng.dispatch_policy
    tm = policy.timings
    ex = eng.host_executor
    emit("decode_step.host_compute_off", us_off,
         f"hit_rate={s_off.hit_rate:.3f} ({SLOTS}-slot batch, lru 2-way)")
    emit("decode_step.host_compute_on", us_on,
         f"hit_rate={s_on.hit_rate:.3f} overhead={us_on / us_off:.2f}x "
         f"cpu_calls={s_on.cpu_expert_calls} cpu_tokens={s_on.cpu_tokens} "
         f"offload_rate={s_on.cpu_offload_rate:.3f} "
         f"pool_groups={ex.groups if ex else 0}")

    ms_off, ms_on = miss_handling_ms(s_on, policy)
    emit("decode_step.miss_handling_ms_model", (ms_off - ms_on) * 1e3,
         f"cost-model miss handling {ms_off:.2f} -> {ms_on:.2f} ms/step "
         f"({tm.name}, {policy.threads} threads: "
         f"cpu_expert={cpu_expert_ms(tm, policy.threads):.2f}ms vs "
         f"fetch_expert={fetch_expert_ms(tm):.2f}ms)")

    # self-check: whenever the paper's measured timings say host execution
    # beats the weight transfer, the dispatcher must (a) route misses to
    # the CPU and (b) reduce the modeled per-step miss-handling time
    if cpu_expert_ms(tm, policy.threads) < fetch_expert_ms(tm):
        assert s_on.cpu_expert_calls > 0, \
            "cost model favors CPU but the dispatcher sent nothing to it"
        assert ms_on < ms_off, \
            ("host execution must reduce modeled miss handling",
             ms_on, ms_off)
        if ex is not None:
            # the pool really ran the dispatched groups. >= not ==: the
            # traced counter is exact, but pure_callback's contract
            # allows re-invocation, so the host-side telemetry is a
            # floor, not a ledger
            assert ex.groups >= eng.stats.cpu_expert_calls > 0, \
                ("pure_callback executor must have run the dispatched "
                 "groups", ex.groups, eng.stats.cpu_expert_calls)
        print(f"[self-check OK] miss handling {ms_off:.2f} -> "
              f"{ms_on:.2f} ms/step "
              f"({(1 - ms_on / max(ms_off, 1e-9)) * 100:.0f}% lower)")
    if args.json:
        dump_json(args.json)


if __name__ == "__main__":
    main()
