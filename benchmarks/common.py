"""Shared benchmark utilities: CSV emit + paper-value validation."""
from __future__ import annotations

import time
from typing import Optional


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def check(name: str, got: float, paper: float, tol: float) -> str:
    rel = abs(got - paper) / abs(paper) if paper else float("inf")
    status = "OK" if rel <= tol else "DIVERGES"
    return (f"{name}: ours={got:.3f} paper={paper:.3f} "
            f"rel_err={rel:.1%} [{status}]")


def timeit(fn, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6
