"""Shared benchmark utilities: CSV emit (with optional JSON capture for
the CI perf-trajectory artifacts), typed run-stats capture, paper-value
validation, and the live batched-scheduler probe used by the fig5/fig6
``--live`` modes."""
from __future__ import annotations

import json
import time
from typing import List, Optional

# every emit() lands in _RESULTS and every record_run() in _RUNS;
# dump_json() snapshots both for BENCH_*.json
_RESULTS: List[dict] = []
_RUNS: List[dict] = []


def run_live_scheduler(policy: str = "lru", slots: int = 4,
                       requests: int = 6, new_tokens: int = 12,
                       arch: str = "mixtral-8x7b", seed: int = 0,
                       prefetch: bool = False, prefetch_min_prob: float = 0.0,
                       prefill_chunk: int = 8, host_compute: bool = False,
                       host_threads: int = 8, host_backend: str = "jax",
                       recorder=None, **serving_overrides):
    """Serve `requests` random prompts through the continuous-batching
    scheduler on a reduced live model (one shared expert cache, grouped
    gmm execution, per-slot KV positions, cache-warming chunked prefill,
    optional cross-layer speculative prefetch). Extra keyword arguments
    pass straight into ``EngineConfig`` (e.g. ``kv_paged=True``,
    ``prefetch_rank_votes=False``); ``recorder`` wires a
    ``repro.obs.TraceRecorder`` through the stack (None = no-op). Returns
    (outputs, RunStats, wall_seconds)."""
    import numpy as np
    from repro.config import get_config, reduced
    from repro.serving import build

    cfg = reduced(get_config(arch))
    _, sched = build(cfg, cache=dict(policy=policy),
                     serving=dict(max_batch=slots, capacity=64,
                                  prefetch=prefetch,
                                  prefetch_min_prob=prefetch_min_prob,
                                  prefill_chunk=prefill_chunk,
                                  host_compute=host_compute,
                                  host_threads=host_threads,
                                  host_backend=host_backend,
                                  **serving_overrides),
                     seed=seed, recorder=recorder)
    rng = np.random.default_rng(seed)
    for _ in range(requests):
        sched.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9))),
                     max_new_tokens=new_tokens)
    # perf_counter throughout (time.time() is wall-clock and can step;
    # every other timing in benchmarks/ already uses the monotonic clock)
    t0 = time.perf_counter()
    outs = sched.run()
    return outs, sched.stats, time.perf_counter() - t0


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    _RESULTS.append({"name": name, "us": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def record_run(name: str, stats) -> None:
    """Capture one serving run's typed stats (``RunStats`` or
    ``EngineStats``) for the JSON artifact — the schema the
    tests/test_bench_schema.py contract pins."""
    _RUNS.append({"name": name, "stats": stats.to_json()})


def dump_json(path: str) -> None:
    """Write every emit() and record_run() of this process to ``path``
    (BENCH_*.json) so CI can archive the perf trajectory run over run.
    Schema: {"results": [{name, us, derived}], "runs": [{name, stats}]}
    where ``stats`` is ``RunStats.to_json()`` / ``EngineStats.to_json()``."""
    with open(path, "w") as f:
        json.dump({"results": _RESULTS, "runs": _RUNS}, f, indent=1)
    print(f"wrote {len(_RESULTS)} results / {len(_RUNS)} runs to {path}")


def check(name: str, got: float, paper: float, tol: float) -> str:
    rel = abs(got - paper) / abs(paper) if paper else float("inf")
    status = "OK" if rel <= tol else "DIVERGES"
    return (f"{name}: ours={got:.3f} paper={paper:.3f} "
            f"rel_err={rel:.1%} [{status}]")


def timeit(fn, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6
