#!/usr/bin/env python
"""Compile-count regression gate for the serving hot path.

The static rules (``python -m repro.analysis``) catch *patterns* that
cause recompilation; this harness catches the *fact* of it. It runs a
short two-request serve on the reduced model under ``jax_log_compiles``,
attributes every XLA compilation to a phase, and asserts the steady-state
decode phase triggers ZERO recompiles:

* ``warmup``     — engine build + request A served end-to-end: every
                   stage (chunked/segmented prefill, decode step, KV
                   writes, token selection) traces and compiles here.
* ``admission``  — request B submitted to the warm engine and ticked
                   until its first token: admission-geometry compiles
                   (a new prefill chunk/segment shape) land here and are
                   reported but allowed.
* ``steady``     — request B's remaining decode ticks: the
                   continuous-batching loop is geometry-stable by
                   design, so ANY compilation here is a regression (the
                   ragged-segment and paged-CSR paths are one stray
                   Python-int static argument away from per-step
                   recompiles) and fails the gate.

Run ``PYTHONPATH=src python tools/compile_gate.py`` (CI adds
``--json COMPILE_GATE.json`` and archives the attribution artifact; use
``--kv-paged`` / ``--prefill-segment`` to gate those paths too).
"""
from __future__ import annotations

import argparse
import json
import logging
import sys
from typing import List, Optional

PHASES = ("warmup", "admission", "steady")
_COMPILE_PREFIX = "Compiling "
_COMPILE_MARKER = " with global shapes"


class CompileLog(logging.Handler):
    """Captures ``jax_log_compiles`` records and stamps each compilation
    with the currently active serve phase."""

    def __init__(self):
        super().__init__(logging.DEBUG)
        self.phase = "warmup"
        self.events: List[dict] = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith(_COMPILE_PREFIX) and _COMPILE_MARKER in msg:
            fn = msg[len(_COMPILE_PREFIX):].split(_COMPILE_MARKER, 1)[0]
            self.events.append({"phase": self.phase, "fn": fn})

    def counts(self) -> dict:
        out = {p: 0 for p in PHASES}
        for e in self.events:
            out[e["phase"]] += 1
        return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="serve two requests under jax_log_compiles and fail "
                    "on any steady-state decode recompilation")
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--kv-paged", action="store_true")
    ap.add_argument("--prefill-segment", type=int, default=0,
                    metavar="C", help="segment-streamed prefill with "
                    "C-token segments (0 = replay prefill)")
    ap.add_argument("--json", default=None,
                    help="write the per-phase compile attribution here "
                         "(the CI artifact)")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_log_compiles", True)
    log = CompileLog()
    # jax 0.4.x emits "Compiling <fn> with global shapes and types [...]"
    # on this logger at WARNING when jax_log_compiles is set
    logging.getLogger("jax._src.interpreters.pxla").addHandler(log)
    # drop the per-compile "Finished tracing/compilation" timing spam the
    # same flag turns on — the gate only needs the Compiling records
    logging.getLogger("jax._src.dispatch").setLevel(logging.ERROR)

    import numpy as np
    from repro.config import get_config, reduced
    from repro.obs import TraceRecorder
    from repro.serving import build

    cfg = reduced(get_config(args.arch))
    serving = dict(max_batch=args.slots, capacity=64,
                   prefill_chunk=args.prefill_chunk)
    if args.kv_paged:
        serving.update(kv_paged=True)
    if args.prefill_segment:
        serving.update(prefill_segment=args.prefill_segment)
    # gate WITH tracing live: the obs drain helpers are host-only work,
    # so a recorder must never change what compiles (a trace-induced
    # recompile would show up here as a steady-phase failure)
    recorder = TraceRecorder()
    _, sched = build(cfg, cache=dict(policy="lru"), serving=serving,
                     seed=0, recorder=recorder)

    rng = np.random.default_rng(0)
    prompt_a = rng.integers(0, cfg.vocab_size, 6)
    prompt_b = rng.integers(0, cfg.vocab_size, 8)
    ticks = {p: 0 for p in PHASES}

    def tick_until(phase: str, done, limit: int = 400) -> None:
        while not done():
            if ticks[phase] >= limit:
                print(f"compile_gate: phase {phase!r} exceeded {limit} "
                      f"ticks without completing", file=sys.stderr)
                sys.exit(2)
            sched.step()
            ticks[phase] += 1

    # warmup: request A end-to-end — every stage compiles here
    sched.submit(prompt_a, max_new_tokens=args.new_tokens)
    tick_until("warmup", lambda: sched.stats.requests_finished >= 1)

    # admission: request B enters the warm engine, up to its first token
    log.phase = "admission"
    sched.submit(prompt_b, max_new_tokens=args.new_tokens)
    first = sched.stats.first_tokens
    tick_until("admission", lambda: sched.stats.first_tokens > first)

    # steady: request B's remaining decode — must be compile-free
    log.phase = "steady"
    tick_until("steady", lambda: sched.stats.requests_finished >= 2)

    counts = log.counts()
    report = {
        "config": {"arch": args.arch, "slots": args.slots,
                   "new_tokens": args.new_tokens,
                   "prefill_chunk": args.prefill_chunk,
                   "kv_paged": args.kv_paged,
                   "prefill_segment": args.prefill_segment,
                   "traced": True},
        "trace_events": len(recorder),
        "ticks": ticks,
        "counts": counts,
        "events": log.events,
        "ok": counts["steady"] == 0 and ticks["steady"] > 0,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
            f.write("\n")

    for phase in PHASES:
        fns = [e["fn"] for e in log.events if e["phase"] == phase]
        print(f"compile_gate: {phase}: {len(fns)} compilation(s) over "
              f"{ticks[phase]} tick(s)"
              + (f" — {', '.join(sorted(set(fns)))}" if fns else ""))

    if ticks["steady"] == 0:
        print("compile_gate: FAIL — steady phase ran zero decode ticks "
              "(nothing was gated)", file=sys.stderr)
        return 2
    if counts["steady"]:
        print(f"compile_gate: FAIL — {counts['steady']} recompilation(s) "
              f"in steady-state decode; the hot loop must be "
              f"geometry-stable", file=sys.stderr)
        return 1
    print("compile_gate: OK — zero steady-state decode recompilations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
