"""reprolint tests: each rule against its bad/good fixture tree, baseline
round-trips, CLI smoke, and the repo-is-clean self-check that keeps the
checked-in baseline honest."""
from pathlib import Path

import pytest

from repro.analysis.baseline import (BASELINE_NAME, load_baseline,
                                     save_baseline, split_findings)
from repro.analysis.cli import main, run_rules
from repro.analysis.core import RULES, load_project

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"


def run_fixture(name, rule_id):
    project = load_project(FIXTURES / name)
    return RULES[rule_id].run(project)


def lines(findings):
    return {(f.file, f.line) for f in findings}


# -- RL001 tracer leaks ------------------------------------------------------

def test_rl001_bad_fixture():
    found = run_fixture("rl001_bad", "RL001")
    assert all(f.rule == "RL001" for f in found)
    assert lines(found) == {
        ("src/repro/serving/engine.py", 6),    # interprocedural taint
        ("src/repro/serving/engine.py", 17),   # if on traced value
        ("src/repro/serving/engine.py", 19),   # float() concretization
        ("src/repro/serving/engine.py", 20),   # while on traced value
    }


def test_rl001_good_fixture():
    assert run_fixture("rl001_good", "RL001") == []


# -- RL002 host syncs in hot path --------------------------------------------

def test_rl002_bad_fixture():
    found = run_fixture("rl002_bad", "RL002")
    assert lines(found) == {
        ("src/repro/serving/scheduler.py", 10),
        ("src/repro/serving/scheduler.py", 11),
        ("src/repro/serving/scheduler.py", 12),
        ("src/repro/serving/scheduler.py", 13),
    }


def test_rl002_good_fixture():
    assert run_fixture("rl002_good", "RL002") == []


# -- RL003 donated-buffer reuse ----------------------------------------------

def test_rl003_bad_fixture():
    found = run_fixture("rl003_bad", "RL003")
    assert lines(found) == {("src/repro/serving/engine.py", 14)}
    assert "donate" in found[0].message


def test_rl003_good_fixture():
    assert run_fixture("rl003_good", "RL003") == []


# -- RL004 callback purity ---------------------------------------------------

def test_rl004_bad_fixture():
    found = run_fixture("rl004_bad", "RL004")
    assert lines(found) == {
        ("src/repro/hostexec/executor.py", 12),
        ("src/repro/hostexec/executor.py", 13),
    }


def test_rl004_good_fixture():
    assert run_fixture("rl004_good", "RL004") == []


# -- RL005 kernel/ref twins --------------------------------------------------

def test_rl005_bad_fixture():
    found = run_fixture("rl005_bad", "RL005")
    by_pkg = {f.symbol: f for f in found}
    assert set(by_pkg) == {"foo", "bar"}
    assert "no ref.py" in by_pkg["foo"].message
    assert "no test importing its ref twin" in by_pkg["bar"].message


def test_rl005_good_fixture():
    assert run_fixture("rl005_good", "RL005") == []


# -- RL006 schema drift ------------------------------------------------------

def test_rl006_bad_fixture():
    found = run_fixture("rl006_bad", "RL006")
    msgs = {(f.file, f.line): f.message for f in found}
    assert set(msgs) == {
        ("src/repro/serving/stats.py", 8),        # unpinned new_counter
        ("tests/test_bench_schema.py", 1),        # stale ghost_key pin
        ("benchmarks/fig9_latency.py", 9),        # uncovered record_run
    }
    assert "new_counter" in msgs[("src/repro/serving/stats.py", 8)]
    assert "ghost_key" in msgs[("tests/test_bench_schema.py", 1)]
    assert "fig9_latency" in msgs[("benchmarks/fig9_latency.py", 9)]


def test_rl006_good_fixture():
    assert run_fixture("rl006_good", "RL006") == []


# -- RL007 trace emission outside drain points -------------------------------

def test_rl007_bad_fixture():
    found = run_fixture("rl007_bad", "RL007")
    assert all(f.rule == "RL007" for f in found)
    assert lines(found) == {
        ("src/repro/serving/scheduler.py", 15),   # instant in hot entry
        ("src/repro/serving/scheduler.py", 16),   # span in hot entry
        ("src/repro/serving/scheduler.py", 21),   # counter, hot-reachable
        ("src/repro/serving/scheduler.py", 24),   # complete under tracing
        ("src/repro/serving/scheduler.py", 28),   # instant in callback lane
    }


def test_rl007_good_fixture():
    assert run_fixture("rl007_good", "RL007") == []


def test_rl007_allow_comment_suppresses():
    project = load_project(FIXTURES / "rl007_good")
    src = project.get("src/repro/serving/scheduler.py")
    assert any("reprolint: allow[RL007]" in line for line in src.lines)
    assert run_rules(project, only=["RL007"]) == []


# -- suppression comments ----------------------------------------------------

def test_allow_comment_suppresses_only_named_rule():
    project = load_project(FIXTURES / "rl002_good")
    src = project.get("src/repro/serving/scheduler.py")
    allowed = [line for line in src.lines if "reprolint: allow" in line]
    assert allowed, "good fixture must exercise a suppression comment"
    assert run_rules(project, only=["RL002"]) == []


# -- baseline round-trip and staleness ---------------------------------------

def test_baseline_round_trip_and_split(tmp_path):
    found = run_fixture("rl002_bad", "RL002")
    assert found
    path = tmp_path / BASELINE_NAME

    # no baseline file: everything is new
    new, old, stale = split_findings(found, load_baseline(path))
    assert (len(new), old, stale) == (len(found), [], [])

    # full baseline: everything grandfathered, nothing stale
    save_baseline(path, found)
    new, old, stale = split_findings(found, load_baseline(path))
    assert (new, len(old), stale) == ([], len(found), [])

    # finding fixed but still in the ledger: reported as stale
    new, old, stale = split_findings(found[1:], load_baseline(path))
    assert new == [] and len(old) == len(found) - 1
    assert stale == [found[0].key()]


def test_baseline_keys_are_line_number_free():
    f = run_fixture("rl003_bad", "RL003")[0]
    assert f.line not in f.key()
    assert f.key() == (f.rule, f.file, f.symbol, f.message)


# -- CLI ---------------------------------------------------------------------

def test_cli_list_and_explain(capsys):
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for rule_id in ("RL001", "RL002", "RL003", "RL004", "RL005", "RL006",
                    "RL007"):
        assert rule_id in out
    assert main(["--explain", "RL001"]) == 0
    assert "RL001" in capsys.readouterr().out
    assert main(["--explain", "RL999"]) == 2


def test_cli_fails_on_bad_fixture_and_passes_on_good(capsys):
    bad = FIXTURES / "rl001_bad"
    assert main(["--root", str(bad), "--rules", "RL001"]) == 1
    out = capsys.readouterr().out
    assert "src/repro/serving/engine.py:17 RL001" in out

    good = FIXTURES / "rl001_good"
    assert main(["--root", str(good), "--rules", "RL001"]) == 0


def test_cli_update_baseline_then_clean(tmp_path, capsys):
    bad = FIXTURES / "rl001_bad"
    baseline = tmp_path / BASELINE_NAME
    assert main(["--root", str(bad), "--rules", "RL001",
                 "--baseline", str(baseline), "--update-baseline"]) == 0
    capsys.readouterr()
    assert main(["--root", str(bad), "--rules", "RL001",
                 "--baseline", str(baseline)]) == 0


def test_cli_json_report(tmp_path, capsys):
    import json
    report = tmp_path / "report.json"
    assert main(["--root", str(FIXTURES / "rl002_bad"), "--rules", "RL002",
                 "--json", str(report)]) == 1
    capsys.readouterr()
    data = json.loads(report.read_text())
    assert len(data["new"]) == 4
    assert data["grandfathered"] == [] and data["stale_baseline"] == []


# -- the real repo must be clean against its checked-in baseline -------------

def test_repo_is_clean_with_baseline():
    """The self-check ISSUE.md asks for: running every rule over the real
    tree must produce no finding outside the checked-in baseline, and no
    stale baseline entry. Fails if a defect lands OR if a grandfathered
    finding is fixed without retiring its ledger line."""
    project = load_project(REPO)
    findings = run_rules(project)
    baseline = load_baseline(REPO / BASELINE_NAME)
    new, _old, stale = split_findings(findings, baseline)
    assert not new, "new reprolint findings:\n" + "\n".join(
        f.render() for f in new)
    assert not stale, "stale baseline entries (retire them):\n" + "\n".join(
        "\t".join(k) for k in stale)
