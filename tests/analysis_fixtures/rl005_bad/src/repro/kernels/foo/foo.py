"""RL005 bad fixture: Pallas kernel package without a ref.py twin."""
from jax.experimental import pallas as pl


def kernel(x):
    return pl.pallas_call(lambda x_ref, o_ref: None, out_shape=None)(x)
