"""jnp twin for the bar kernel (present — the missing piece is the test)."""


def kernel_ref(x):
    return x
