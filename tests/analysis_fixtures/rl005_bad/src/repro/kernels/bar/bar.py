"""RL005 bad fixture: ref twin exists but no bitwise parity test."""
from jax.experimental import pallas as pl


def kernel(x):
    return pl.pallas_call(lambda x_ref, o_ref: None, out_shape=None)(x)
