import numpy as np

from repro.kernels.bar import ref
from repro.kernels.bar.bar import kernel


def test_bar_bitwise_matches_ref_twin():
    x = np.ones((4,))
    assert np.array_equal(kernel(x), ref.kernel_ref(x))
