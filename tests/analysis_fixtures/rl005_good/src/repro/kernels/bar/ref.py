"""jnp twin for the bar kernel."""


def kernel_ref(x):
    return x
