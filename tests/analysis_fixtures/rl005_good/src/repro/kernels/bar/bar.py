"""RL005 good fixture: Pallas kernel with ref twin + bitwise test."""
from jax.experimental import pallas as pl


def kernel(x):
    return pl.pallas_call(lambda x_ref, o_ref: None, out_shape=None)(x)
