"""RL007 bad fixture: trace emission in the hot path / jitted graph /
pure_callback lane."""
import jax
import jax.numpy as jnp

from repro.obs.trace import now_ns


class Sched:
    def __init__(self):
        self._decode = jax.jit(self._decode_step)

    def _tick(self):
        t0 = now_ns()
        self.obs.instant("sched", "tick")        # emission in the hot entry
        with self.obs.span("sched", "phase"):    # span() is emission too
            self._step_phase()
        return t0

    def _step_phase(self):
        self.obs.counter("sched", "depth", 1)    # hot-reachable emission

    def _decode_step(self, x):
        self.obs.complete("engine", "mm", 0, 1)  # emission under tracing
        return jnp.sum(x)

    def _lane(self, x):
        self.obs.instant("lane", "cb")           # pure_callback lane emission
        return x

    def dispatch(self, x):
        return jax.pure_callback(self._lane, x, x)
