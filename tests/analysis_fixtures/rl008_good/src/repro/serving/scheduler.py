"""RL008 good fixture: claim/release pair split across functions — the
component-mode contract (the release exists somewhere in the project)."""


class Scheduler:
    def admit(self, ticket, slot):
        self.engine.claim_slot(ticket, slot)
        self.slots[slot] = ticket

    def retire(self, slot):
        self.engine.release_slot(slot)
        self.slots[slot] = None
