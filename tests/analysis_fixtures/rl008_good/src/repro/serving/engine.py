"""RL008 good fixture: every acquire is released or handed off on every
path, exception paths included."""


class Engine:
    def guarded(self):
        # the canonical admission pattern: hand off through a wrapper,
        # release in the handler behind the None-guard (the guard's
        # else-arm is pruned by the rule's None-ness path-sensitivity)
        table, shared = self.kv_pool.alloc_prompt(self.prompt, 8)
        try:
            return self.open_ticket(table)
        except BaseException:
            if table is not None:
                self.kv_pool.free(table)
            raise

    def finally_release(self):
        # a finally-block release discharges the normal AND the
        # exceptional route out of the audit
        table, shared = self.kv_pool.alloc_prompt(self.prompt, 8)
        try:
            self.audit(table.pages)
        finally:
            self.kv_pool.free(table)

    def open_ticket(self, table):
        # keeps the resource: stores it into self before anything can
        # raise, so callers' hand-off completes atomically
        self._tables[0] = table
        return table

    def caller_stores(self):
        # inherits no live obligation: the wrapper's result is stored
        # into self immediately
        table = self.alloc_wrap()
        self._tables[1] = table

    def alloc_wrap(self):
        table, shared = self.kv_pool.alloc_prompt(self.prompt, 8)
        return table

    def sequence_lands(self, slot):
        plan = self.kv_pool.prepare_append(slot)
        self.log(plan)
        self.kv_pool.commit_append(plan)
