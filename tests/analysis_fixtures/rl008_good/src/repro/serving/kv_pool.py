"""Provider stub (exempt from RL008 — it implements the lifecycle)."""


class KVPagePool:
    def alloc_prompt(self, prompt, total):
        table = object()
        return table, 0

    def prepare_append(self, slot):
        return [slot]

    def commit_append(self, plan):
        pass

    def free(self, table):
        pass
