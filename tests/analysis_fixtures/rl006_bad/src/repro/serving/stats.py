"""RL006 bad fixture: EngineStats field not pinned; test pins a stale key."""
from dataclasses import dataclass


@dataclass
class EngineStats:
    decode_steps: int = 0
    new_counter: int = 0  # not pinned in test_bench_schema.py -> finding


@dataclass
class RunStats:
    wall_s: float = 0.0
