"""RL006 bad fixture: emits a benchmark record with no schema-test coverage."""


def record_run(name, payload):
    return name, payload


def main():
    record_run("fig9.latency", {"wall_s": 1.0})
