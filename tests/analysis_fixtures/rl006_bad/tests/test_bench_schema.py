ENGINE_KEYS = {
    "decode_steps",
    "ghost_key",  # not an EngineStats field -> stale-pin finding
}

RUN_KEYS = {"wall_s"}
