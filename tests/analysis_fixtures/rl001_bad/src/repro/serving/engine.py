"""RL001 bad fixture: tracer leaks inside jit-reachable code."""
import jax


def _helper(mask):
    if mask.any():                      # line 6: leak via call taint
        return 1
    return 0


class Engine:
    def __init__(self):
        self._decode = jax.jit(self._decode_step,
                               static_argnames=("greedy",))

    def _decode_step(self, tokens, state, greedy=True):
        if tokens.sum() > 0:            # line 17: `if` on traced value
            state = state + 1
        scale = float(tokens.mean())    # line 19: float() concretizes
        while state > 0:                # line 20: `while` on traced value
            state = state - 1
        flag = _helper(tokens > 0)      # taints _helper's `mask`
        return state * scale + flag
