"""RL011 good fixture: every field reachable from a surface."""
from dataclasses import dataclass


@dataclass
class EngineConfig:
    max_batch: int = 4
    page_size: int = 16
