"""RL011 good fixture: every flag consumed, every field wired."""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()
    print(dict(max_batch=args.max_batch, page_size=args.page_size))


if __name__ == "__main__":
    main()
