"""RL011 bad fixture: an EngineConfig field no surface mentions."""
from dataclasses import dataclass


@dataclass
class EngineConfig:
    max_batch: int = 4
    page_size: int = 16
    secret_knob: int = 3
