"""RL011 bad fixture: a parsed-but-never-read CLI flag."""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dead-flag", type=int, default=0)
    args = ap.parse_args()
    print(args.page_size)


if __name__ == "__main__":
    main()
