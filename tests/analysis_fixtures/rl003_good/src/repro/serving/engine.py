"""RL003 good fixture: the threading idiom rebinds the donated name."""
import jax


class Engine:
    def __init__(self):
        self._decode = jax.jit(self._decode_step, donate_argnums=(1,))

    def _decode_step(self, tokens, state):
        return tokens, state + 1

    def step(self, tokens, state):
        logits, state = self._decode(tokens, state)   # rebind clears it
        return logits + state.mean()
