"""RL001 good fixture: static-at-trace-time control flow only."""
import jax


class Engine:
    def __init__(self):
        self._decode = jax.jit(self._decode_step,
                               static_argnames=("greedy",))

    def _decode_step(self, tokens, state, greedy=True):
        if greedy:                      # static_argnames param: a Python bool
            state = state + 1
        if tokens.shape[0] > 2:         # array metadata is trace-static
            state = state * 2
        if state is None:               # identity tests never concretize
            return tokens
        n = len(tokens)                 # len() is trace-static
        return state + n
