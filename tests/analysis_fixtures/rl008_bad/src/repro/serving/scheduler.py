"""RL008 bad fixture: component-mode acquire with no release anywhere.

``claim_slot`` is called but ``release_slot`` appears nowhere in the
project — claimed slots are never returned.
"""


class Scheduler:
    def admit(self, ticket, slot):
        self.engine.claim_slot(ticket, slot)
        self.slots[slot] = ticket
