"""RL008 bad fixture: lifecycle acquires that leak on some path."""


class Engine:
    def leak_on_raise(self):
        # acquire, then a may-raise call BEFORE the hand-off: the
        # exception edge leaves the function with the table live
        table, shared = self.kv_pool.alloc_prompt(self.prompt, 8)
        self.audit()
        self._tables[0] = table

    def leak_every_path(self):
        # acquired and then simply dropped: the fall-through exit leaks
        table, shared = self.kv_pool.alloc_prompt(self.prompt, 8)
        return 1

    def sequence_leak(self, slot):
        # staged pool mutation with no commit_append later in the
        # function: the plan is built and never lands
        plan = self.kv_pool.prepare_append(slot)
        self.log(plan)

    def open_ticket(self):
        # propagating wrapper: returns the fresh acquire, so callers
        # inherit the obligation; its own paths are clean (the return
        # either completes, handing the table off, or raises before the
        # acquire completes)
        table, shared = self.kv_pool.alloc_prompt(self.prompt, 8)
        return table

    def caller_leaks(self):
        # inherits open_ticket's obligation and drops it: the may-raise
        # audit() and the bare return both leave the table live
        table = self.open_ticket()
        self.audit()
