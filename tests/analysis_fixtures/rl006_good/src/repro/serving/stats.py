"""RL006 good fixture: stats fields and schema pins match exactly."""
from dataclasses import dataclass


@dataclass
class EngineStats:
    decode_steps: int = 0


@dataclass
class RunStats:
    wall_s: float = 0.0
