# Covers benchmarks/fig9_latency.py artifacts.
ENGINE_KEYS = {"decode_steps"}

RUN_KEYS = {"wall_s"}
