"""RL006 good fixture: benchmark whose stem appears in the schema test."""


def record_run(name, payload):
    return name, payload


def main():
    record_run("fig9.latency", {"wall_s": 1.0})
