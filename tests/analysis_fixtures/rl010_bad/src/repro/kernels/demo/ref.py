"""Reference twin for the demo kernels (float32 only — the bad kernel's
bfloat16 out_shape has no counterpart here)."""
import jax.numpy as jnp


def dense_ref(x):
    return x.astype(jnp.float32)


def paged_ref(s, x):
    return x.astype(jnp.float32)
