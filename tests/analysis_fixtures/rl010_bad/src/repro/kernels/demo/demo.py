"""RL010 bad fixture: every contract-arithmetic failure mode."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def dense_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run_dense(x):
    # index map takes 3 args vs a 2-dim grid with 0 prefetch;
    # out_shape declares 1 output vs 2 out_specs; the kernel takes 2
    # refs vs 1 in + 2 out; bfloat16 never appears in ref.py
    return pl.pallas_call(
        dense_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j, k: (i, j))],
        out_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                   pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.bfloat16),
    )(x)


def paged_kernel(s_ref, x_ref, o_ref):
    # scalar-prefetch kernel with NO bound compare on the last grid
    # axis's program_id: the padded tail is read unmasked
    o_ref[...] = x_ref[...]


def run_paged(s, x, y):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(2, 3),
        in_specs=[pl.BlockSpec((8,), lambda p, i, j: (i,))],
        out_specs=pl.BlockSpec((8,), lambda p, i, j: (i,)),
        scratch_shapes=[],
    )
    # 3 operands vs 1 prefetch + 1 input
    return pl.pallas_call(
        paged_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
    )(s, x, y)
