"""RL009 bad fixture: pool-shared attributes written without discipline."""


class Executor:
    def __init__(self, pool):
        self._pool = pool
        self.done = 0
        self.busy_ns = 0

    def run(self, items):
        def work(g):
            self.done += 1
            self.busy_ns += g
        list(self._pool.map(work, items))
        self.busy_ns += 1

    def report(self):
        return self.done, self.busy_ns
