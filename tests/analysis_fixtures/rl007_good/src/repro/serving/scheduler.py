"""RL007 good fixture: clock reads inline, emission at the _obs_* drain."""
import jax
import jax.numpy as jnp

from repro.obs.metrics import LogHistogram
from repro.obs.trace import now_ns


class Sched:
    def __init__(self):
        self._decode = jax.jit(self._decode_step)
        self._h = LogHistogram()

    def _tick(self):
        t0 = now_ns()                      # reading the clock is not emission
        y = self._decode(jnp.ones((4,)))
        self._h.observe(1.0)               # histograms are not emission
        self._step_phase()
        self._obs_tick(t0)                 # drain helper: sanctioned by name
        return y

    def _step_phase(self):
        self.obs.counter("sched", "depth", 1)  # reprolint: allow[RL007] documented exception

    def _obs_tick(self, t0):
        # the one emission site: stopped out of the hot graph by name
        self.obs.complete("sched", "tick", t0, now_ns())
        self.obs.instant("sched", "drained")

    def _decode_step(self, x):
        return jnp.sum(x)

    def _retire(self):
        # outside the hot graph entirely (stop name): emission is legal
        self.obs.instant("sched", "retired")
