"""Reference twin for the good demo kernels."""
import jax.numpy as jnp


def dense_ref(x):
    return x.astype(jnp.float32)


def paged_ref(s, n, x):
    return x.astype(jnp.float32)
