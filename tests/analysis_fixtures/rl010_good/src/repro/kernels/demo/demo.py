"""RL010 good fixture: contract arithmetic that adds up, a masked
ragged tail, and the SMEM no-index-map idiom (exempt from arity)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def dense_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run_dense(x):
    return pl.pallas_call(
        dense_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )(x)


def paged_kernel(s_ref, n_ref, x_ref, m_ref, o_ref):
    live = pl.program_id(1) < n_ref[0]
    o_ref[...] = jnp.where(live, x_ref[...] + m_ref[0], 0.0)


def run_paged(s, n, x, m):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(2, 3),
        in_specs=[pl.BlockSpec((8,), lambda p, q, i, j: (i,)),
                  pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((8,), lambda p, q, i, j: (i,)),
        scratch_shapes=[],
    )
    return pl.pallas_call(
        paged_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
    )(s, n, x, m)
