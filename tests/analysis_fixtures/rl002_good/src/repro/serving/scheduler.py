"""RL002 good fixture: sanctioned drains + inline allow suppression."""
import jax
import jax.numpy as jnp
import numpy as np


class Sched:
    def _tick(self):
        x = jnp.ones((4,))
        self._accumulate(x)             # sanctioned drain point (by name)
        toks = np.asarray(jax.device_get(x))  # reprolint: allow[RL002] once-per-tick token drain
        return toks

    def _accumulate(self, stats):
        # stop name: this body is outside the computed hot path
        return int(np.asarray(stats).sum())
