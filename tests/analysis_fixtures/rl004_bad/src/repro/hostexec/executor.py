"""RL004 bad fixture: impure pure_callback target."""
import jax

TABLE = {}


class Exec:
    def run(self, layer, x):
        return jax.pure_callback(self.compute, x, layer, x)

    def compute(self, layer, x):
        self.total = 1                  # line 12: non-telemetry self write
        TABLE[layer] = x                # line 13: module-global write
        return x
