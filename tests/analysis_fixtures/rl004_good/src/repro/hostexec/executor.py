"""RL004 good fixture: sanctioned telemetry + invocation-local buffers."""
import jax
import numpy as np


class Exec:
    def run(self, layer, x):
        return jax.pure_callback(self.compute, x, layer, x)

    def compute(self, layer, x):
        self.calls += 1                 # sanctioned pool telemetry
        out = np.zeros_like(x)          # local buffer: dies with the call
        out[:] = x
        return out
