"""RL002 bad fixture: host syncs inside the hot tick loop."""
import jax
import jax.numpy as jnp
import numpy as np


class Sched:
    def _tick(self):
        x = jnp.ones((4,))              # device value born in this frame
        toks = np.asarray(x)            # line 10: implicit transfer+sync
        y = jax.device_get(x)           # line 11: explicit sync
        x.block_until_ready()           # line 12: explicit sync
        n = int(x.sum())                # line 13: implicit sync via int()
        return toks, y, n

    def _drain(self, x):
        # parameters are not device-tainted in THIS frame: the rule only
        # flags syncs on values the same function created on-device
        return np.asarray(x)
