"""RL009 good fixture: the same shape, disciplined both ways — a lock
around one shared write, the documented-race annotation on the others."""


class Executor:
    def __init__(self, pool, lock):
        self._pool = pool
        self._lock = lock
        self.done = 0
        self.busy_ns = 0

    def run(self, items):
        def work(g):
            with self._lock:
                self.done += 1
            self.busy_ns += g  # reprolint: shared[atomic] telemetry floor — a torn add undercounts, never corrupts
        list(self._pool.map(work, items))
        self.busy_ns += 1  # reprolint: shared[atomic] telemetry floor — races the workers' adds by design

    def report(self):
        return self.done, self.busy_ns
