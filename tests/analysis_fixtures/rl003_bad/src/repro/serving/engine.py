"""RL003 bad fixture: donated buffer read after the donating call."""
import jax


class Engine:
    def __init__(self):
        self._decode = jax.jit(self._decode_step, donate_argnums=(1,))

    def _decode_step(self, tokens, state):
        return state + 1

    def step(self, tokens, state):
        logits = self._decode(tokens, state)
        return logits + state.mean()    # line 14: `state` was donated
