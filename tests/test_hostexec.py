"""Live host-execution subsystem tests (repro.hostexec).

Pins the dispatcher's three contracts:
  * cost-model split — the per-group CPU-vs-fetch decision follows
    ``PaperModelTimings`` exactly: CPU when the multithreaded expert FFN
    beats the weight transfer, GPU otherwise, and the decision table the
    jitted dispatcher gathers from agrees entry for entry;
  * parity — with the in-graph backend the hybrid dispatcher is
    BIT-identical to the all-GPU path (same y, same cache state, same
    tokens through the full reduced-Mixtral serving stack), and the
    callback backend (numpy thread pool via ``jax.pure_callback``)
    matches to float32 tolerance while really running on the pool;
  * channel — ``cpu_expert_calls`` / ``cpu_tokens`` count exactly the
    groups/assignments dispatched to the host, zero when disabled.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.core import collaborative as collab
from repro.core.costmodel import MIXTRAL_TIMINGS, PAPER_TIMINGS, \
    cpu_expert_ms, fetch_expert_ms
from repro.hostexec import HostDispatchPolicy, HostExpertExecutor, \
    dispatch_execute, dispatch_plan, host_expert_ffn, timings_for
from repro.models import init_params
from repro.serving import EngineConfig, build


# ---------------------------------------------------------------------------
# cost-model split decision
# ---------------------------------------------------------------------------

def test_split_picks_cpu_when_fetch_slower_and_gpu_otherwise():
    """The satellite contract, on synthetic timings with no activation
    overhead: CPU exactly when fetch_expert_ms > cpu_expert_ms."""
    tm = dataclasses.replace(
        MIXTRAL_TIMINGS, comm_pair_ms=20.0, cpu_pair_ms={1: 30.0, 8: 10.0},
        act_transfer_ms=0.0, gpu_pair_ms=0.0)
    slow_cpu = HostDispatchPolicy(tm, threads=1)    # cpu 15 > fetch 10
    fast_cpu = HostDispatchPolicy(tm, threads=8)    # cpu 5 < fetch 10
    assert fetch_expert_ms(tm) > cpu_expert_ms(tm, 8)
    assert fast_cpu.prefers_cpu(1)
    assert fetch_expert_ms(tm) < cpu_expert_ms(tm, 1)
    assert not slow_cpu.prefers_cpu(1)


@pytest.mark.parametrize("name", list(PAPER_TIMINGS))
def test_split_on_paper_timings(name):
    """On the paper's measured testbed numbers: many threads put the
    single-token miss on the CPU, one thread keeps the weight fetch."""
    tm = PAPER_TIMINGS[name]
    assert HostDispatchPolicy(tm, threads=24).prefers_cpu(1)
    assert not HostDispatchPolicy(tm, threads=1).prefers_cpu(1)


def test_decision_table_matches_policy_and_scales_with_tokens():
    pol = HostDispatchPolicy(MIXTRAL_TIMINGS, threads=8)
    table = pol.decision_table(8)
    assert table.shape == (9,) and table.dtype == bool
    assert not table[0]                       # empty groups never dispatch
    for c in range(9):
        assert table[c] == pol.prefers_cpu(c)
    # both lanes are linear in tokens with cpu_expert_ms > gpu_expert_ms,
    # so once the fetch amortizes the decision flips to GPU and stays
    assert table[1] and not table[8]
    flips = np.flatnonzero(table[1:] != table[:-1])
    assert len(flips) <= 2                    # False, True..., False...


def test_timings_for_resolves_reduced_arch_names():
    import warnings
    # calibrated archs resolve silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert timings_for("mixtral-8x7b") is MIXTRAL_TIMINGS
        assert timings_for("phi35-moe") is PAPER_TIMINGS["phi35-moe"]
    # unknown archs still fall back to Mixtral, but never silently: the
    # cost decisions are uncalibrated and the caller must hear about it
    with pytest.warns(UserWarning, match="uncalibrated"):
        assert timings_for("unknown-arch") is MIXTRAL_TIMINGS


# ---------------------------------------------------------------------------
# dispatcher stage (collab-level)
# ---------------------------------------------------------------------------

def _tiers(key, L=3, E=4, D=16, F=32):
    ks = jax.random.split(key, 3)
    ccfg = CacheConfig(num_indexes=2, num_ways=2, policy="lru")
    w1 = jax.random.normal(ks[0], (L, E, D, F), jnp.float32) * 0.1
    w3 = jax.random.normal(ks[1], (L, E, D, F), jnp.float32) * 0.1
    w2 = jax.random.normal(ks[2], (L, E, F, D), jnp.float32) * 0.1
    return collab.init_tiers(w1, w3, w2, ccfg, num_experts=E), ccfg


def test_dispatch_plan_partitions_miss_groups_only():
    tiers, ccfg = _tiers(jax.random.PRNGKey(0))
    # warm expert 1 so the next probe has a resident group
    pr0 = collab.probe(tiers, jnp.int32(0), jnp.asarray([[1, 2]]), ccfg)
    _, host_w = collab.execute(tiers, jnp.int32(0),
                               jnp.zeros((1, 16)), jnp.ones((1, 2)), pr0,
                               ccfg)
    tiers, _ = collab.commit(tiers, jnp.int32(0), pr0, host_w, ccfg)
    pr = collab.probe(tiers, jnp.int32(0), jnp.asarray([[1, 3]]), ccfg)
    all_cpu = jnp.ones((3,), bool)
    to_cpu, counts = dispatch_plan(pr, all_cpu)
    res = np.asarray(pr.resident)
    e = np.asarray(pr.rep_e)
    got = np.asarray(to_cpu)
    # resident group (expert 1) stays on device; the miss (expert 3) goes
    # to the CPU; padded groups never dispatch
    assert not got[res].any()
    assert got[(~res) & (e >= 0)].all()
    assert np.asarray(counts).sum() == 2
    none, _ = dispatch_plan(pr, jnp.zeros((3,), bool))
    assert not np.asarray(none).any()


def test_jax_backend_bitwise_identical_to_execute():
    """The in-graph fallback: dispatch_execute == collab.execute, bit for
    bit, whatever the split table says."""
    key = jax.random.PRNGKey(1)
    tiers, ccfg = _tiers(key)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    tw = jnp.asarray([[0.6, 0.4], [0.5, 0.5]], jnp.float32)
    rng = np.random.default_rng(0)
    for step in range(4):
        ti = jnp.asarray(rng.integers(0, 4, size=(2, 2)))
        pr = collab.probe(tiers, jnp.int32(1), ti, ccfg)
        y_ref, host_ref = collab.execute(tiers, jnp.int32(1), x, tw, pr,
                                         ccfg)
        table = jnp.asarray(rng.integers(0, 2, size=5).astype(bool)
                            .tolist())
        y, host_w, dstats = dispatch_execute(tiers, jnp.int32(1), x, tw,
                                             pr, ccfg, table)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
        for a, b in zip(host_w, host_ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        tiers, _ = collab.commit(tiers, jnp.int32(1), pr, host_w, ccfg)


def test_callback_backend_matches_device_numerics_and_runs_pool():
    key = jax.random.PRNGKey(2)
    tiers, ccfg = _tiers(key)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    tw = jnp.asarray([[0.5, 0.5], [0.7, 0.3]], jnp.float32)
    ti = jnp.asarray([[0, 3], [2, 3]])
    pr = collab.probe(tiers, jnp.int32(0), ti, ccfg)
    y_ref, _ = collab.execute(tiers, jnp.int32(0), x, tw, pr, ccfg)
    ex = HostExpertExecutor(tiers.host_w1, tiers.host_w3, tiers.host_w2,
                            threads=4)
    all_cpu = jnp.ones((5,), bool)
    y, _, dstats = dispatch_execute(tiers, jnp.int32(0), x, tw, pr, ccfg,
                                    all_cpu, ex)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # >= not ==: pure_callback may legally re-invoke; the traced channel
    # is the exact ledger, the host telemetry a floor
    assert ex.calls >= 1
    assert ex.groups >= int(np.asarray(dstats["cpu_expert_calls"]))
    assert int(np.asarray(dstats["cpu_expert_calls"])) == 3  # {0, 2, 3}
    assert int(np.asarray(dstats["cpu_tokens"])) == 4


def test_host_expert_ffn_matches_jnp_reference():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((5, 16)).astype(np.float32)
    w1 = rng.standard_normal((16, 32)).astype(np.float32)
    w3 = rng.standard_normal((16, 32)).astype(np.float32)
    w2 = rng.standard_normal((32, 16)).astype(np.float32)
    got = host_expert_ffn(x, w1, w3, w2)
    want = np.asarray((jax.nn.silu(x @ w1) * (x @ w3)) @ w2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine + scheduler (the acceptance pair)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _run_sched(cfg, params, **serving):
    _, sched = build(cfg, serving=dict(max_batch=2, capacity=64, **serving),
                     seed=0, params=params)
    rng = np.random.default_rng(7)
    for _ in range(3):
        sched.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9))),
                     max_new_tokens=8)
    outs = sched.run()
    return outs, sched.stats, sched.engine


def test_host_compute_tokens_bit_identical_on_serving_run(setup):
    """The acceptance criterion: host_compute=True (in-graph backend)
    decodes BIT-identical tokens to the all-GPU path on the reduced
    Mixtral serving stack, while really dispatching misses to the CPU
    lane (cpu_expert_calls > 0)."""
    cfg, params = setup
    outs_off, s_off, _ = _run_sched(cfg, params, host_compute=False)
    outs_on, s_on, eng = _run_sched(cfg, params, host_compute=True,
                                    host_threads=8)
    assert sorted(outs_on) == sorted(outs_off)
    for rid in outs_off:
        np.testing.assert_array_equal(outs_on[rid], outs_off[rid])
    assert s_on.cpu_expert_calls > 0
    assert s_on.cpu_tokens >= s_on.cpu_expert_calls
    assert s_on.cpu_tokens <= s_on.host_assignments
    assert s_on.miss_expert_groups >= s_on.cpu_expert_calls
    assert 0.0 < s_on.cpu_offload_rate <= 1.0
    # host execution changes where FLOPs run, never residency: the whole
    # demand channel matches the all-GPU run counter for counter
    for k in ("hits", "accesses", "host_assignments", "fetched_experts"):
        assert getattr(s_on, k) == getattr(s_off, k), k
    assert s_off.cpu_expert_calls == s_off.cpu_tokens == 0
    assert s_off.miss_expert_groups == 0       # counted only by dispatch


def test_callback_backend_serves_and_counts(setup):
    """The real thread-pool lane end to end: tokens all valid, the
    executor really ran, and the traced channel agrees with the host-side
    telemetry."""
    cfg, params = setup
    outs, stats, eng = _run_sched(cfg, params, host_compute=True,
                                  host_backend="callback", host_threads=4)
    assert stats.cpu_expert_calls > 0
    assert eng.host_executor is not None
    assert eng.host_executor.groups >= stats.cpu_expert_calls
    for toks in outs.values():
        assert len(toks) == 8
        assert ((toks >= 0) & (toks < cfg.vocab_size)).all()


def test_single_thread_cost_model_keeps_misses_on_gpu(setup):
    """threads=1: the paper's timings put the weight fetch ahead of the
    single-threaded CPU FFN, so the dispatcher sends nothing to the host
    even with host_compute on."""
    cfg, params = setup
    _, stats, eng = _run_sched(cfg, params, host_compute=True,
                               host_threads=1)
    assert not eng.dispatch_policy.prefers_cpu(1)
    assert stats.cpu_expert_calls == 0
    assert stats.cpu_tokens == 0
    # an all-False decision table never dispatches, so the callback
    # backend skips the executor entirely (no per-layer host round-trip)
    _, _, eng_cb = _run_sched(cfg, params, host_compute=True,
                              host_threads=1, host_backend="callback")
    assert eng_cb.host_executor is None


def test_engine_config_validation():
    ccfg = CacheConfig(num_indexes=2, num_ways=2)
    with pytest.raises(ValueError, match="host_threads"):
        EngineConfig(cache=ccfg, host_threads=0)
    with pytest.raises(ValueError, match="host_backend"):
        EngineConfig(cache=ccfg, host_backend="cuda")
    with pytest.raises(ValueError, match="prefetch_min_prob"):
        EngineConfig(cache=ccfg, prefetch_min_prob=1.5)


# ---------------------------------------------------------------------------
# census-driven worker fan-out (HybriMoE-style thread scaling + affinity)
# ---------------------------------------------------------------------------

def _toy_executor(threads, E=6, D=8, F=16, seed=5):
    rng = np.random.default_rng(seed)
    w1 = rng.standard_normal((1, E, D, F)).astype(np.float32)
    w3 = rng.standard_normal((1, E, D, F)).astype(np.float32)
    w2 = rng.standard_normal((1, E, F, D)).astype(np.float32)
    return HostExpertExecutor(w1, w3, w2, threads=threads)


def test_effective_threads_follows_census_curve():
    """Workers track the step's miss-group census: linear to the
    8-thread bandwidth knee, sqrt growth past it, capped by the pool,
    floored at one."""
    ex = _toy_executor(threads=32)
    for census in range(1, 9):
        assert ex._effective_threads(census) == census
    assert ex._effective_threads(0) == 1
    assert ex._effective_threads(9) == 9          # 8 + isqrt(1)
    assert ex._effective_threads(12) == 10        # 8 + isqrt(4)
    assert ex._effective_threads(24) == 12        # 8 + isqrt(16)
    # the configured pool size is a hard cap
    assert _toy_executor(threads=4)._effective_threads(24) == 4
    assert _toy_executor(threads=1)._effective_threads(5) == 1


def test_census_fanout_bitwise_and_affinity_telemetry():
    """The census-driven bucketed fan-out is schedule-only: outputs are
    BIT-identical to the sequential single-thread lane (groups are
    independent; only their worker placement changes). Repeat experts
    land on their pinned bucket — affinity_hits counts them — and the
    census telemetry averages the per-step worker pick."""
    rng = np.random.default_rng(11)
    G, A, D = 5, 3, 8
    rep_e = np.array([0, 2, 3, 5, 1], np.int64)
    run = np.ones(G, bool)
    xbuf = rng.standard_normal((G, A, D)).astype(np.float32)

    pooled = _toy_executor(threads=8)
    solo = _toy_executor(threads=1)
    out1 = pooled.compute_groups(0, rep_e, run, xbuf)
    np.testing.assert_array_equal(
        out1, solo.compute_groups(0, rep_e, run, xbuf))
    assert pooled.census_calls == 1
    assert pooled.census_threads == 5             # census 5 <= knee
    assert pooled.affinity_hits == 0              # first sighting of each
    assert set(pooled._affinity) == set(rep_e.tolist())

    # same experts next step: every group lands on its pinned bucket
    out2 = pooled.compute_groups(0, rep_e, run, xbuf)
    np.testing.assert_array_equal(out2, out1)
    assert pooled.affinity_hits == G
    assert pooled.census_calls == 2 and pooled.census_threads == 10

    # the single-thread lane never consults the census machinery
    assert solo.census_calls == 0 and solo.affinity_hits == 0
