"""Decode parity: prefill(n)+k decode steps == prefill(n+k) logits.

The strongest KV/SSM-state correctness property — any cache-indexing,
RoPE-position, masking, or state-threading bug breaks it. Run for one
arch per state family (attention KV, sliding-window, SSM, hybrid,
enc-dec cross-attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.models import decode_step, init_params, prefill

ARCHS = ["smollm-360m",            # dense GQA KV
         "gemma3-4b",              # sliding-window + global interleave
         "mamba2-370m",            # SSM state
         "jamba-v0.1-52b",         # hybrid KV + SSM + MoE
         "seamless-m4t-large-v2"]  # enc-dec self+cross attention


def _mk_batch(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, S, cfg.frontend_embed_dim), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, 16, cfg.frontend_embed_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_plus_decode_equals_longer_prefill(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, n, k = 2, 96, 3

    full = _mk_batch(cfg, key, B, n + k)

    # enc-dec: the encoder memory must be IDENTICAL in both runs — only
    # the decoder grows. Hold frames fixed at n.
    def slice_batch(upto):
        out = {}
        for kk, v in full.items():
            if kk == "tokens":
                out[kk] = v[:, :upto]
            elif kk == "frames":
                out[kk] = v[:, :n]
            else:
                out[kk] = v
        return out

    # reference: prefill over n+j tokens, last-position logits
    ref_logits = []
    for j in range(1, k + 1):
        lg, _ = jax.jit(lambda p, b: prefill(p, b, cfg))(
            params, slice_batch(n + j))
        ref_logits.append(np.asarray(lg[:, -1], np.float32))

    # candidate: prefill n, then k cached decode steps (decode step j
    # consumes token t_{n+j} and must reproduce prefill(n+j+1)'s logits)
    _, state = jax.jit(lambda p, b: prefill(p, b, cfg))(params,
                                                        slice_batch(n))
    # widen self-attention caches to n+k capacity (NOT the encoder
    # memory_kv: padded zero-keys would perturb unmasked cross-attention)
    def widen(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        if "memory_kv" in names:
            return x
        if names and names[-1] in ("k", "v") and x.ndim >= 4 \
                and x.shape[-3] == n:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, k)
            return jnp.pad(x, pad)
        return x
    state = jax.tree_util.tree_map_with_path(widen, state)

    dstep = jax.jit(lambda p, s, b: decode_step(p, s, b, cfg))
    got = []
    for j in range(k):
        tok = full["tokens"][:, n + j:n + j + 1]
        lg, state = dstep(params, state, {"tokens": tok})
        got.append(np.asarray(lg[:, 0], np.float32))

    # bf16 noise: the prefill flash path and the decode einsum path
    # accumulate in different orders; 5e-2 absolute on O(4) logits
    for j in range(k):
        np.testing.assert_allclose(
            got[j], ref_logits[j], rtol=5e-2, atol=5e-2,
            err_msg=f"{arch} step {j}")
