"""Staged collaborative core + cross-layer speculative prefetch tests.

Covers the three contracts the refactor introduces:
  * reserve/land semantics — a reservation is policy-correct but has none
    of a demand access's observable effects, is invisible to same-step
    probes and visible from the next probe on;
  * staged parity — driving probe/execute/commit separately (as the
    serving engine does) is BIT-identical to the collaborative_moe
    composition with prefetch disabled;
  * live pipeline — prefetch changes residency and counters, never
    logits; counters accumulate monotonically through the scheduler.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.core import cache as cache_lib
from repro.core import collaborative as collab
from repro.core.cache import FLAG_DEMAND
from repro.core.policies import NumpyCache
from repro.models import init_params
from repro.serving import CollaborativeEngine, \
    ContinuousBatchingScheduler, EngineConfig


def _acc(state, layer, experts, policy="lru"):
    return cache_lib.access_ex(state, jnp.int32(layer),
                               jnp.asarray(experts, jnp.int32), policy)


def _res(state, layer, experts, policy="lru"):
    return cache_lib.reserve(state, jnp.int32(layer),
                             jnp.asarray(experts, jnp.int32), policy)


def _tiers(key, L=3, E=4, D=16, F=32, ccfg=None, policy="lru"):
    ks = jax.random.split(key, 3)
    ccfg = ccfg or CacheConfig(num_indexes=2, num_ways=2, policy=policy)
    w1 = jax.random.normal(ks[0], (L, E, D, F), jnp.float32) * 0.1
    w3 = jax.random.normal(ks[1], (L, E, D, F), jnp.float32) * 0.1
    w2 = jax.random.normal(ks[2], (L, E, F, D), jnp.float32) * 0.1
    return collab.init_tiers(w1, w3, w2, ccfg, num_experts=E,
                             key=jax.random.PRNGKey(7)), ccfg


# ---------------------------------------------------------------------------
# reserve / land semantics
# ---------------------------------------------------------------------------

def test_reservation_invisible_same_step_visible_next():
    s = cache_lib.init_cache_state(CacheConfig(num_indexes=2, num_ways=2))
    s, issued, ways = _res(s, 1, [4, 6])
    assert list(np.asarray(issued)) == [True, True]
    # same-step: read-only probe AND demand access both miss the PENDING
    # reservations (and the demand access must not re-insert/evict)
    hit, _ = cache_lib.lookup(s, jnp.int32(1), jnp.asarray([4, 6]))
    assert not np.asarray(hit).any()
    tags_before = np.asarray(s.tags).copy()
    s2, hits, _, spec = _acc(s, 1, [4, 6])
    assert not np.asarray(hits).any() and not np.asarray(spec).any()
    assert np.array_equal(np.asarray(s2.tags), tags_before)
    # next probe boundary: land -> the reservations serve demand hits,
    # attributed to the speculative channel exactly once
    s3 = cache_lib.land(s2)
    s4, hits, _, spec = _acc(s3, 1, [4, 6])
    assert np.asarray(hits).all() and np.asarray(spec).all()
    s5, hits, _, spec = _acc(s4, 1, [4, 6])
    assert np.asarray(hits).all() and not np.asarray(spec).any()


def test_reserve_has_no_demand_observable_effects():
    """No hit inflation: reserving must leave the demand-visible LRU
    order and the twin's hit/access counters untouched for experts it
    does not insert."""
    nc = NumpyCache(CacheConfig(num_indexes=1, num_ways=2))
    nc.access(0, [1, 2])
    hits0, acc0 = nc.hits, nc.accesses
    nc.reserve(0, [1, 2])          # both present -> full no-op
    assert (nc.hits, nc.accesses) == (hits0, acc0)
    assert nc.reserved == 0
    # an already-resident expert is NOT age-refreshed by reserve: 1 is
    # still the LRU victim for the next demand insert
    nc.access(0, [3])
    assert 1 not in nc.tags[0] and 2 in nc.tags[0]


def test_reserve_does_not_duplicate_in_flight_fetches():
    s = cache_lib.init_cache_state(CacheConfig(num_indexes=1, num_ways=4))
    s, issued, _ = _res(s, 0, [5])
    assert np.asarray(issued).all()
    # re-reserving (same step or next) never issues a second transfer
    s, issued, _ = _res(s, 0, [5, 5])
    assert not np.asarray(issued).any()
    s = cache_lib.land(s)
    s, issued, _ = _res(s, 0, [5])
    assert not np.asarray(issued).any()


def test_reserve_batch_protection():
    """Reserving pick B must not evict predicted pick A of the same
    batch — at M = top_k the batch would otherwise evict itself."""
    s = cache_lib.init_cache_state(CacheConfig(num_indexes=1, num_ways=2))
    s, _, _, _ = _acc(s, 0, [1])     # oldest way: expert 1
    s, _, _, _ = _acc(s, 0, [2])
    # batch {1, 3}: 1 is present (protected), so 3 must evict 2 — the
    # unprotected way — even though 1 is the LRU
    s, issued, _ = _res(s, 0, [1, 3])
    assert list(np.asarray(issued)) == [False, True]
    tags = set(np.asarray(s.tags)[0].tolist())
    assert tags == {1, 3}
    # all ways protected -> the reservation is skipped, not forced
    s1 = cache_lib.init_cache_state(CacheConfig(num_indexes=1, num_ways=1))
    s1, _, _, _ = _acc(s1, 0, [7])
    s1, issued, _ = _res(s1, 0, [7, 3])
    assert not np.asarray(issued).any()
    assert np.asarray(s1.tags)[0, 0] == 7


def test_reserve_priority_ranks_retention():
    """``priority`` adds to the reservation's age stamp: when a later
    demand insert must evict a reserved way it takes the lowest-priority
    reservation first — retention ranking with claim order untouched —
    and the numpy twin replays the same choice."""
    s = cache_lib.init_cache_state(CacheConfig(num_indexes=2, num_ways=3))
    s, issued, _ = cache_lib.reserve(
        s, jnp.int32(0), jnp.asarray([1, 2, 3], jnp.int32), "lru",
        priority=jnp.asarray([0, 5, 0], jnp.int32))
    assert np.asarray(issued).all()        # priority never blocks a claim
    s = cache_lib.land(s)
    s, hits, _, _ = _acc(s, 0, [7])        # evicts 1: lowest stamped age
    assert not np.asarray(hits).any()
    assert set(np.asarray(s.tags)[0].tolist()) == {7, 2, 3}
    nc = NumpyCache(CacheConfig(num_indexes=2, num_ways=3), num_experts=8)
    nc.reserve(0, [1, 2, 3], priority=[0, 5, 0])
    nc.land()
    nc.access(0, [7])
    assert set(nc.tags[0].tolist()) == {7, 2, 3}


def test_prediction_votes_counts_cross_batch():
    """Votes are pairwise pick-equality counts; masked picks score 0 and
    never contribute to a real pick's count."""
    votes = collab.prediction_votes(
        jnp.asarray([3, 5, 3, -1, 3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(votes), [3, 1, 3, 0, 3])
    # -1 masks must not vote for each other
    votes = collab.prediction_votes(jnp.asarray([-1, -1, 2], jnp.int32))
    np.testing.assert_array_equal(np.asarray(votes), [0, 0, 1])


def test_rank_votes_changes_retention_never_tokens(setup):
    """prefetch_rank_votes stamps reservations with cross-batch vote
    priority: the claimed set is identical (priority never blocks a
    claim, so issued counts match exactly) and the generated tokens are
    bit-identical — like every prefetch knob it moves residency, never
    logits."""
    cfg, params = setup
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(6), (2, 8), 0, cfg.vocab_size), np.int32)
    out_rv, s_rv = _engine(cfg, params, True, max_batch=2).generate(
        prompt, steps=12)
    out_nr, s_nr = _engine(cfg, params, True, max_batch=2,
                           prefetch_rank_votes=False).generate(
        prompt, steps=12)
    np.testing.assert_array_equal(out_rv, out_nr)
    assert s_rv.prefetch_issued == s_nr.prefetch_issued
    assert s_rv.predicted == s_nr.predicted


def test_reserve_static_policy_and_coverage():
    ccfg = CacheConfig(num_indexes=2, num_ways=2, policy="random")
    s = cache_lib.init_cache_state(ccfg, num_experts=8,
                                   key=jax.random.PRNGKey(0))
    tags0 = np.asarray(s.tags).copy()
    s, issued, _ = _res(s, 0, [1, 2], "random")
    assert not np.asarray(issued).any()
    assert np.array_equal(tags0, np.asarray(s.tags))
    s2 = cache_lib.init_cache_state(CacheConfig(num_indexes=2, num_ways=2))
    s2, issued, _ = _res(s2, 5, [1, 2])          # beyond coverage
    assert not np.asarray(issued).any()
    assert (np.asarray(s2.tags) == -1).all()


def test_demand_insert_over_pending_way_clears_flag():
    """A demand insert that evicts an in-flight reservation takes clean
    DEMAND provenance (the dropped transfer must not mark it)."""
    s = cache_lib.init_cache_state(CacheConfig(num_indexes=1, num_ways=1))
    s, issued, _ = _res(s, 0, [4])
    assert np.asarray(issued).all()
    s, hits, _, _ = _acc(s, 0, [6])              # evicts pending 4
    assert not np.asarray(hits).any()
    assert np.asarray(s.tags)[0, 0] == 6
    assert np.asarray(s.in_flight)[0, 0] == FLAG_DEMAND


@pytest.mark.parametrize("policy", ["lru", "fifo"])
def test_jax_and_numpy_twin_agree_on_reserve_traces(policy):
    """Random interleavings of access / reserve / land replay identically
    through the JAX cache and the numpy twin (tags, flags, hit flags)."""
    rng = np.random.default_rng(5)
    for trial in range(4):
        n, m = int(rng.integers(1, 4)), int(rng.integers(1, 4))
        e = int(rng.integers(max(m, 2), 9))
        ccfg = CacheConfig(num_indexes=n, num_ways=m, policy=policy)
        js = cache_lib.init_cache_state(ccfg)
        nc = NumpyCache(ccfg, num_experts=e)
        for step in range(12):
            layer = int(rng.integers(0, n + 1))
            ex = rng.integers(-1, e, size=int(rng.integers(1, 5)))
            op = rng.integers(0, 3)
            if op == 0:
                js, h1, _, sp1 = _acc(js, layer, ex, policy)
                h2 = nc.access(layer, ex)
                assert list(np.asarray(h1)) == h2, (trial, step, ex)
            elif op == 1:
                js, iss1, _ = _res(js, layer, ex, policy)
                iss2 = nc.reserve(layer, ex)
                assert list(np.asarray(iss1)) == iss2, (trial, step, ex)
            else:
                js = cache_lib.land(js)
                nc.land()
            assert np.array_equal(np.asarray(js.tags), nc.tags)
            assert np.array_equal(np.asarray(js.in_flight), nc.flags)


# ---------------------------------------------------------------------------
# staged parity
# ---------------------------------------------------------------------------

def test_staged_path_bit_identical_to_collaborative_moe():
    """Driving the stages separately (the engine's pipeline, prefetch
    disabled) is BIT-identical to the collaborative_moe composition:
    same y, same stats, same cache state, same slot buffers."""
    key = jax.random.PRNGKey(0)
    tiers_a, ccfg = _tiers(key)
    tiers_b, _ = _tiers(key, ccfg=ccfg)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    tw = jnp.asarray([[0.6, 0.4], [0.5, 0.5]], jnp.float32)
    rng = np.random.default_rng(3)
    for layer in (0, 1, 2):
        for rep in range(3):
            ti = jnp.asarray(rng.integers(0, 4, size=(2, 2)))
            y_a, tiers_a, s_a = collab.collaborative_moe(
                tiers_a, jnp.int32(layer), x, ti, tw, ccfg)
            pr = collab.probe(tiers_b, jnp.int32(layer), ti, ccfg)
            y_b, host_w = collab.execute(tiers_b, jnp.int32(layer), x, tw,
                                         pr, ccfg)
            tiers_b, fetch = collab.commit(tiers_b, jnp.int32(layer), pr,
                                           host_w, ccfg)
            assert np.array_equal(np.asarray(y_a), np.asarray(y_b))
            for k, v in s_a.items():
                if k == "fetched_experts":
                    assert int(v) == int(np.asarray(fetch.sum()))
                elif k == "hits":
                    assert int(v) == int(np.asarray(pr.hits.sum()))
            for fa, fb in zip(tiers_a, tiers_b):
                if isinstance(fa, cache_lib.CacheState):
                    for xa, xb in zip(fa, fb):
                        assert np.array_equal(np.asarray(xa), np.asarray(xb))
                else:
                    assert np.array_equal(np.asarray(fa), np.asarray(fb))


def test_prefetch_stage_populates_next_layer_probe():
    """prefetch() at layer 1 makes layer 1's next probe hit: correct
    weights in the slots, hits attributed to the speculative channel, and
    the layer output numerically unchanged."""
    key = jax.random.PRNGKey(2)
    tiers, ccfg = _tiers(key)
    tiers_ref, _ = _tiers(key, ccfg=ccfg)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    ti = jnp.asarray([[0, 1], [1, 2]])
    tw = jnp.asarray([[0.5, 0.5], [0.6, 0.4]], jnp.float32)

    tiers, rep_p, issued, n = collab.prefetch(tiers, jnp.int32(1), ti, ccfg)
    assert int(n) == 2            # ways=2: top protected inserts only
    # slot buffers hold the predicted experts' actual host weights
    st = cache_lib.land(tiers.state)
    res, way = cache_lib.lookup(st, jnp.int32(1), jnp.asarray([0, 1]))
    assert np.asarray(res).all()
    for e, w in zip([0, 1], np.asarray(way)):
        np.testing.assert_array_equal(
            np.asarray(tiers.slot_w1[1 * ccfg.num_ways + int(w)]),
            np.asarray(tiers.host_w1[1, e]))
    # the demand pass: y identical to the never-prefetched tiers, hits up
    y_pf, tiers, s_pf = collab.collaborative_moe(
        tiers, jnp.int32(1), x, ti, tw, ccfg)
    y_rf, tiers_ref, s_rf = collab.collaborative_moe(
        tiers_ref, jnp.int32(1), x, ti, tw, ccfg)
    np.testing.assert_allclose(np.asarray(y_pf), np.asarray(y_rf),
                               rtol=1e-6, atol=1e-6)
    assert int(s_pf["prefetch_hits"]) >= 2
    assert int(s_pf["hits"]) >= int(s_rf["hits"]) + 2


# ---------------------------------------------------------------------------
# live pipeline (engine + scheduler)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _engine(cfg, params, prefetch=False, **kw):
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    return CollaborativeEngine(
        cfg, params, EngineConfig(cache=ccfg, capacity=64, prefetch=prefetch,
                                  **kw),
        key=jax.random.PRNGKey(3))


def test_prefetch_changes_residency_never_logits(setup):
    """The acceptance pair: identical greedy generations with prefetch on
    and off, and a strictly better demand hit rate with it on."""
    cfg, params = setup
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size), np.int32)
    out_off, s_off = _engine(cfg, params, False).generate(prompt, steps=16)
    out_on, s_on = _engine(cfg, params, True).generate(prompt, steps=16)
    np.testing.assert_array_equal(out_off, out_on)
    assert s_on.hit_rate > s_off.hit_rate
    assert s_on.prefetch_issued > 0
    assert s_on.prefetch_hits > 0
    assert s_off.prefetch_issued == s_off.prefetch_hits == 0
    # accounting identity holds with prefetch enabled: every access is
    # either a demand hit or a host-computed assignment
    assert s_on.accesses == s_on.hits + s_on.host_assignments
    assert s_on.prefetch_hits <= s_on.hits


def test_per_layer_hit_rates_reported(setup):
    cfg, params = setup
    eng = _engine(cfg, params, False)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size), np.int32)
    _, stats = eng.generate(prompt, steps=12)
    rates = stats.per_layer_hit_rates
    assert rates.shape == (cfg.num_layers,)
    assert ((rates >= 0) & (rates <= 1)).all()
    assert sum(stats.per_layer_hits) == stats.hits
    assert sum(stats.per_layer_accesses) == stats.accesses


def test_scheduler_prefetch_counters_monotone(setup):
    """Counters only ever grow across scheduler ticks, and rates stay
    guarded (finite) from the zero-access initial state onwards."""
    cfg, params = setup
    eng = _engine(cfg, params, True, max_batch=2)
    sched = ContinuousBatchingScheduler(eng)
    s = sched.stats
    assert s.hit_rate == 0.0 and s.prediction_accuracy == 0.0
    assert s.prefetch_waste_rate == 0.0             # zero-division guarded
    rng = np.random.default_rng(0)
    for _ in range(3):
        sched.submit(rng.integers(0, cfg.vocab_size, 6), max_new_tokens=5)
    prev = sched.stats
    while any(sl is not None for sl in sched.slots) or sched.queue:
        sched.step()
        cur = sched.stats
        for k in ("prefetch_issued", "prefetch_hits", "prefetch_wasted",
                  "predicted", "predicted_correct", "hits", "accesses"):
            assert getattr(cur, k) >= getattr(prev, k), k
        prev = cur
    assert prev.prefetch_issued > 0
    assert prev.predicted > 0
    assert 0.0 <= prev.prediction_accuracy <= 1.0


def test_confidence_gate_cuts_reservations_never_tokens(setup):
    """prefetch_min_prob thresholds reservations on router probability:
    a strict gate suppresses predictions (and with them speculative
    transfers and any waste) while the generated tokens stay identical —
    gating changes residency, never logits."""
    cfg, params = setup
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab_size), np.int32)

    def run(min_prob):
        eng = _engine(cfg, params, True, max_batch=2,
                      prefetch_min_prob=min_prob)
        return eng.generate(prompt, steps=12)

    out_open, s_open = run(0.0)
    out_gate, s_gate = run(0.35)
    out_shut, s_shut = run(0.999)
    np.testing.assert_array_equal(out_open, out_gate)
    np.testing.assert_array_equal(out_open, out_shut)
    assert 0 < s_gate.predicted < s_open.predicted
    assert s_gate.prefetch_wasted <= s_open.prefetch_wasted
    # a gate above every achievable pick probability disables prefetch
    # entirely (router probs on 8 experts never reach 0.999 here)
    assert s_shut.predicted == s_shut.prefetch_issued == 0
    assert s_shut.prefetch_wasted == 0


def test_sampling_honors_per_request_params(setup):
    """Per-request SamplingParams drive the scheduler's sampler:
    reproducible per request seed, and actually different from greedy
    argmax decoding at high temperature."""
    from repro.serving import SamplingParams
    cfg, params = setup

    def run(key_seed, greedy, temperature=8.0):
        eng = _engine(cfg, params)
        sched = ContinuousBatchingScheduler(
            eng, key=jax.random.PRNGKey(key_seed))
        sp = SamplingParams() if greedy else SamplingParams(
            greedy=False, temperature=temperature, seed=key_seed)
        r = sched.submit(np.arange(6, dtype=np.int32), max_new_tokens=10,
                         sampling=sp)
        return sched.run()[r.rid]

    a = run(11, greedy=False)
    b = run(11, greedy=False)
    np.testing.assert_array_equal(a, b)             # same seed -> same draw
    g1 = run(11, greedy=True)
    g2 = run(99, greedy=True)
    np.testing.assert_array_equal(g1, g2)           # greedy ignores the seed
    c = run(12, greedy=False)
    assert not (np.array_equal(a, g1) and np.array_equal(c, g1)), \
        "temperature sampling must not collapse to argmax for every seed"
