"""Sort-based capacity dispatch MoE: correctness, drops, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.models.moe import (load_balance_loss, moe_apply, moe_params,
                              route, sort_dispatch)


def _dense_ref(p, x, m: MoEConfig):
    """No-capacity dense reference (every token reaches its experts)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    probs, top_i, top_w = route(p["router"], xf, m.top_k)
    y = np.zeros_like(np.asarray(xf, np.float32))
    for t in range(xf.shape[0]):
        for k in range(m.top_k):
            e = int(top_i[t, k])
            xt = np.asarray(xf[t], np.float32)
            w1 = np.asarray(p["w1"][e], np.float32)
            w3 = np.asarray(p["w3"][e], np.float32)
            w2 = np.asarray(p["w2"][e], np.float32)
            h = (xt @ w1) / (1 + np.exp(-(xt @ w1))) * (xt @ w3)
            y[t] += float(top_w[t, k]) * (h @ w2)
    return y.reshape(B, S, D)


def test_moe_matches_dense_reference_with_ample_capacity():
    m = MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_params(key, 16, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 16), jnp.float32)
    y, aux = moe_apply(p, x, m)
    ref = _dense_ref(p, x, m)
    np.testing.assert_allclose(np.asarray(y, np.float32), ref, rtol=5e-2,
                               atol=5e-2)


def test_sort_dispatch_respects_capacity_and_uniqueness():
    top_i = jnp.asarray([[0, 1], [0, 1], [0, 2], [0, 2]])   # expert0 x4
    token, slot, keep, order = sort_dispatch(top_i, capacity=2,
                                             num_experts=3)
    token, slot, keep = map(np.asarray, (token, slot, keep))
    # expert 0 got 4 assignments but capacity 2 -> exactly 2 kept
    e0 = slot // 2 == 0
    assert (keep & e0).sum() == 2
    # kept slots are unique
    kept_slots = slot[keep]
    assert len(set(kept_slots.tolist())) == len(kept_slots)


def test_dropped_tokens_get_zero_contribution():
    m = MoEConfig(num_experts=2, top_k=1, d_ff=8, capacity_factor=0.25)
    key = jax.random.PRNGKey(1)
    p = moe_params(key, 8, m)
    # router heavily prefers expert 0 -> most tokens dropped
    # (positive inputs so the linear router's expert-0 logit is always max)
    p = dict(p)
    p["router"] = jnp.zeros((8, 2)).at[:, 0].set(10.0)
    x = jnp.abs(jax.random.normal(key, (1, 16, 8), jnp.float32)) + 0.1
    y, _ = moe_apply(p, x, m)
    T = 16
    C = max(int(T * 1 / 2 * 0.25), 1)
    C = (C + 7) // 8 * 8
    nonzero_rows = (np.abs(np.asarray(y[0])).sum(-1) > 1e-9).sum()
    assert nonzero_rows <= C


def test_router_gets_gradients():
    m = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=4.0)
    key = jax.random.PRNGKey(2)
    p = moe_params(key, 16, m)
    x = jax.random.normal(key, (1, 8, 16), jnp.float32)

    def loss(p):
        y, aux = moe_apply(p, x, m)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w1"]).sum()) > 0


def test_load_balance_loss_minimized_when_uniform():
    E = 8
    probs_u = jnp.full((64, E), 1 / E)
    idx_u = jnp.stack([jnp.arange(64) % E, (jnp.arange(64) + 1) % E], 1)
    lb_u = load_balance_loss(probs_u, idx_u, E)
    probs_s = jnp.zeros((64, E)).at[:, 0].set(1.0)
    idx_s = jnp.zeros((64, 2), jnp.int32)
    lb_s = load_balance_loss(probs_s, idx_s, E)
    assert float(lb_u) == pytest.approx(1.0, rel=1e-5)
    assert float(lb_s) > float(lb_u)
