"""Optimizer, checkpointing, data pipeline, fault tolerance, elastic."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.config import (OptimizerConfig, ShapeConfig, get_config, reduced)
from repro.data import SyntheticLM
from repro.optim import (adamw_update, clip_by_global_norm, compress_int8,
                         decompress_int8, global_norm, init_opt_state,
                         lr_schedule)
from repro.runtime import (FailureDetector, StragglerMonitor, TrainSupervisor,
                           plan_reshard)


# --- optimizer -----------------------------------------------------------

def test_adamw_decreases_quadratic():
    ocfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                           weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, opt = adamw_update(g, opt, params, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clip_and_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    n = float(global_norm(g))
    assert n == pytest.approx(np.sqrt(10 * 9 + 5 * 16))
    clipped, _ = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_and_decay():
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(ocfg, jnp.int32(0))) == 0.0
    assert float(lr_schedule(ocfg, jnp.int32(10))) == pytest.approx(1e-3)
    assert float(lr_schedule(ocfg, jnp.int32(100))) == pytest.approx(1e-4,
                                                                     rel=0.01)


def test_int8_compression_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (256, 64)) * 0.01
    q, s = compress_int8(g)
    assert q.dtype == jnp.int8
    back = decompress_int8(q, s)
    max_err = float(jnp.abs(back - g).max())
    assert max_err <= float(s) * 0.51 + 1e-9       # half-ulp of the scale


# --- checkpoint ----------------------------------------------------------

def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "m": {"v": jnp.ones((3,), jnp.float32) * 0.5},
            "step": jnp.int32(7)}
    save_checkpoint(tmp_path, 7, tree)
    restored, step = load_checkpoint(tmp_path, tree)
    assert step == 7
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
    assert int(restored["step"]) == 7


def test_checkpoint_manager_async_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((4,), float(s))})
    mgr.wait()
    restored, step = mgr.restore(tree)
    assert step == 3 and float(restored["x"][0]) == 3.0
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2                      # retention enforced


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    save_checkpoint(tmp_path, 1, {"x": jnp.zeros((2,))})
    assert not list(tmp_path.glob("*.tmp"))


# --- data pipeline -------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = reduced(get_config("smollm-360m"))
    shape = ShapeConfig("t", 64, 8, "train")
    a = SyntheticLM(cfg, shape, seed=1).batch(5)
    b = SyntheticLM(cfg, shape, seed=1).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg, shape, seed=1).batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the batch deterministically and differ
    s0 = SyntheticLM(cfg, shape, seed=1, num_shards=2, shard=0).batch(5)
    s1 = SyntheticLM(cfg, shape, seed=1, num_shards=2, shard=1).batch(5)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    assert (a["tokens"] >= 0).all() and (a["tokens"] < cfg.vocab_size).all()


# --- fault tolerance -----------------------------------------------------

def test_failure_detector():
    fd = FailureDetector(timeout_s=10)
    fd.beat(0, now=100.0)
    fd.beat(1, now=105.0)
    assert fd.dead_workers(now=112.0) == [0]
    assert fd.alive_workers(now=112.0) == [1]


def test_straggler_monitor_flags_outlier():
    sm = StragglerMonitor(k=3.0)
    for w in range(4):
        for _ in range(10):
            sm.record(w, 1.0 + 0.01 * w)
    for _ in range(10):
        sm.record(4, 5.0)
    assert sm.stragglers() == [4]


def test_supervisor_recovers_and_replays_exactly():
    log = []

    def step(state, i):
        log.append(i)
        return state + 1

    saved = {}

    def save(i, state):
        saved["ckpt"] = (state, i)

    def restore():
        return saved["ckpt"]

    sup = TrainSupervisor(step, save, restore, ckpt_every=4, max_restarts=2)
    save(0, 0)
    state, end = sup.run(0, 0, 10, failure_at=6)
    assert state == 10 and end == 10 and sup.restarts == 1
    # steps 4,5 replayed after the failure at 6
    assert log == [0, 1, 2, 3, 4, 5, 4, 5, 6, 7, 8, 9]


def test_elastic_plan():
    # batch divisibility binds: 255 chips, batch 256 -> data=8 (not 15)
    p = plan_reshard(alive_chips=255, model=16, global_batch=256)
    assert p is not None and p.model == 16 and p.data == 8
    # with a 15-divisible batch the planner keeps 15 data shards
    p2 = plan_reshard(alive_chips=255, model=16, global_batch=240)
    assert p2 is not None and p2.data == 15 and p2.chips <= 255
    assert plan_reshard(alive_chips=8, model=16) is None
