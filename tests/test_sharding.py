"""Partitioning rules + HLO analyzer unit tests (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze, parse_hlo
from repro.sharding.partition import opt_state_spec, spec_for


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def _spec(path_names, shape):
    class K:
        def __init__(self, n):
            self.key = n
    from jax.tree_util import DictKey
    path = tuple(DictKey(n) for n in path_names)
    return spec_for(path, shape, _FakeMesh())


def test_column_row_rules():
    assert _spec(("scan", "s0", "attn", "wq"), (1, 512, 1024)) == P(None, None, "model")
    assert _spec(("scan", "s0", "attn", "wo"), (1, 1024, 512)) == P(None, "model", None)
    assert _spec(("scan", "s0", "ffn", "w1"), (1, 512, 2048)) == P(None, None, "model")
    assert _spec(("scan", "s0", "ffn", "w2"), (1, 2048, 512)) == P(None, "model", None)


def test_moe_expert_parallel_rule():
    assert _spec(("scan", "s0", "moe", "w1"), (1, 128, 512, 768)) == \
        P(None, "model", None, None)
    assert _spec(("scan", "s0", "moe", "w2"), (1, 128, 768, 512)) == \
        P(None, "model", None, None)
    # router replicated
    assert _spec(("scan", "s0", "moe", "router"), (1, 512, 128)) == P()


def test_indivisible_dims_stay_replicated():
    # 1000 not divisible by the 16-way axis -> replicated (canonical P())
    assert _spec(("scan", "s0", "attn", "wq"), (1, 960, 1000)) == P()
    # note: smollm's 15*64=960 IS divisible by 16 at the projection level;
    # the head-count misfit only bites at the [S, H, hd] reshape.


def test_vocab_rule_and_zero1():
    sp = _spec(("embed",), (49152, 960))
    assert sp == P("model", None)
    o = opt_state_spec(sp, (49152, 960), _FakeMesh())
    assert o == P("model", "data")
    # nothing free/divisible -> unchanged
    o2 = opt_state_spec(P("model", None), (49152, 15), _FakeMesh())
    assert o2 == P("model", None)


def test_hlo_analyzer_counts_scan_trips():
    def scanned(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    hlo = jax.jit(scanned).lower(xs, ws).compile().as_text()
    c = analyze(hlo)
    expect = 12 * 2 * 128 * 256 * 256
    assert c.flops == pytest.approx(expect, rel=0.05)


def test_hlo_analyzer_collective_formulas():
    hlo = """
HloModule m

ENTRY %main.1 (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128] parameter(0)
  %ar = f32[64,128] all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %ag = f32[64,128] all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
}
"""
    c = analyze(hlo)
    bytes_ = 64 * 128 * 4
    want_ar = 2 * bytes_ * 3 / 4
    want_ag = bytes_ * 3 / 4
    assert c.coll["all-reduce"]["wire_bytes"] == pytest.approx(want_ar)
    assert c.coll["all-gather"]["wire_bytes"] == pytest.approx(want_ag)
