"""Segment-streamed prefill: the acceptance bar for the incremental
prompt pipeline.

Pins four contracts:
  * bit-identity — segmenting the prompt forward (any segment size:
    divisor, ragged last segment, one segment covering the whole prompt,
    single-token segments) reproduces the one-shot prefill's logits AND
    KV bitwise, dense and paged, through the full scheduler;
  * prefix elision — a repeat admission under paged KV + retention skips
    the shared span's forward outright (fewer forwarded prompt tokens,
    ``prefix_tokens_skipped`` counts, identical output tokens);
  * deferred first token — a streamed ticket has no logits until the
    stream drains; the guarded entry points say so instead of
    miscomputing, and a ``max_new_tokens=1`` request still completes
    through the deferred-sample path;
  * no leaks — an admission rejected AFTER its page allocation frees the
    table before the error reaches the caller (``pages_in_use`` returns
    to baseline).
"""
import jax
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, ContinuousBatchingScheduler, \
    EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _engine(cfg, params, slots=2, capacity=32, **ecfg):
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    return CollaborativeEngine(
        cfg, params, EngineConfig(cache=ccfg, max_batch=slots,
                                  capacity=capacity, **ecfg),
        key=jax.random.PRNGKey(3))


def _fleet(cfg, n=5, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 13)))
            .astype(np.int32) for _ in range(n)]


def _trim(leaf, cap, P):
    """Slice every capacity-sized axis down to the prompt: dense ragged
    segments write pad rows past plen (decode overwrites them before any
    read — causally masked), so only rows < P are contractual."""
    a = np.asarray(leaf)
    for ax, d in enumerate(a.shape):
        if d == cap:
            a = np.take(a, np.arange(P), axis=ax)
    return a


# ---------------------------------------------------------------------------
# dense bit-identity, engine level, across segment decompositions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seg", [4, 5, 16, 1],
                         ids=["divisor", "ragged", "covers", "single"])
def test_dense_segmented_bitwise_matches_one_shot(setup, seg):
    """prefill_chunked on a segment-streamed engine (start_prefill opens
    the ticket with no forward; the drain streams the segments) matches
    the one-shot engine's logits and live KV rows BITWISE, for a divisor
    segment (4 | 12), a ragged last segment (12 = 2*5 + 2), one segment
    covering the whole prompt (16 > 12), and single-token segments."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    P, cap = len(prompt), 32

    base = _engine(cfg, params, capacity=cap)
    logits0, state0 = base.prefill_chunked(prompt, chunk=4)

    eng = _engine(cfg, params, capacity=cap, prefill_segment=seg)
    logits, state = eng.prefill_chunked(prompt)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits0))
    assert int(np.asarray(state["pos"]).reshape(-1)[0]) == P
    for got, want in zip(jax.tree_util.tree_leaves(state["scan"]),
                         jax.tree_util.tree_leaves(state0["scan"])):
        np.testing.assert_array_equal(_trim(got, cap, P),
                                      _trim(want, cap, P))
    assert eng.stats.prefill_segments == -(-P // seg)
    # segment warming routes the same tokens the trace replay would have
    assert eng.stats.prefill_tokens == base.stats.prefill_tokens == P


# ---------------------------------------------------------------------------
# scheduler-level parity: dense and paged streams vs the one-shot fleet
# ---------------------------------------------------------------------------

def _serve(cfg, params, prompts, **ecfg):
    eng = _engine(cfg, params, slots=3, capacity=32, **ecfg)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=1 if i == 0 else 6)
            for i, p in enumerate(prompts)]
    outs = sched.run()
    return eng, {i: outs[r.rid] for i, r in enumerate(reqs)}


def test_scheduler_segmented_tokens_bit_identical(setup):
    """The same request fleet through the continuous-batching scheduler:
    one-shot admission vs dense segment streaming vs paged segment
    streaming (segments appending straight into the pool pages) produce
    bit-identical tokens; the streamed request admitted with
    ``max_new_tokens=1`` completes through the deferred first-token path
    (sampled on the drain tick, retired the same tick); the paged pool
    drains to zero."""
    cfg, params = setup
    prompts = _fleet(cfg)
    _, base = _serve(cfg, params, prompts)
    eng_d, dense = _serve(cfg, params, prompts, prefill_segment=4,
                          admit_chunks_per_tick=1)
    eng_p, paged = _serve(cfg, params, prompts, prefill_segment=4,
                          admit_chunks_per_tick=1, kv_paged=True,
                          page_size=8)
    assert len(base[0]) == 1                     # max_new_tokens=1 request
    for i in base:
        np.testing.assert_array_equal(dense[i], base[i])
        np.testing.assert_array_equal(paged[i], base[i])
    for eng in (eng_d, eng_p):
        assert eng.stats.prefill_segments > 0
        assert eng.stats.first_tokens == len(prompts)
    assert eng_p.kv_pool.pages_in_use == 0
    eng_p.kv_pool.check_invariants()


def test_prefix_hit_segmented_admission_parity(setup):
    """Retention + segment streaming: re-admitting an identical prompt
    adopts the retained prefix pages and the stream starts past the
    shared span — only the last prompt token forwards, the skip is
    counted, and the output tokens are bit-identical to the cold run."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = _engine(cfg, params, slots=2, capacity=32, prefill_segment=4,
                  admit_chunks_per_tick=1, kv_paged=True, page_size=4,
                  prefix_keep_pages=16)
    sched = ContinuousBatchingScheduler(eng)

    def admit():
        before = eng.stats.prefill_tokens
        r = sched.submit(prompt, max_new_tokens=5)
        outs = sched.run()
        return outs[r.rid], eng.stats.prefill_tokens - before

    out_cold, fwd_cold = admit()
    assert eng.kv_pool.prefix_pages_retained > 0   # parked at retirement
    out_hit, fwd_hit = admit()
    np.testing.assert_array_equal(out_hit, out_cold)
    assert fwd_cold == 12
    assert fwd_hit == 1                # only the last prompt token reran
    s = eng.stats
    assert s.prefix_tokens_skipped == 11
    assert s.prefix_hits == 1
    assert s.prefix_pages_retained > 0
    eng.kv_pool.check_invariants()


# ---------------------------------------------------------------------------
# deferred-first-token guards
# ---------------------------------------------------------------------------

def test_streamed_ticket_guards_before_drain(setup):
    """A paged streamed ticket mid-stream: sample_first and bind_slot
    refuse (no logits yet), advance_prefill without the batch state
    refuses (the stream appends into the batch pool), and the ticket
    drains to done through advance_prefill_state."""
    cfg, params = setup
    eng = _engine(cfg, params, capacity=32, prefill_segment=4,
                  kv_paged=True, page_size=8)
    state = eng.init_slots()
    ticket = eng.start_prefill(np.arange(9, dtype=np.int32) + 3)
    assert ticket.logits is None and ticket.kv_streamed
    with pytest.raises(RuntimeError, match="no logits yet"):
        eng.sample_first(ticket)
    with pytest.raises(RuntimeError, match="not drained"):
        eng.bind_slot(state, ticket, 0)
    with pytest.raises(RuntimeError, match="batch pool"):
        eng.advance_prefill(ticket)
    state, done = eng.advance_prefill_state(ticket, state,
                                            max_chunks=ticket.n_chunks)
    assert done and ticket.logits is not None
    state = eng.bind_slot(state, ticket, 0)
    assert int(np.asarray(state["pos"])[0]) == 9
    eng.release_slot(0)
    assert eng.kv_pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# leak fix: rejected admissions free their pages
# ---------------------------------------------------------------------------

def test_start_prefill_error_frees_pages(setup):
    """The satellite regression: start_prefill allocates the page table
    FIRST, so any later validation error (here: a prompt filling the
    whole capacity, leaving no decode slot — allocable, not servable)
    must free it on the way out. ``pages_in_use`` returns to baseline on
    both the segmented and the trace-replay path."""
    cfg, params = setup
    bad = np.arange(32, dtype=np.int32) % cfg.vocab_size   # P == capacity

    eng = _engine(cfg, params, capacity=32, prefill_segment=4,
                  kv_paged=True, page_size=8)
    eng.init_slots()
    assert eng.kv_pool.can_admit(bad, 32)                  # pool-admissible
    with pytest.raises(ValueError, match="outside"):
        eng.start_prefill(bad)
    assert eng.kv_pool.pages_in_use == 0
    eng.kv_pool.check_invariants()

    eng2 = _engine(cfg, params, capacity=32, kv_paged=True, page_size=8)
    eng2.init_slots()
    with pytest.raises(ValueError, match="outside"):
        eng2.start_prefill(bad)
    assert eng2.kv_pool.pages_in_use == 0
    eng2.kv_pool.check_invariants()


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_engine_config_validation():
    ccfg = CacheConfig(num_indexes=4, num_ways=2)
    with pytest.raises(ValueError, match="prefill_segment"):
        EngineConfig(cache=ccfg, prefill_segment=-1)
    with pytest.raises(ValueError, match="prefix_keep_pages"):
        EngineConfig(cache=ccfg, prefix_keep_pages=-1)
    with pytest.raises(ValueError, match="requires kv_paged"):
        EngineConfig(cache=ccfg, prefix_keep_pages=4)
    EngineConfig(cache=ccfg, prefix_keep_pages=4, kv_paged=True,
                 capacity=32, page_size=8)          # valid combination
