"""Pallas moe_gmm kernels vs pure-jnp oracles: shape/dtype sweeps
(interpret mode — kernel-body semantics, CPU-executable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_gmm import grouped_matmul, moe_ffn, ref
from repro.kernels.moe_gmm.moe_gmm import gmm, swiglu_gmm

SHAPES = [
    (1, 128, 128, 128),
    (4, 128, 256, 128),
    (2, 256, 128, 384),
    (8, 64, 96, 160),      # exercises padding in the ops wrappers
    (3, 8, 64, 48),        # decode-sized capacity
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_grouped_matmul_matches_ref(shape, dtype):
    E, C, D, F = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (E, C, D), dtype)
    w = jax.random.normal(k2, (E, D, F), dtype) * 0.1
    got = grouped_matmul(x, w)
    want = ref.gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_ffn_matches_ref(shape, dtype):
    E, C, D, F = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w1 = jax.random.normal(ks[1], (E, D, F), dtype) * 0.1
    w3 = jax.random.normal(ks[2], (E, D, F), dtype) * 0.1
    w2 = jax.random.normal(ks[3], (E, F, D), dtype) * 0.1
    got = moe_ffn(x, w1, w3, w2)
    want = ref.moe_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_gmm_bitwise_matches_ref_twin():
    """Kernel/ref-twin landing convention (reprolint RL005): in the
    single-K-block regime the kernel's fp32 accumulator performs the
    exact contraction the einsum oracle does, so interpret mode and the
    jnp twin must agree BITWISE — both for the plain grouped matmul and
    the fused SwiGLU gate."""
    E, C, D, F = 2, 8, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (E, C, D), jnp.float32)
    w = jax.random.normal(ks[1], (E, D, F), jnp.float32)
    got = gmm(x, w, interpret=True)
    want = ref.gmm_ref(x, w)
    assert np.array_equal(np.asarray(got), np.asarray(want)), \
        "gmm kernel drifted from its ref.py twin (bitwise)"
    w1 = jax.random.normal(ks[2], (E, D, F), jnp.float32)
    w3 = jax.random.normal(ks[3], (E, D, F), jnp.float32)
    fused = swiglu_gmm(x, w1, w3, interpret=True)
    want2 = ref.swiglu_gmm_ref(x, w1, w3)
    assert np.array_equal(np.asarray(fused), np.asarray(want2)), \
        "swiglu_gmm kernel drifted from its ref.py twin (bitwise)"


def test_tiled_equals_untiled():
    """Block-shape independence: different tilings, same numbers."""
    E, C, D, F = 2, 256, 256, 256
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (E, C, D), jnp.float32)
    w = jax.random.normal(k2, (E, D, F), jnp.float32) * 0.1
    a = gmm(x, w, bm=128, bn=128, bk=128, interpret=True)
    b = gmm(x, w, bm=64, bn=256, bk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_fused_swiglu_equals_two_pass():
    E, C, D, F = 2, 128, 128, 128
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (E, C, D), jnp.float32)
    w1 = jax.random.normal(ks[1], (E, D, F), jnp.float32) * 0.1
    w3 = jax.random.normal(ks[2], (E, D, F), jnp.float32) * 0.1
    fused = swiglu_gmm(x, w1, w3, interpret=True)
    two = ref.swiglu_gmm_ref(x, w1, w3)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                               rtol=1e-5, atol=1e-5)
