"""Beyond-paper EP hot-expert replication cache: planning invariants."""
import numpy as np
import pytest

from repro.core.ep_cache import (home_shard, plan_replication,
                                 simulate_ep_cache)
from repro.core.router_trace import TraceConfig, synthetic_trace


def test_home_shard_contiguous():
    e = np.arange(16)
    assert list(home_shard(e, 16, 4)) == [i // 4 for i in range(16)]


def test_plan_only_replicates_remote_experts():
    counts = np.arange(16)[::-1].copy()         # expert 0 hottest
    plan = plan_replication(counts, ep_degree=4, m_hot=2,
                            expert_bytes=1000, token_bytes=10)
    per = 16 // 4
    for shard in range(4):
        own = set(range(shard * per, (shard + 1) * per))
        assert not own & set(plan.hot_experts[shard].tolist())


def test_replication_increases_local_fraction_monotonically():
    rng = np.random.default_rng(0)
    counts = rng.integers(1, 100, size=32)
    fracs = []
    for m in (0, 2, 4, 8):
        if m == 0:
            fracs.append(1 / 8)
            continue
        p = plan_replication(counts, 8, m, 1000, 10)
        fracs.append(p.local_fraction)
    assert all(b >= a - 1e-9 for a, b in zip(fracs, fracs[1:]))


def test_skewed_routing_cuts_traffic():
    """Zipf-skewed expert popularity -> big a2a savings at small m_hot."""
    tc = TraceConfig(num_tokens=200, num_layers=1, num_experts=32,
                     zipf_s=1.2, stickiness=0.3)
    trace = synthetic_trace(tc)
    frac, ratio = simulate_ep_cache(trace, ep_degree=8, m_hot=4,
                                    expert_bytes=10_000,
                                    token_bytes=8192, refresh_every=16)
    assert frac > 1 / 8 + 0.2         # way better than the EP-local share
    assert ratio < 0.8                # >20% wire-byte reduction


def test_uniform_routing_gains_little():
    tc = TraceConfig(num_tokens=100, num_layers=1, num_experts=32,
                     zipf_s=0.0, stickiness=0.0)
    trace = synthetic_trace(tc)
    frac, _ = simulate_ep_cache(trace, 8, 2, 10_000, 8192, refresh_every=16)
    assert frac < 0.35                # uniform traffic ~ (1+m/...)/ep-ish
