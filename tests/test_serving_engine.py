"""End-to-end collaborative serving engine behaviour tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prompt = np.asarray(
        jax.random.randint(key, (1, 8), 0, cfg.vocab_size), np.int32)
    return cfg, params, prompt


def _engine(cfg, params, policy="lru", ways=2, indexes=None):
    ccfg = CacheConfig(num_indexes=indexes or cfg.num_layers,
                       num_ways=ways, policy=policy)
    return CollaborativeEngine(cfg, params,
                               EngineConfig(cache=ccfg, capacity=64),
                               key=jax.random.PRNGKey(3))


def test_cache_does_not_change_outputs(setup):
    """Paper claim: no accuracy trade-off. Greedy generations with and
    without cache coverage must be IDENTICAL token-for-token."""
    cfg, params, prompt = setup
    full = _engine(cfg, params, ways=cfg.moe.num_experts)  # everything fits
    none = _engine(cfg, params, indexes=1, ways=1)         # minimal cache
    out_full, _ = full.generate(prompt, steps=16)
    out_none, _ = none.generate(prompt, steps=16)
    np.testing.assert_array_equal(out_full, out_none)


def test_full_cache_reaches_full_hit_rate_after_warmup(setup):
    cfg, params, prompt = setup
    eng = _engine(cfg, params, ways=cfg.moe.num_experts)
    _, stats = eng.generate(prompt, steps=24)
    # every miss must be a (layer, expert) first-touch: the cache holds all
    # E experts per layer, so nothing is ever evicted
    E, L = cfg.moe.num_experts, cfg.num_layers
    cold_bound = L * E
    expected = (stats.accesses - cold_bound) / stats.accesses
    assert stats.hit_rate >= expected - 1e-6
    assert stats.fetched_experts <= cold_bound


def test_lru_beats_static_random_on_average(setup):
    """LRU vs the static-random baseline. The untrained reduced router has
    near-chance expert reuse, so any SINGLE short run is a coin flip that
    depends on the random placement drawn (the seed version asserted on
    one placement and one trace and failed). Aggregate instead: LRU hit
    rate pooled over several prompt seeds, vs static-random averaged over
    several pinned placements on the same prompts."""
    cfg, params, prompt = setup

    def aggregate(policy, placement_key):
        ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2,
                           policy=policy)
        eng = CollaborativeEngine(
            cfg, params, EngineConfig(cache=ccfg, capacity=64),
            key=jax.random.PRNGKey(placement_key))
        for seed in range(3):
            p = np.asarray(jax.random.randint(
                jax.random.PRNGKey(seed), (1, 8), 0, cfg.vocab_size))
            eng.generate(p, steps=16)
        return eng.stats.hit_rate

    lru = aggregate("lru", 3)               # placement key is unused by LRU
    rnd = np.mean([aggregate("random", k) for k in (3, 5)])
    assert lru >= rnd - 0.05, (lru, rnd)


def test_stats_accounting_consistent(setup):
    cfg, params, prompt = setup
    eng = _engine(cfg, params)
    out, stats = eng.generate(prompt, steps=12)
    assert stats.accesses == stats.hits + stats.host_assignments
    assert stats.fetched_experts <= stats.host_assignments


def test_generate_counts_first_tokens(setup):
    """The first token of every row is sampled from prefill logits, not a
    decode step — it must still count toward token totals (the old
    ``tokens``-only throughput undercounted by one per sequence)."""
    cfg, params, prompt = setup
    eng = _engine(cfg, params)
    out, stats = eng.generate(prompt, steps=12)
    B = prompt.shape[0]
    assert out.shape == (B, 12)
    assert stats.first_tokens == B
    assert stats.tokens == B * 11                 # decode-step tokens only
    assert stats.generated_tokens == B * 12 == out.size
    # per-request path: prefill_request counts exactly one first token
    eng2 = _engine(cfg, params)
    eng2.prefill_request(prompt[0])
    assert eng2.stats.first_tokens == 1
    assert eng2.stats.tokens == 0
