"""Integration: the full train step improves the loss on every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import init_opt_state, make_train_step

FAMILIES = ["smollm-360m",            # dense
            "mixtral-8x7b",           # moe
            "mamba2-370m",            # ssm
            "jamba-v0.1-52b",         # hybrid
            "seamless-m4t-large-v2"]  # enc-dec


@pytest.mark.parametrize("arch", FAMILIES)
def test_loss_decreases(arch):
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("t", 128, 4, "train")
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg, shape, seed=0)
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i % 2).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1]), (arch, i, losses)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), (arch, losses)


def test_grad_accumulation_matches_full_batch():
    """micro=2 over the same global batch produces the same update as
    micro=1 (fp32 accumulation; bf16 noise tolerance)."""
    cfg = reduced(get_config("smollm-360m"))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    shape = ShapeConfig("t", 64, 4, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    outs = {}
    for micro in (1, 2):
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(cfg, ocfg, microbatches=micro))
        p2, _, m = step(params, opt, batch)
        outs[micro] = (m["loss"], p2)
    assert float(outs[1][0]) == pytest.approx(float(outs[2][0]), rel=2e-2)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        outs[1][1], outs[2][1])
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_grad_compression_step_runs():
    cfg = reduced(get_config("smollm-360m"))
    ocfg = OptimizerConfig(lr=1e-3, compress_pod_grads=True,
                           warmup_steps=1, total_steps=10)
    shape = ShapeConfig("t", 64, 2, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg, shape)
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
