"""Property-based tests (hypothesis): cache invariants + JAX/numpy twin
equivalence on arbitrary traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (minimal image); "
                    "deterministic twin-parity coverage lives in "
                    "test_cache.py")
from hypothesis import given, settings, strategies as st

from repro.config import CacheConfig
from repro.core import access, init_cache_state
from repro.core.policies import NumpyCache

policies = st.sampled_from(["lru", "fifo"])


@st.composite
def trace_and_geometry(draw):
    n = draw(st.integers(1, 4))
    m = draw(st.integers(1, 4))
    e = draw(st.integers(max(m, 2), 10))
    layers = draw(st.integers(1, 6))        # may exceed n (coverage misses)
    steps = draw(st.lists(
        st.tuples(st.integers(0, layers - 1),
                  st.lists(st.integers(0, e - 1), min_size=1, max_size=3)),
        min_size=1, max_size=40))
    return n, m, e, steps


@given(trace_and_geometry(), policies)
@settings(max_examples=60, deadline=None)
def test_jax_cache_equals_numpy_twin(tg, policy):
    n, m, e, steps = tg
    ccfg = CacheConfig(num_indexes=n, num_ways=m, policy=policy)
    js = init_cache_state(ccfg)
    nc = NumpyCache(ccfg, num_experts=e)
    for layer, experts in steps:
        js, jh, _ = access(js, jnp.int32(layer),
                           jnp.asarray(experts, jnp.int32), policy)
        nh = nc.access(layer, experts)
        assert list(np.asarray(jh)) == nh
    assert np.array_equal(np.asarray(js.tags), nc.tags)


@given(trace_and_geometry(), policies)
@settings(max_examples=60, deadline=None)
def test_cache_invariants(tg, policy):
    """(1) valid tags within a set are distinct; (2) tag values are legal
    expert ids; (3) an immediately-repeated access hits (a *non-adjacent*
    repeat may legitimately miss: an intervening FIFO insert can evict it —
    which is precisely the paper's argument for LRU, whose touch-refresh
    protects just-used experts); (4) under LRU, every expert accessed this
    call is resident afterwards when the set has enough ways."""
    n, m, e, steps = tg
    ccfg = CacheConfig(num_indexes=n, num_ways=m, policy=policy)
    s = init_cache_state(ccfg)
    for layer, experts in steps:
        s, hits, _ = access(s, jnp.int32(layer),
                            jnp.asarray(experts, jnp.int32), policy)
        hits = list(np.asarray(hits))
        for i in range(1, len(experts)):
            if layer < n and experts[i] == experts[i - 1]:
                assert hits[i]
        tags = np.asarray(s.tags)
        assert ((tags == -1) | ((tags >= 0) & (tags < max(e, 1)))).all()
        for row in tags:
            valid = row[row >= 0].tolist()
            assert len(valid) == len(set(valid))
        if policy == "lru" and layer < n and len(set(experts)) <= m:
            for ex in experts:
                assert ex in set(tags[layer].tolist())


@given(st.integers(2, 16), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_lru_never_worse_than_static_random_on_sticky_traffic(e, m):
    """On a perfectly sticky trace (same experts forever), LRU reaches 100%
    hit rate after the cold pass; static random stays at its closed form."""
    if m > e:
        m = e
    ccfg = CacheConfig(num_indexes=1, num_ways=m)
    c = NumpyCache(ccfg, num_experts=e)
    picks = list(range(min(2, m)))
    for _ in range(50):
        c.access(0, picks)
    hits_after_warm = NumpyCache(ccfg, num_experts=e)
    hits_after_warm.access(0, picks)          # cold
    for _ in range(10):
        h = hits_after_warm.access(0, picks)
        assert all(h)
