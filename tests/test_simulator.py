"""The discrete-event simulator + trace generator vs the PAPER's numbers."""
import numpy as np
import pytest

from repro.config import CacheConfig
from repro.core import TraceConfig, synthetic_trace, trace_stats
from repro.core.costmodel import PAPER_TIMINGS, cpu_pair_ms
from repro.core.simulator import best_cache_config, simulate


@pytest.fixture(scope="module")
def mixtral_trace():
    return synthetic_trace(TraceConfig(num_tokens=800, num_layers=32,
                                       num_experts=8))


def test_trace_matches_paper_fig2_bands(mixtral_trace):
    s = trace_stats(mixtral_trace)
    # Consecutive Tokens Pattern: 40-60% per layer (paper Fig. 2)
    assert 0.40 <= s["consec_token_repeat_min"]
    assert s["consec_token_repeat_max"] <= 0.65
    # Consecutive Layers Pattern: ~44%
    assert 0.35 <= s["consec_layer_repeat"] <= 0.60
    # run persistence: ~23% / ~18% (generous bands)
    assert 0.15 <= s["persist_t2_given_repeat"] <= 0.45
    assert 0.08 <= s["persist_t3_given_repeat"] <= 0.35


def test_cpu_thread_interpolation_matches_measured():
    tm = PAPER_TIMINGS["mixtral-8x7b"]
    for threads, want in tm.cpu_pair_ms.items():
        assert cpu_pair_ms(tm, threads) == want
    assert cpu_pair_ms(tm, 12) < cpu_pair_ms(tm, 8)


def test_paper_headline_claims(mixtral_trace):
    """Validates the reproduction against §IV-B numbers."""
    tm = PAPER_TIMINGS["mixtral-8x7b"]
    cfgs = best_cache_config(tm)
    tr = mixtral_trace[:400]
    ours = max(simulate(tr, tm, 24, "ours", ccfg=c).tokens_per_s
               for c in cfgs.values())
    pre = simulate(tr, tm, 24, "pregated", ccfg=cfgs[4]).tokens_per_s
    cpu = simulate(tr, tm, 24, "cpu_only", ccfg=cfgs[4]).tokens_per_s
    fid = simulate(tr, tm, 24, "fiddler", ccfg=cfgs[4]).tokens_per_s
    ond = simulate(tr, tm, 24, "on_demand", ccfg=cfgs[4]).tokens_per_s

    assert ours == pytest.approx(4.8, rel=0.12)       # paper: 4.8 tok/s
    assert ours / pre == pytest.approx(4.4, rel=0.15)  # paper: 4.4x
    assert ours / fid == pytest.approx(1.6, rel=0.25)  # paper: ~1.6x
    assert 1.15 <= ours / cpu <= 1.35                  # paper: 15~35%
    assert ond < 1.3                                   # on-demand ~1 tok/s


def test_energy_model_matches_table5(mixtral_trace):
    tm = PAPER_TIMINGS["mixtral-8x7b"]
    cfgs = best_cache_config(tm)
    r = simulate(mixtral_trace[:300], tm, 24, "ours", ccfg=cfgs[4])
    # paper Table V: 51.1 J/token at 24 cores
    assert r.joules_per_token == pytest.approx(51.1, rel=0.15)
    r1 = simulate(mixtral_trace[:300], tm, 1, "ours", ccfg=cfgs[2])
    # paper Table V: 177.7 J/token at 1 core
    assert r1.joules_per_token == pytest.approx(177.7, rel=0.2)
    pre = simulate(mixtral_trace[:300], tm, 24, "pregated", ccfg=cfgs[4])
    # paper: ours uses ~29.9% of prefetching energy
    assert r.joules_per_token / pre.joules_per_token == pytest.approx(
        0.299, rel=0.25)


def test_cache_geometry_tradeoff_matches_paper_sec4c(mixtral_trace):
    """Low cores -> more indexes/fewer ways wins; high cores -> more ways."""
    tm = PAPER_TIMINGS["mixtral-8x7b"]
    cfgs = best_cache_config(tm)
    tr = mixtral_trace[:300]
    lo_narrow = simulate(tr, tm, 1, "ours", ccfg=cfgs[2]).tokens_per_s
    lo_wide = simulate(tr, tm, 1, "ours", ccfg=cfgs[8]).tokens_per_s
    hi_narrow = simulate(tr, tm, 24, "ours", ccfg=cfgs[2]).tokens_per_s
    hi_wide = simulate(tr, tm, 24, "ours", ccfg=cfgs[4]).tokens_per_s
    assert lo_narrow >= lo_wide * 0.98     # narrow-way competitive at 1 core
    assert hi_wide > hi_narrow             # more ways clearly wins at 24
