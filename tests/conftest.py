import numpy as np
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the 512-device mesh is dry-run-only).

# analysis_fixtures/ holds miniature repo trees the reprolint tests lint;
# their test_*.py files are lint INPUT, not runnable tests.
collect_ignore_glob = ["analysis_fixtures/*"]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
