"""Unit tests for the set-associative expert cache (paper core)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig
from repro.core import access, init_cache_state, lookup
from repro.core.policies import NumpyCache, random_policy_hit_probs


def _acc(state, layer, experts, policy="lru"):
    return access(state, jnp.int32(layer), jnp.asarray(experts, jnp.int32),
                  policy)


def test_cold_miss_then_hit():
    ccfg = CacheConfig(num_indexes=2, num_ways=2)
    s = init_cache_state(ccfg)
    s, hits, ways = _acc(s, 0, [3, 5])
    assert not hits.any()
    s, hits, _ = _acc(s, 0, [3, 5])
    assert hits.all()


def test_sets_are_independent_per_layer():
    ccfg = CacheConfig(num_indexes=2, num_ways=2)
    s = init_cache_state(ccfg)
    s, _, _ = _acc(s, 0, [1, 2])
    hit, _ = lookup(s, jnp.int32(1), jnp.asarray([1, 2]))
    assert not hit.any()          # layer 1's set is untouched
    hit0, _ = lookup(s, jnp.int32(0), jnp.asarray([1, 2]))
    assert hit0.all()


def test_lru_evicts_least_recent():
    ccfg = CacheConfig(num_indexes=1, num_ways=2)
    s = init_cache_state(ccfg)
    s, _, _ = _acc(s, 0, [1])
    s, _, _ = _acc(s, 0, [2])     # set = {1, 2}, 1 older
    s, _, _ = _acc(s, 0, [1])     # touch 1 -> 2 is LRU
    s, _, _ = _acc(s, 0, [3])     # evicts 2
    hit, _ = lookup(s, jnp.int32(0), jnp.asarray([1, 3, 2]))
    assert list(np.asarray(hit)) == [True, True, False]


def test_fifo_ignores_touches():
    ccfg = CacheConfig(num_indexes=1, num_ways=2, policy="fifo")
    s = init_cache_state(ccfg)
    s, _, _ = _acc(s, 0, [1], "fifo")
    s, _, _ = _acc(s, 0, [2], "fifo")
    s, _, _ = _acc(s, 0, [1], "fifo")   # hit does NOT refresh under FIFO
    s, _, _ = _acc(s, 0, [3], "fifo")   # evicts 1 (oldest insertion)
    hit, _ = lookup(s, jnp.int32(0), jnp.asarray([1, 2, 3]))
    assert list(np.asarray(hit)) == [False, True, True]


def test_beyond_coverage_never_hits_or_inserts():
    ccfg = CacheConfig(num_indexes=2, num_ways=2)
    s = init_cache_state(ccfg)
    s, hits, ways = _acc(s, 5, [1, 2])      # layer 5 >= N=2
    assert not hits.any() and (np.asarray(ways) == -1).all()
    assert (np.asarray(s.tags) == -1).all()


def test_static_random_is_static():
    ccfg = CacheConfig(num_indexes=4, num_ways=2, policy="random")
    s = init_cache_state(ccfg, num_experts=8, key=jax.random.PRNGKey(0))
    tags0 = np.asarray(s.tags).copy()
    for t in range(20):
        s, _, _ = _acc(s, t % 4, [t % 8, (t + 3) % 8], "random")
    assert np.array_equal(tags0, np.asarray(s.tags))
    # per-set tags are distinct experts
    for row in tags0:
        assert len(set(row.tolist())) == len(row)


def test_random_policy_matches_closed_form():
    """Paper §IV-D equations vs long-run simulation on uniform traffic."""
    n, M = 8, 4
    p_any, p_both = random_policy_hit_probs(n, M)
    rng = np.random.default_rng(0)
    c = NumpyCache(CacheConfig(num_indexes=1, num_ways=M, policy="random"),
                   num_experts=n, seed=1)
    hits_any = hits_both = trials = 0
    for _ in range(4000):
        picks = rng.choice(n, size=2, replace=False)
        h = c.access(0, picks)
        hits_any += any(h)
        hits_both += all(h)
        trials += 1
    assert abs(hits_any / trials - p_any) < 0.03
    assert abs(hits_both / trials - p_both) < 0.03


def test_slot_count_math_matches_paper():
    """RTX4090 example from §III-B: 56 slots, 4-way -> 14 indexes."""
    cc = CacheConfig.from_memory(mem_bytes=56 * 340 * 2**20,
                                 expert_bytes=340 * 2**20, num_ways=4)
    assert cc.num_indexes == 14 and cc.num_slots == 56


@pytest.mark.parametrize("policy", ["lru", "fifo", "random"])
def test_vectorized_access_matches_scan_reference_and_twin(policy):
    """The vectorized row-local access must replay arbitrary traces
    (duplicates, masked -1 picks, beyond-coverage layers) bit-identically
    to the retained seed scan implementation AND the numpy twin.
    Deterministic complement to the hypothesis property suite — runs on
    minimal installs too."""
    from repro.core.cache import access_scan_reference

    rng = np.random.default_rng(7)
    for trial in range(6):
        n, m = int(rng.integers(1, 5)), int(rng.integers(1, 5))
        e = int(rng.integers(max(m, 2), 11))
        ccfg = CacheConfig(num_indexes=n, num_ways=m, policy=policy)
        key = jax.random.PRNGKey(trial)
        js = init_cache_state(ccfg, num_experts=e, key=key)
        jr = js
        nc = NumpyCache(ccfg, num_experts=e)
        if policy == "random":
            nc.tags = np.asarray(js.tags).astype(np.int64).copy()
        for step in range(10):
            layer = int(rng.integers(0, n + 2))
            ex = rng.integers(-1, e, size=int(rng.integers(1, 6)))
            js, h1, w1 = _acc(js, layer, ex, policy)
            jr, h2, w2 = access_scan_reference(
                jr, jnp.int32(layer), jnp.asarray(ex, jnp.int32), policy)
            nh = nc.access(layer, ex)
            assert list(np.asarray(h1)) == list(np.asarray(h2)) == nh, \
                (trial, step, ex)
            assert np.array_equal(np.asarray(w1), np.asarray(w2))
            assert np.array_equal(np.asarray(js.tags), np.asarray(jr.tags))
            assert np.array_equal(np.asarray(js.tags), nc.tags)
            assert np.array_equal(np.asarray(js.age), np.asarray(jr.age))


def test_masked_picks_neither_hit_nor_insert():
    """-1 picks (padded scheduler slots) are invisible to the cache — even
    when empty ways carry the -1 sentinel tag."""
    ccfg = CacheConfig(num_indexes=2, num_ways=2)
    s = init_cache_state(ccfg)            # all tags are -1 (empty)
    s, hits, ways = _acc(s, 0, [-1, 3, -1])
    assert list(np.asarray(hits)) == [False, False, False]
    assert list(np.asarray(ways)) == [-1, 0, -1]
    assert (np.asarray(s.tags)[0] == np.array([3, -1])).all()
