"""Flash-decode Pallas kernels (dense and paged) vs oracles + the
model's chunked-flash prefill vs naive attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, ref
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.models.attention import flash_attention

CASES = [
    # B, H, Hk, hd, S, pos, window
    (1, 4, 4, 64, 512, 511, -1),
    (2, 8, 2, 64, 1024, 700, -1),
    (2, 8, 2, 64, 1024, 700, 128),
    (1, 16, 8, 128, 2048, 100, -1),     # mostly-empty cache
    (3, 6, 2, 32, 512, 0, -1),          # single valid slot
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(case, dtype):
    B, H, Hk, hd, S, pos, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), dtype)
    got = decode_attention(q, k, v, jnp.int32(pos), window=window)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(pos), window=window)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("window", [-1, 96])
def test_flash_decode_vector_pos_matches_per_row(window):
    """A [B] pos vector must behave exactly like B independent scalar-pos
    calls — the serving engine's continuous batch mixes fill levels in
    one dispatch."""
    B, H, Hk, hd, S = 4, 8, 2, 64, 512
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), jnp.float32)
    pos = jnp.asarray([0, 17, 200, 511], jnp.int32)
    got = decode_attention(q, k, v, pos, window=window)
    for b in range(B):
        want = ref.decode_attention_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                        pos[b], window=window)
        np.testing.assert_allclose(np.asarray(got[b:b + 1]),
                                   np.asarray(want), rtol=2e-5, atol=2e-5,
                                   err_msg=f"row {b}")


def test_decode_attention_pos_contract():
    """ops.decode_attention rejects malformed pos at the op boundary."""
    B, H, Hk, hd, S = 2, 4, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), jnp.float32)
    with pytest.raises(ValueError, match="scalar or a"):
        decode_attention(q, k, v, jnp.zeros((B, 1), jnp.int32))
    with pytest.raises(ValueError, match="per-row pos length"):
        decode_attention(q, k, v, jnp.zeros((B + 1,), jnp.int32))


def _paged_case(seed, B, Hk, group, hd, ps, lengths, num_pages):
    """Build q + a pool and CSR tables holding the given row lengths."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hk * group, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (num_pages, ps, Hk, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (num_pages, ps, Hk, hd), jnp.float32)
    rng = np.random.default_rng(seed)
    perm = list(rng.permutation(num_pages))    # physically scattered pages
    indptr, indices, lastlen = [0], [], []
    for ln in lengths:
        n = -(-ln // ps)
        indices += [perm.pop() for _ in range(n)]
        indptr.append(len(indices))
        lastlen.append(ln - (n - 1) * ps)
    return (q, k_pages, v_pages, np.asarray(indptr, np.int32),
            np.asarray(indices, np.int32), np.asarray(lastlen, np.int32))


@pytest.mark.parametrize("window", [-1, 40])
def test_paged_flash_decode_bitwise_matches_ref_twin(window):
    """The Pallas paged kernel (interpret mode off-TPU) must match its
    jnp replay twin BITWISE — the acceptance bar for the serving paged
    path being a pure layout change."""
    lengths = [8, 23, 64, 41]
    case = _paged_case(3, B=4, Hk=2, group=3, hd=32, ps=8,
                       lengths=lengths, num_pages=24)
    q, kp, vp, indptr, indices, lastlen = case
    max_pages = int((indptr[1:] - indptr[:-1]).max())
    got = paged_decode_attention(q, kp, vp, indptr, indices, lastlen,
                                 max_pages=max_pages, window=window)
    want = ref.paged_decode_ref(q, kp, vp, indptr, indices, lastlen,
                                max_pages=max_pages, window=window)
    assert np.array_equal(np.asarray(got), np.asarray(want)), \
        np.abs(np.asarray(got) - np.asarray(want)).max()


@pytest.mark.parametrize("window", [-1, 40])
def test_paged_flash_decode_matches_gathered_dense_oracle(window):
    """Gathering each row's pages into a contiguous cache and running the
    dense oracle must agree (allclose: different reduction order)."""
    lengths = [8, 23, 64, 41]
    case = _paged_case(11, B=4, Hk=2, group=3, hd=32, ps=8,
                       lengths=lengths, num_pages=24)
    q, kp, vp, indptr, indices, lastlen = case
    max_pages = int((indptr[1:] - indptr[:-1]).max())
    got = paged_decode_attention(q, kp, vp, indptr, indices, lastlen,
                                 max_pages=max_pages, window=window)
    k = ref.paged_gather(kp, indptr, indices, max_pages)
    v = ref.paged_gather(vp, indptr, indices, max_pages)
    pos = ref.paged_lengths(indptr, lastlen, 8) - 1
    want = ref.decode_attention_ref(q, k, v, jnp.asarray(pos, jnp.int32),
                                    window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_table_contract():
    """ops.paged_decode_attention rejects CSR tables sized for the wrong
    batch at the op boundary."""
    q, kp, vp, indptr, indices, lastlen = _paged_case(
        0, B=2, Hk=2, group=2, hd=32, ps=8, lengths=[8, 16], num_pages=6)
    with pytest.raises(ValueError, match="page_indptr carries"):
        paged_decode_attention(q, kp, vp, indptr[:-1], indices, lastlen,
                               max_pages=2)
    with pytest.raises(ValueError, match="last_page_len carries"):
        paged_decode_attention(q, kp, vp, indptr, indices, lastlen[:1],
                               max_pages=2)


def _naive(q, k, v, causal=True, window=-1):
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    g = H // Hk
    kr = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * hd**-0.5, kr)
    qp, kp = jnp.arange(Sq), jnp.arange(k.shape[1])
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window > 0:
        m &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(m[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr).astype(q.dtype)


@pytest.mark.parametrize("window", [-1, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_custom_vjp_grads_match_naive(window, causal):
    """The memory-frugal FlashAttention-2-style backward must produce the
    same gradients as autodiff through naive attention."""
    B, Sq, H, Hk, hd = 1, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sq, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sq, Hk, hd), jnp.float32)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, window, causal, 32) ** 2).sum()

    def loss_naive(q, k, v):
        return (_naive(q, k, v, causal, window) ** 2).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


@pytest.mark.parametrize("window", [-1, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_flash_prefill_matches_naive(window, causal):
    B, Sq, H, Hk, hd = 2, 256, 6, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sq, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sq, Hk, hd), jnp.float32)
    got = flash_attention(q, k, v, window=window, causal=causal, chunk=64)
    want = _naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -- chunked-prefill paged kernel (segment-streamed prefill) --------------

def _prefill_paged_case(seed, C, Hk, group, hd, ps, pos0s, num_pages):
    """Build a C-query segment per row plus a pool whose row b holds
    ``pos0s[b] + C`` tokens (the segment's own KV already written — the
    serving contract: attention runs after the segment's append)."""
    B = len(pos0s)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, C, Hk * group, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (num_pages, ps, Hk, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (num_pages, ps, Hk, hd), jnp.float32)
    rng = np.random.default_rng(seed)
    perm = list(rng.permutation(num_pages))
    indptr, indices, lastlen = [0], [], []
    for p0 in pos0s:
        ln = p0 + C
        n = -(-ln // ps)
        indices += [perm.pop() for _ in range(n)]
        indptr.append(len(indices))
        lastlen.append(ln - (n - 1) * ps)
    return (q, k_pages, v_pages, np.asarray(indptr, np.int32),
            np.asarray(indices, np.int32), np.asarray(lastlen, np.int32),
            np.asarray(pos0s, np.int32))


@pytest.mark.parametrize("window", [-1, 24])
def test_paged_flash_prefill_bitwise_matches_ref_twin(window):
    """The chunked-prefill Pallas kernel (interpret mode off-TPU) must
    match its jnp replay twin BITWISE — same acceptance bar as the
    paged decode kernel: paging + segmentation are layout/schedule
    changes, never numeric ones."""
    from repro.kernels.prefill_attention import paged_prefill_attention
    from repro.kernels.prefill_attention import ref as pref

    case = _prefill_paged_case(3, C=8, Hk=2, group=3, hd=32, ps=8,
                               pos0s=[0, 5, 24, 40], num_pages=32)
    q, kp, vp, indptr, indices, lastlen, pos0 = case
    max_pages = int((indptr[1:] - indptr[:-1]).max())
    got = paged_prefill_attention(q, kp, vp, indptr, indices, lastlen,
                                  pos0, max_pages=max_pages, window=window)
    want = pref.paged_prefill_ref(q, kp, vp, indptr, indices, lastlen,
                                  pos0, max_pages=max_pages, window=window)
    assert np.array_equal(np.asarray(got), np.asarray(want)), \
        np.abs(np.asarray(got) - np.asarray(want)).max()


@pytest.mark.parametrize("window", [-1, 24])
def test_paged_flash_prefill_matches_gathered_dense_oracle(window):
    """Gathering each row's pages into a dense cache and running the
    naive C-query oracle must agree (allclose: different reduction
    order) — including rows whose segment starts at position 0."""
    from repro.kernels.decode_attention import ref as dref
    from repro.kernels.prefill_attention import paged_prefill_attention
    from repro.kernels.prefill_attention import ref as pref

    case = _prefill_paged_case(11, C=6, Hk=2, group=2, hd=32, ps=8,
                               pos0s=[0, 3, 17, 33], num_pages=32)
    q, kp, vp, indptr, indices, lastlen, pos0 = case
    max_pages = int((indptr[1:] - indptr[:-1]).max())
    got = paged_prefill_attention(q, kp, vp, indptr, indices, lastlen,
                                  pos0, max_pages=max_pages, window=window)
    k = dref.paged_gather(kp, indptr, indices, max_pages)
    v = dref.paged_gather(vp, indptr, indices, max_pages)
    lengths = pos0 + 6
    want = pref.prefill_attention_ref(q, k, v, jnp.asarray(pos0),
                                      jnp.asarray(lengths), window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_attention_boundary_contract():
    """ops.paged_prefill_attention rejects mis-sized CSR tables and pos0
    vectors at the op boundary."""
    from repro.kernels.prefill_attention import paged_prefill_attention

    q, kp, vp, indptr, indices, lastlen, pos0 = _prefill_paged_case(
        0, C=4, Hk=2, group=2, hd=32, ps=8, pos0s=[0, 8], num_pages=8)
    with pytest.raises(ValueError, match="page_indptr carries"):
        paged_prefill_attention(q, kp, vp, indptr[:-1], indices, lastlen,
                                pos0, max_pages=2)
    with pytest.raises(ValueError, match="last_page_len carries"):
        paged_prefill_attention(q, kp, vp, indptr, indices, lastlen[:1],
                                pos0, max_pages=2)
    with pytest.raises(ValueError, match="pos0"):
        paged_prefill_attention(q, kp, vp, indptr, indices, lastlen,
                                pos0[:1], max_pages=2)
