"""Flash-decode Pallas kernel vs oracle + the model's chunked-flash
prefill vs naive attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, ref
from repro.models.attention import flash_attention

CASES = [
    # B, H, Hk, hd, S, pos, window
    (1, 4, 4, 64, 512, 511, -1),
    (2, 8, 2, 64, 1024, 700, -1),
    (2, 8, 2, 64, 1024, 700, 128),
    (1, 16, 8, 128, 2048, 100, -1),     # mostly-empty cache
    (3, 6, 2, 32, 512, 0, -1),          # single valid slot
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(case, dtype):
    B, H, Hk, hd, S, pos, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), dtype)
    got = decode_attention(q, k, v, jnp.int32(pos), window=window)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(pos), window=window)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def _naive(q, k, v, causal=True, window=-1):
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    g = H // Hk
    kr = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * hd**-0.5, kr)
    qp, kp = jnp.arange(Sq), jnp.arange(k.shape[1])
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window > 0:
        m &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(m[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr).astype(q.dtype)


@pytest.mark.parametrize("window", [-1, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_custom_vjp_grads_match_naive(window, causal):
    """The memory-frugal FlashAttention-2-style backward must produce the
    same gradients as autodiff through naive attention."""
    B, Sq, H, Hk, hd = 1, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sq, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sq, Hk, hd), jnp.float32)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, window, causal, 32) ** 2).sum()

    def loss_naive(q, k, v):
        return (_naive(q, k, v, causal, window) ** 2).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


@pytest.mark.parametrize("window", [-1, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_flash_prefill_matches_naive(window, causal):
    B, Sq, H, Hk, hd = 2, 256, 6, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sq, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sq, Hk, hd), jnp.float32)
    got = flash_attention(q, k, v, window=window, causal=causal, chunk=64)
    want = _naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
