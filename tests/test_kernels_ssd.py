"""SSD Pallas kernel vs oracles + the model's chunked implementation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_chunked_kernel, ssd_scan, ref
from repro.models.ssm import ssd_chunked

CASES = [
    # G, S, hp, ds, chunk
    (2, 256, 64, 128, 128),
    (4, 128, 64, 64, 128),
    (1, 512, 32, 128, 128),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(case, dtype):
    G, S, hp, ds, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    a = -jnp.abs(jax.random.normal(ks[0], (G, S))) * 0.1
    x = jax.random.normal(ks[1], (G, S, hp), dtype)
    B = (jax.random.normal(ks[2], (G, S, ds)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[3], (G, S, ds)) * 0.3).astype(dtype)
    y, h = ssd_scan(a, x, B, C, chunk=chunk, interpret=True)
    n = S // chunk
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    for g in range(G):
        y_ref, h_ref = ref.ssd_multi_chunk_ref(
            a[g].reshape(n, chunk),
            x[g].reshape(n, chunk, hp).astype(jnp.float32),
            B[g].reshape(n, chunk, ds).astype(jnp.float32),
            C[g].reshape(n, chunk, ds).astype(jnp.float32),
            jnp.zeros((ds, hp), jnp.float32))
        np.testing.assert_allclose(
            np.asarray(y[g], np.float32),
            np.asarray(y_ref.reshape(S, hp), np.float32), **tol)
        np.testing.assert_allclose(np.asarray(h[g]), np.asarray(h_ref),
                                   rtol=2e-2, atol=2e-2)


def test_ssd_scan_bitwise_matches_ref_twin():
    """Kernel/ref-twin landing convention (reprolint RL005): with one
    chunk per sequence the kernel body performs exactly the oracle's op
    sequence, so interpret mode and the jnp twin agree BITWISE on both
    the output and the carried state."""
    G, S, hp, ds = 2, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    a = -jnp.abs(jax.random.normal(ks[0], (G, S))) * 0.1
    x = jax.random.normal(ks[1], (G, S, hp), jnp.float32)
    B = jax.random.normal(ks[2], (G, S, ds), jnp.float32)
    C = jax.random.normal(ks[3], (G, S, ds), jnp.float32)
    y, h = ssd_scan(a, x, B, C, interpret=True)
    for g in range(G):
        y_ref, h_ref = ref.ssd_multi_chunk_ref(
            a[g][None], x[g][None], B[g][None], C[g][None],
            jnp.zeros((ds, hp), jnp.float32))
        assert np.array_equal(np.asarray(y[g]), np.asarray(y_ref[0])), \
            "ssd_scan kernel drifted from its ref.py twin (bitwise)"
        assert np.array_equal(np.asarray(h[g]), np.asarray(h_ref)), \
            "ssd_scan carried state drifted from its ref.py twin (bitwise)"


def test_kernel_matches_model_ssd_chunked():
    """The Pallas kernel and the XLA model path agree end-to-end."""
    Bb, S, nh, hp, ds = 2, 256, 4, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (Bb, S, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, nh)))
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (Bb, S, ds)) * 0.3
    C = jax.random.normal(ks[4], (Bb, S, ds)) * 0.3

    y_k, h_k = ssd_chunked_kernel(x, dt, A_log, B, C)
    y_m, h_m = ssd_chunked(x, dt, A_log, B, C, chunk=128)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=5e-4, atol=5e-4)
