"""Reprolint v2 tests: the shared dataflow engine (call-graph
resolution, summaries, CFG exception paths) and the RL008–RL011 rule
families against their fixture trees."""
import ast
import textwrap
from pathlib import Path

from repro.analysis.callgraph import build_callgraph
from repro.analysis.cfg import (EXIT, RAISED, build_cfg, reaches_terminal)
from repro.analysis.cli import main
from repro.analysis.core import RULES, load_project
from repro.analysis.dataflow import Analysis
from repro.analysis.summaries import alias_closure, bare_names, summarize

FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"


def run_fixture(name, rule_id):
    project = load_project(FIXTURES / name)
    return RULES[rule_id].run(project)


def lines(findings):
    return {(f.file, f.line) for f in findings}


def mini_project(tmp_path, files):
    """A throwaway project: {relpath: source} under tmp_path."""
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return load_project(tmp_path)


# -- RL008 lifecycle pairing -------------------------------------------------

def test_rl008_bad_fixture():
    found = run_fixture("rl008_bad", "RL008")
    assert all(f.rule == "RL008" for f in found)
    by_line = {(f.file, f.line): f for f in found}
    assert set(by_line) == {
        ("src/repro/serving/engine.py", 8),    # leak on exception path
        ("src/repro/serving/engine.py", 14),   # dropped on fall-through
        ("src/repro/serving/engine.py", 20),   # prepare w/o commit
        ("src/repro/serving/engine.py", 34),   # propagated-wrapper caller
        ("src/repro/serving/scheduler.py", 10),  # claim w/o any release
    }
    assert "exception path" in \
        by_line[("src/repro/serving/engine.py", 8)].message
    assert "fall-through path" in \
        by_line[("src/repro/serving/engine.py", 14)].message
    assert "commit_append" in \
        by_line[("src/repro/serving/engine.py", 20)].message
    # the wrapper obligation was computed through the call graph, not
    # hand-listed: the acquire name in the message is the wrapper's
    assert "'open_ticket'" in \
        by_line[("src/repro/serving/engine.py", 34)].message
    assert "release_slot" in \
        by_line[("src/repro/serving/scheduler.py", 10)].message


def test_rl008_good_fixture():
    # handler release behind a None-guard, finally release, immediate
    # store, claim/release split across functions: all clean
    assert run_fixture("rl008_good", "RL008") == []


# -- RL009 thread-shared state -----------------------------------------------

def test_rl009_bad_fixture():
    found = run_fixture("rl009_bad", "RL009")
    assert all(f.rule == "RL009" for f in found)
    assert lines(found) == {
        ("src/repro/hostexec/executor.py", 12),  # worker write of done
        ("src/repro/hostexec/executor.py", 13),  # worker write of busy_ns
        ("src/repro/hostexec/executor.py", 15),  # submitting-thread write
    }
    assert all(f.symbol == "Executor" for f in found)


def test_rl009_good_fixture():
    # lock-guarded write + shared[atomic] annotations: clean
    assert run_fixture("rl009_good", "RL009") == []


# -- RL010 kernel contracts --------------------------------------------------

def test_rl010_bad_fixture():
    found = run_fixture("rl010_bad", "RL010")
    msgs = sorted(f.message for f in found)
    assert len(found) == 6
    assert lines(found) == {
        ("src/repro/kernels/demo/demo.py", 16),  # kernel params + out count
        ("src/repro/kernels/demo/demo.py", 19),  # index-map arity
        ("src/repro/kernels/demo/demo.py", 22),  # dtype not in ref twin
        ("src/repro/kernels/demo/demo.py", 41),  # operands + unmasked tail
    }
    assert any("index map takes 3 args" in m for m in msgs)
    assert any("takes 2 positional refs" in m for m in msgs)
    assert any("declares 1 output(s) but out_specs declares 2" in m
               for m in msgs)
    assert any("jnp.bfloat16" in m for m in msgs)
    assert any("3 operand(s)" in m for m in msgs)
    assert any("never bound-compares program_id(1)" in m for m in msgs)


def test_rl010_good_fixture():
    # matching arithmetic, masked ragged tail, SMEM spec without an
    # index map (exempt): clean
    assert run_fixture("rl010_good", "RL010") == []


# -- RL011 config/flag drift -------------------------------------------------

def test_rl011_bad_fixture():
    found = run_fixture("rl011_bad", "RL011")
    msgs = {(f.file, f.line): f.message for f in found}
    assert set(msgs) == {
        ("src/repro/serving/engine.py", 9),    # undiscoverable field
        ("src/repro/launch/serve.py", 8),      # unconsumed flag
    }
    assert "secret_knob" in msgs[("src/repro/serving/engine.py", 9)]
    assert "dead_flag" in msgs[("src/repro/launch/serve.py", 8)]


def test_rl011_good_fixture():
    assert run_fixture("rl011_good", "RL011") == []


def test_rl011_severity_is_warning():
    assert RULES["RL011"].severity == "warning"
    assert RULES["RL008"].severity == "error"


# -- callgraph: alias + self resolution --------------------------------------

def test_callgraph_import_alias_normalizes_bare_calls(tmp_path):
    project = mini_project(tmp_path, {
        "src/repro/a.py": """\
            from repro.b import helper as h

            def caller():
                return h(1)
        """,
        "src/repro/b.py": """\
            def helper(x):
                return x
        """,
    })
    cg = build_callgraph(project)
    names = [n for n, _ in cg.calls[("src/repro/a.py", "caller")]]
    assert names == ["helper"]          # alias normalized to the def
    site = cg.call_sites[("src/repro/a.py", "caller")][0]
    resolved = cg.resolve_site("src/repro/a.py", "caller", site)
    assert [fi.qualname for fi in resolved] == ["helper"]


def test_callgraph_self_call_prefers_own_class(tmp_path):
    project = mini_project(tmp_path, {
        "src/repro/a.py": """\
            class A:
                def m(self):
                    return 1

                def caller(self):
                    return self.m()

            class B:
                def m(self):
                    return 2
        """,
    })
    cg = build_callgraph(project)
    site = cg.call_sites[("src/repro/a.py", "A.caller")][0]
    resolved = cg.resolve_site("src/repro/a.py", "A.caller", site)
    assert [fi.qualname for fi in resolved] == ["A.m"]
    # a bare resolve would have seen both
    assert {fi.qualname for fi in cg.resolve("m")} == {"A.m", "B.m"}


# -- summaries: escapes, aliasing, the call-result cut -----------------------

def _summary_of(code, name="f"):
    tree = ast.parse(textwrap.dedent(code))
    fn = next(n for n in ast.walk(tree)
              if isinstance(n, ast.FunctionDef) and n.name == name)
    return summarize("src/repro/x.py", name, fn), fn


def test_summary_store_and_return_escapes():
    s, _ = _summary_of("""\
        def f(self, table, other):
            self._tables[0] = table
            return other
    """)
    assert s.param_stored == {"table"}
    assert s.param_returned == {"other"}


def test_summary_call_result_is_fresh():
    # tok derives from ticket through a call: NOT an alias — returning
    # tok must not count as returning the ticket
    s, _ = _summary_of("""\
        def f(self, ticket):
            tok = int(convert(ticket.logits))
            return tok
    """)
    assert "ticket" not in s.param_returned
    assert "ticket" not in s.param_stored


def test_summary_mutating_param_attr_is_not_escape():
    s, _ = _summary_of("""\
        def f(self, ticket):
            ticket.state = advance(ticket.state)
    """)
    assert s.param_stored == set()


def test_alias_closure_and_bare_names():
    tree = ast.parse(textwrap.dedent("""\
        def f(p):
            x = p
            y = x[0]
            z = g(p)
            return p.attr, y
    """))
    fn = tree.body[0]
    assert alias_closure(fn, {"p"}) == {"p", "x", "y"}   # z cut by call
    ret = fn.body[-1].value
    # p appears only as an attribute base -> not bare; y is bare
    assert bare_names(ret) == {"y"}


def test_param_escape_and_release_fixpoints(tmp_path):
    project = mini_project(tmp_path, {
        "src/repro/a.py": """\
            def keeper(self, t):
                self._all.append(t)

            def forwarder(t):
                keeper(None, t)

            def releaser(pool, t):
                pool.free(t)

            def dropper(t):
                x = t
        """,
    })
    an = Analysis(project)
    fwd = an.graph.functions[("src/repro/a.py", "forwarder")]
    rel = an.graph.functions[("src/repro/a.py", "releaser")]
    drp = an.graph.functions[("src/repro/a.py", "dropper")]
    # hmm: keeper appends t (a pass to unknown .append) -> escapes; the
    # fixpoint carries that through forwarder
    assert an.param_escapes(fwd, "t")
    assert not an.param_escapes(drp, "t")
    assert an.param_released_by(rel, "t", ("free",))
    assert not an.param_released_by(drp, "t", ("free",))


# -- CFG: exception routes and finally duplication ---------------------------

def _cfg_of(code):
    tree = ast.parse(textwrap.dedent(code))
    return build_cfg(tree.body[0])


def _node(cfg, pred):
    ids = cfg.nodes_of(pred)
    assert ids, "statement not found in CFG"
    return ids


def test_cfg_finally_discharges_both_routes():
    cfg = _cfg_of("""\
        def f(pool, table):
            try:
                audit(table)
            finally:
                pool.free(table)
    """)
    frees = set(_node(cfg, lambda s: isinstance(s, ast.Expr)
                      and isinstance(s.value, ast.Call)
                      and isinstance(s.value.func, ast.Attribute)
                      and s.value.func.attr == "free"))
    assert len(frees) == 2              # duplicated: normal + exceptional
    assert reaches_terminal(cfg, {cfg.entry}, frees) is None


def test_cfg_return_exception_edge_stays_live():
    cfg = _cfg_of("""\
        def f(self, table):
            return self.open(table)
    """)
    ret = _node(cfg, lambda s: isinstance(s, ast.Return))
    # blocked_normal absorbs the completed return but the call inside it
    # can still raise: RAISED stays reachable (PR 7's leak class)
    assert reaches_terminal(cfg, {cfg.entry}, set(),
                            blocked_normal=set(ret)) == RAISED
    # a plain `return table` has no call: nothing can raise
    cfg2 = _cfg_of("""\
        def f(table):
            return table
    """)
    ret2 = _node(cfg2, lambda s: isinstance(s, ast.Return))
    assert reaches_terminal(cfg2, {cfg2.entry}, set(),
                            blocked_normal=set(ret2)) is None


def test_cfg_handler_chain_and_branch_skip():
    cfg = _cfg_of("""\
        def f(self, table):
            try:
                return self.open(table)
            except BaseException:
                if table is not None:
                    self.free(table)
                raise
    """)
    free_ids = set(_node(cfg, lambda s: isinstance(s, ast.Expr)
                         and isinstance(s.value, ast.Call)))
    # without the None-guard skip, the impossible else-arm reaches the
    # re-raise; with it, every route is discharged by the free
    ifs = [i for i in cfg.if_branches]
    assert len(ifs) == 1
    body, orelse = cfg.if_branches[ifs[0]]
    ret = set(_node(cfg, lambda s: isinstance(s, ast.Return)))
    free_in_handler = {i for i in free_ids
                       if getattr(cfg.stmts[i].value.func, "attr", "")
                       == "free"}
    assert reaches_terminal(cfg, {cfg.entry}, free_in_handler,
                            blocked_normal=ret) == RAISED
    assert reaches_terminal(cfg, {cfg.entry}, free_in_handler,
                            blocked_normal=ret,
                            branch_skip={ifs[0]: orelse}) is None


def test_cfg_while_true_has_no_exit_edge():
    cfg = _cfg_of("""\
        def f():
            while True:
                pass
    """)
    assert reaches_terminal(cfg, {cfg.entry}, set()) is None


# -- CLI: SARIF, changed-only, severity tags ---------------------------------

def test_cli_list_shows_all_rules_with_severity(capsys):
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for rule_id in (f"RL{i:03d}" for i in range(1, 12)):
        assert rule_id in out
    assert "[error]" in out and "[warning]" in out


def test_cli_sarif_output(tmp_path, capsys):
    import json
    sarif = tmp_path / "out.sarif"
    assert main(["--root", str(FIXTURES / "rl011_bad"),
                 "--rules", "RL011", "--sarif", str(sarif)]) == 1
    capsys.readouterr()
    log = json.loads(sarif.read_text())
    assert log["version"] == "2.1.0"
    run = log["runs"][0]
    ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert ids == sorted(RULES)
    results = run["results"]
    assert len(results) == 2
    for r in results:
        assert r["ruleId"] == "RL011"
        assert r["level"] == "warning"
        assert ids[r["ruleIndex"]] == "RL011"
        loc = r["locations"][0]["physicalLocation"]
        assert loc["artifactLocation"]["uri"].startswith("src/repro/")
        assert loc["region"]["startLine"] > 0
        assert "reprolintKey/v1" in r["partialFingerprints"]


def test_cli_changed_only_filters_findings(tmp_path, capsys, monkeypatch):
    import shutil
    import subprocess
    root = tmp_path / "repo"
    shutil.copytree(FIXTURES / "rl011_bad", root)
    def git(*argv):
        subprocess.run(["git", *argv], cwd=root, check=True,
                       capture_output=True)
    git("init", "-q")
    git("config", "user.email", "t@t")
    git("config", "user.name", "t")
    git("add", "-A")
    git("commit", "-qm", "base")
    # change ONLY serve.py: the engine.py finding must be filtered out
    serve = root / "src/repro/launch/serve.py"
    serve.write_text(serve.read_text() + "\n# touched\n")
    assert main(["--root", str(root), "--rules", "RL011",
                 "--changed-only", "HEAD"]) == 1
    out = capsys.readouterr().out
    assert "serve.py" in out
    assert "engine.py" not in out
    # unknown ref: fail open — report everything rather than hide
    assert main(["--root", str(root), "--rules", "RL011",
                 "--changed-only", "no-such-ref"]) == 1
    out = capsys.readouterr().out
    assert "engine.py" in out
